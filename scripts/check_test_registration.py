#!/usr/bin/env python3
"""Integration-test registration guard.

Cargo.toml sets `autotests = false` (the offline crate universe pins
every target path explicitly), which has a footgun: a new file under
rust/tests/ that never gets a matching [[test]] entry silently stops
being compiled or run — the suite "passes" because it does not exist.

This gate diffs the files on disk against the declared [[test]] targets
and fails on any mismatch in either direction:

  * a rust/tests/*.rs file with no [[test]] entry  -> unregistered
    (it would silently never run);
  * a [[test]] entry whose path does not exist     -> dangling
    (the build would error, but catch it here with a clear message);
  * two [[test]] entries sharing a name or path    -> duplicate.

No tomllib dependency: the manifest subset this repo uses is parsed
with a line scanner so the script runs on any Python 3.

Usage:
  python3 scripts/check_test_registration.py [--manifest Cargo.toml] \
      [--tests-dir rust/tests]
"""

import argparse
import os
import re
import sys


def declared_tests(manifest_path):
    """Yield (name, path, line_number) for every [[test]] block."""
    tests = []
    current = None  # dict while inside a [[test]] block
    with open(manifest_path) as f:
        for lineno, raw in enumerate(f, 1):
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            if line.startswith("["):
                if current is not None:
                    tests.append(current)
                    current = None
                if line == "[[test]]":
                    current = {"name": None, "path": None, "line": lineno}
                continue
            if current is not None:
                m = re.match(r'(name|path)\s*=\s*"([^"]*)"', line)
                if m:
                    current[m.group(1)] = m.group(2)
    if current is not None:
        tests.append(current)
    return tests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--manifest", default="Cargo.toml")
    ap.add_argument("--tests-dir", default="rust/tests")
    args = ap.parse_args()

    declared = declared_tests(args.manifest)
    problems = []

    for t in declared:
        if not t["name"] or not t["path"]:
            problems.append(
                "[[test]] at %s:%d is missing a name or path"
                % (args.manifest, t["line"])
            )

    seen_names, seen_paths = {}, {}
    for t in declared:
        if t["name"] in seen_names:
            problems.append(
                "duplicate [[test]] name %r (lines %d and %d)"
                % (t["name"], seen_names[t["name"]], t["line"])
            )
        else:
            seen_names[t["name"]] = t["line"]
        if t["path"] in seen_paths:
            problems.append(
                "duplicate [[test]] path %r (lines %d and %d)"
                % (t["path"], seen_paths[t["path"]], t["line"])
            )
        else:
            seen_paths[t["path"]] = t["line"]

    on_disk = sorted(
        os.path.join(args.tests_dir, f)
        for f in os.listdir(args.tests_dir)
        if f.endswith(".rs")
    )
    declared_paths = {t["path"] for t in declared if t["path"]}

    for path in on_disk:
        if path not in declared_paths:
            problems.append(
                "%s has no [[test]] entry in %s — with autotests = false "
                "it would silently never compile or run" % (path, args.manifest)
            )
    for t in declared:
        if t["path"] and not os.path.exists(t["path"]):
            problems.append(
                "[[test]] %r (line %d) points at missing file %s"
                % (t["name"], t["line"], t["path"])
            )

    if problems:
        print("test registration check FAILED:")
        for p in problems:
            print("  - " + p)
        return 1
    print(
        "test registration ok: %d files under %s, %d [[test]] targets, "
        "all matched." % (len(on_disk), args.tests_dir, len(declared))
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
