#!/usr/bin/env python3
"""Hot-path bench regression gate.

Compares a freshly measured BENCH_hotpath.json (written by
`cargo bench --bench hotpath -- --smoke`) against the committed
baseline at the repo root.

The HARD gate runs on the `derived` machine-relative ratios
(batched-vs-eager / batched-vs-scalar speedups measured within one run
on one machine, plus the coordinator overlap speedups): a matched
ratio dropping by more than --threshold (default 20%) FAILS the job.
Ratios are comparable across unlike hardware, so a baseline minted on
a developer machine stays meaningful on shared CI runners.

Only derived keys that encode a bigger-is-better speedup (containing
"_vs_" or "speedup") are hard-gated. Other derived keys are raw
observability numbers (round times, idle seconds, bonus-sweep counts)
where a drop may be an improvement; they are reported as informational
only.

Absolute per-case rows_per_s numbers are compared too, but only as a
WARNING (shared-runner hardware and noise make absolute throughput
non-portable); they exist to make cross-push trends visible in the
uploaded artifacts.

A baseline whose provenance starts with "bootstrap" (or that has no
derived ratios) only records: the gate prints how to mint a real
baseline and exits 0. Keys present on only one side are reported but
never fail the gate (the matrix may grow across PRs).

Usage:
  python3 scripts/check_bench_regression.py \
      --baseline BENCH_hotpath.json \
      --fresh bench_results/BENCH_hotpath.json \
      --threshold 0.20
"""

import argparse
import json
import sys


def case_key(c):
    return "{}|J{}|p{:.2f}|{}".format(
        c["kernel"], int(c["clusters"]), float(c["density"]), c["mode"]
    )


def load(path):
    with open(path) as f:
        doc = json.load(f)
    cases = {case_key(c): float(c["rows_per_s"]) for c in doc.get("cases", [])}
    derived = {k: float(v) for k, v in doc.get("derived", {}).items()}
    return doc, cases, derived


def compare(kind, base, fresh, threshold, hard):
    failures = []
    for key, old in sorted(base.items()):
        new = fresh.get(key)
        if new is None:
            print("  [skip] %-52s missing from fresh run" % key)
            continue
        ratio = new / old if old > 0 else float("inf")
        flag = "ok "
        if ratio < 1.0 - threshold:
            flag = "FAIL" if hard else "warn"
            failures.append((key, old, new, ratio))
        print("  [%s] %s %-52s %10.3f -> %10.3f  (%.2fx)" % (flag, kind, key, old, new, ratio))
    for key in sorted(set(fresh) - set(base)):
        print("  [new ] %s %-52s %10.3f (not in baseline)" % (kind, key, fresh[key]))
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_hotpath.json")
    ap.add_argument("--fresh", default="bench_results/BENCH_hotpath.json")
    ap.add_argument("--threshold", type=float, default=0.20)
    args = ap.parse_args()

    base_doc, base_cases, base_derived = load(args.baseline)
    _, fresh_cases, fresh_derived = load(args.fresh)

    provenance = str(base_doc.get("provenance", ""))
    if provenance.startswith("bootstrap") or not base_derived:
        print(
            "baseline %r is a bootstrap (provenance=%r, %d derived ratios): gate disabled.\n"
            "Mint a measured baseline with:\n"
            "  cargo bench --bench hotpath -- --smoke --update-baseline\n"
            "and commit the rewritten BENCH_hotpath.json."
            % (args.baseline, provenance, len(base_derived))
        )
        return 0

    def is_speedup(key):
        return "_vs_" in key or "speedup" in key

    base_ratios = {k: v for k, v in base_derived.items() if is_speedup(k)}
    fresh_ratios = {k: v for k, v in fresh_derived.items() if is_speedup(k)}
    base_obs = {k: v for k, v in base_derived.items() if not is_speedup(k)}
    fresh_obs = {k: v for k, v in fresh_derived.items() if not is_speedup(k)}

    print("machine-relative speedup ratios (HARD gate):")
    hard_failures = compare("ratio", base_ratios, fresh_ratios, args.threshold, hard=True)
    if base_obs or fresh_obs:
        print("derived observability numbers (informational — lower may be better):")
        compare("obs  ", base_obs, fresh_obs, args.threshold, hard=False)
    print("absolute sweep throughput (informational — hardware-dependent):")
    soft = compare("abs  ", base_cases, fresh_cases, args.threshold, hard=False)
    if soft:
        print(
            "note: %d absolute-throughput drop(s) beyond %.0f%% (warning only; "
            "runner hardware differs from the baseline machine)."
            % (len(soft), 100 * args.threshold)
        )

    if hard_failures:
        print(
            "\n%d speedup ratio(s) regressed more than %.0f%% — failing the gate."
            % (len(hard_failures), 100 * args.threshold)
        )
        return 1
    print("no machine-relative speedup regression beyond %.0f%%." % (100 * args.threshold))
    return 0


if __name__ == "__main__":
    sys.exit(main())
