//! End-to-end driver (the repo's full-system validation, recorded in
//! EXPERIMENTS.md): the paper's density-estimation experiment (Fig. 5)
//! on a real small workload — several synthetic mixtures spanning a grid
//! of sizes and cluster counts, each fit with the parallel supercluster
//! sampler, scoring through the AOT-compiled PJRT artifacts, reporting
//! predictive log-likelihood against the generator's true entropy.
//!
//!     cargo run --release --example density_estimation [-- --full]

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::metrics::adjusted_rand_index;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    // (rows, true clusters): the paper spans 200k–1MM rows / 128–2048
    // clusters; the default grid is the laptop-scale image of it
    let grid: Vec<(usize, usize)> = if full {
        vec![(200_000, 128), (200_000, 512), (500_000, 1024), (1_000_000, 2048)]
    } else {
        vec![(5_000, 16), (10_000, 32), (10_000, 64), (20_000, 128)]
    };
    let rounds = if full { 100 } else { 50 };
    let mut scorer = auto_scorer();
    println!("density estimation (Fig. 5 shape), scorer = {}\n", scorer.name());
    println!(
        "{:>8} {:>6} | {:>10} {:>10} {:>8} {:>6} {:>6}",
        "rows", "trueJ", "true -H", "pred LL", "gap", "J", "ARI"
    );

    for (idx, &(n, clusters)) in grid.iter().enumerate() {
        let ds = SyntheticConfig {
            n,
            d: 64,
            clusters,
            beta: 0.05,
            seed: 100 + idx as u64,
        }
        .generate();
        let h = ds.true_entropy_estimate();
        let cfg = CoordinatorConfig {
            workers: 8,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(idx as u64);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        for _ in 0..rounds {
            coord.step(&mut rng);
        }
        let ll = coord.predictive_loglik(&ds.test, scorer.as_mut());
        let ari = adjusted_rand_index(&coord.assignments(), &ds.train_z);
        println!(
            "{:>8} {:>6} | {:>10.4} {:>10.4} {:>8.4} {:>6} {:>6.3}",
            n,
            clusters,
            -h,
            ll,
            ll + h,
            coord.num_clusters(),
            ari
        );
    }
    println!("\ngap → 0 means the estimate reached the generator's entropy rate");
    println!("(the Fig. 5 diagonal); J tracks the true cluster count within ~1 octave.");
}
