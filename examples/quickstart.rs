//! Quickstart: generate a small synthetic Bernoulli-mixture dataset, run
//! the serial baseline and the parallel supercluster sampler side by
//! side, and compare their convergence to the generator's entropy rate.
//!
//!     cargo run --release --example quickstart

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;
use clustercluster::serial::{SerialConfig, SerialGibbs};

fn main() {
    // 1. a synthetic workload: 4,000 rows, 64 binary dims, 16 true clusters
    let ds = SyntheticConfig {
        n: 4_000,
        d: 64,
        clusters: 16,
        beta: 0.1,
        seed: 7,
    }
    .generate();
    let h = ds.true_entropy_estimate();
    println!(
        "dataset: {} train / {} test rows, {} dims; generator entropy ≈ {h:.3} nats",
        ds.train.rows(),
        ds.test.rows(),
        ds.train.dims()
    );
    println!("(a converged density estimate reaches test log-lik ≈ {:.3})\n", -h);

    // 2. serial baseline (Neal 2000, Algorithm 3). Single-site Gibbs
    //    nucleates clusters slowly, so — like the paper's §5 calibration
    //    run — start from a prior draw with a generous initial α (the
    //    α update shrinks it to the posterior afterwards).
    let mut rng = Pcg64::seed_from(1);
    let serial_cfg = SerialConfig {
        init_alpha: 8.0,
        ..Default::default()
    };
    let mut serial = SerialGibbs::init_from_prior(&ds.train, serial_cfg, &mut rng);
    for sweep in 0..20 {
        serial.sweep(&mut rng);
        if sweep % 5 == 4 {
            println!(
                "serial   sweep {:>3}: J={:<4} test-loglik {:.4}",
                sweep + 1,
                serial.num_clusters(),
                serial.predictive_loglik(&ds.test)
            );
        }
    }

    // 3. the paper's parallel sampler: 8 superclusters, cluster shuffling,
    //    scoring through the AOT-compiled PJRT artifact when available
    let cfg = CoordinatorConfig {
        workers: 8,
        comm: CommModel::free(), // quickstart: ignore network costs
        ..Default::default()
    };
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    let mut scorer = auto_scorer();
    println!("\nparallel sampler: K=8 superclusters, scorer = {}", scorer.name());
    for round in 0..20 {
        coord.step(&mut rng);
        if round % 5 == 4 {
            println!(
                "parallel round {:>3}: J={:<4} α={:<7.3} test-loglik {:.4}",
                round + 1,
                coord.num_clusters(),
                coord.alpha(),
                coord.predictive_loglik(&ds.test, scorer.as_mut())
            );
        }
    }
    println!("\nboth chains target the same DPM posterior; the parallel one");
    println!("runs its per-datum sweeps on K independent workers (see DESIGN.md).");
}
