//! The paper's Tiny-Images vector-quantization experiment (Figs. 9–10),
//! on the synthetic substitute corpus: synthesize cluster-structured
//! "images", run the paper's exact feature pipeline (randomized PCA →
//! per-component median binarization), fit the DPM with 32 virtual
//! workers, and quantify cluster coherence vs random rows.
//!
//!     cargo run --release --example tiny_images_vq [-- --full]

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::tinyimages::{generate, mean_hamming, TinyImagesConfig};
use clustercluster::rng::Pcg64;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = if full {
        TinyImagesConfig {
            n: 100_000,
            side: 24,
            categories: 500,
            features: 256,
            calibration_rows: 10_000,
            noise: 0.6,
            seed: 3,
        }
    } else {
        TinyImagesConfig {
            n: 4_000,
            side: 16,
            categories: 30,
            features: 64,
            calibration_rows: 1_000,
            noise: 0.35,
            seed: 3,
        }
    };
    println!(
        "synthesizing {} images ({}x{} px) -> rPCA -> {} median-binarized features...",
        cfg.n, cfg.side, cfg.side, cfg.features
    );
    let corpus = generate(&cfg);
    println!("feature pipeline done; running DPM vector quantization (K=32 workers)\n");

    let ccfg = CoordinatorConfig {
        workers: 32,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(9);
    let mut coord = Coordinator::new(&corpus.features, ccfg, &mut rng);
    let rounds = if full { 60 } else { 40 };
    for it in 0..rounds {
        coord.step(&mut rng);
        if it % 5 == 4 {
            println!(
                "round {:>3}: J={:<5} α={:<8.3} modeled wall-clock {:.1}s",
                it + 1,
                coord.num_clusters(),
                coord.alpha(),
                coord.modeled_time_s
            );
        }
    }

    // Fig. 10: coherence of an inferred cluster vs random rows
    let z = coord.assignments();
    let mut sizes: std::collections::HashMap<u32, Vec<usize>> = Default::default();
    for (r, &zi) in z.iter().enumerate() {
        sizes.entry(zi).or_default().push(r);
    }
    let biggest = sizes.values().max_by_key(|v| v.len()).unwrap();
    let random: Vec<usize> = (0..corpus.features.rows()).step_by(7).take(64).collect();
    let within = mean_hamming(&corpus.features, biggest);
    let baseline = mean_hamming(&corpus.features, &random);
    println!(
        "\nFig.10 coherence: largest inferred cluster ({} rows) mean Hamming {:.2} bits",
        biggest.len(),
        within
    );
    println!("random rows baseline: {baseline:.2} bits ({:.1}x compression)", baseline / within.max(1e-9));

    // ASCII raster: 16 feature vectors of the cluster vs 16 random rows
    let render = |rows: &[usize], label: &str| {
        println!("\n{label} (rows x first 64 features):");
        for &r in rows.iter().take(16) {
            let line: String = (0..corpus.features.dims().min(64))
                .map(|c| if corpus.features.get(r, c) { '#' } else { '.' })
                .collect();
            println!("  {line}");
        }
    };
    render(biggest, "inferred cluster");
    render(&random, "random rows");
}
