//! The Fig.-8 saturation study in miniature: sweep the worker count K
//! over a fixed workload under the Hadoop-like communication cost model
//! and watch modeled time-to-target improve, saturate, then regress as
//! per-round communication overwhelms per-iteration parallelism.
//!
//! A second sweep holds K fixed and varies the supercluster granularity
//! (`MuMode`): uniform vs size-proportional vs adaptive μ, reporting
//! time-to-target and the max/mean per-shard load imbalance each mode
//! sustains — the quantity the adaptive retarget steers.
//!
//!     cargo run --release --example saturation_study

use clustercluster::coordinator::{Coordinator, CoordinatorConfig, MuMode};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::metrics::{ShardTrace, ShardTraceRow};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;

fn main() {
    let ds = SyntheticConfig {
        n: 10_000,
        d: 64,
        clusters: 64,
        beta: 0.05,
        seed: 42,
    }
    .generate();
    let h = ds.true_entropy_estimate();
    let target = -h * 1.08; // within 8% of the entropy rate
    let mut scorer = auto_scorer();
    println!(
        "workload: {} rows, 64 true clusters; target test-loglik {:.4}\n",
        ds.train.rows(),
        target
    );
    println!(
        "{:>4} {:>14} {:>14} {:>10}",
        "K", "t_target (s)", "t/round (s)", "speedup"
    );

    // latency/bandwidth scaled to the miniature workload: the paper's
    // Hadoop rounds took minutes against seconds of job overhead; here a
    // round of map compute is tens of ms, so the modeled overhead keeps
    // the same overhead:compute ratio
    let comm = CommModel {
        round_latency_s: 0.05,
        per_worker_latency_s: 0.002,
        bandwidth_bytes_per_s: 50e6,
    };
    // the paper's §5 calibration run fixes the initial concentration so
    // every K starts from a comparable state
    let mut cal_rng = Pcg64::seed_from(1234);
    let alpha0 = clustercluster::serial::calibrate_alpha(&ds.train, 0.05, 10, &mut cal_rng);
    println!("calibrated α₀ = {alpha0:.2} (serial run on 5% of the data)\n");

    let mut t1 = None;
    for k in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let cfg = CoordinatorConfig {
            workers: k,
            init_alpha: alpha0,
            comm,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(k as u64);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let mut t_target = None;
        for round in 0..80 {
            coord.step(&mut rng);
            // evaluate every 2 rounds (PJRT eval is itself not free)
            if round % 2 == 0 {
                let ll = coord.predictive_loglik(&ds.test, scorer.as_mut());
                if ll >= target {
                    t_target = Some(coord.modeled_time_s);
                    break;
                }
            }
        }
        let per_round = coord.modeled_time_s / coord.rounds as f64;
        match t_target {
            Some(t) => {
                // normalize against the first K that converged (single
                // chains can trap in merged-cluster local modes — see
                // EXPERIMENTS.md; the paper's Fig. 6 shows the same
                // per-configuration convergence spread)
                if t1.is_none() {
                    t1 = Some(t);
                }
                let speedup = t1.unwrap() / t;
                println!("{k:>4} {t:>14.2} {per_round:>14.3} {speedup:>10.2}x");
            }
            None => println!(
                "{k:>4} {:>14} {per_round:>14.3} {:>10}",
                "stuck", "-"
            ),
        }
    }
    println!("\nexpected shape (paper Fig. 8): speedup grows, saturates, then");
    println!("declines as the per-round communication term dominates.");

    // ---- second sweep: granularity modes at fixed K ----
    let k = 8usize;
    println!("\nμ-mode sweep at K={k} (same workload, same comm model):\n");
    println!(
        "{:>22} {:>14} {:>12} {:>10}",
        "mu-mode", "t_target (s)", "imbalance", "mh-accept"
    );
    for (label, mode) in [
        ("uniform", MuMode::Uniform),
        ("size-proportional", MuMode::SizeProportional),
        (
            "adaptive:1.0",
            MuMode::Adaptive {
                target_occupancy: 1.0,
            },
        ),
    ] {
        let cfg = CoordinatorConfig {
            workers: k,
            init_alpha: alpha0,
            mu_mode: mode,
            comm,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(777);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let mut t_target = None;
        // the same per-(round, shard) series --shard-trace exports; its
        // imbalance() is the max/mean occupancy statistic the adaptive
        // mode steers
        let mut st = ShardTrace::new(label);
        let rounds = 80u64;
        for round in 0..rounds {
            coord.step(&mut rng);
            for s in coord.shard_stats() {
                st.push(ShardTraceRow {
                    round,
                    shard: s.shard as u64,
                    mu: s.mu,
                    rows: s.rows,
                    clusters: s.clusters,
                    map_seconds: s.map_seconds,
                    rows_per_s: s.rows_per_s,
                    idle_s: s.idle_s,
                    barrier_wait_s: s.barrier_wait_s,
                    bonus_sweeps: s.bonus_sweeps,
                });
            }
            if round % 2 == 0 && t_target.is_none() {
                let ll = coord.predictive_loglik(&ds.test, scorer.as_mut());
                if ll >= target {
                    t_target = Some(coord.modeled_time_s);
                }
            }
        }
        // mean over rounds of the max/mean per-shard occupancy ratio
        let imbs: Vec<f64> = (0..rounds).filter_map(|r| st.imbalance(r)).collect();
        let imb = imbs.iter().sum::<f64>() / imbs.len().max(1) as f64;
        let accept = coord
            .mu_acceptance_rate()
            .map(|r| format!("{:.0}%", 100.0 * r))
            .unwrap_or_else(|| "-".to_string());
        match t_target {
            Some(t) => println!("{label:>22} {t:>14.2} {imb:>12.2} {accept:>10}"),
            None => println!("{label:>22} {:>14} {imb:>12.2} {accept:>10}", "stuck"),
        }
    }
    println!("\nadaptive μ should sustain the lowest imbalance; all three modes");
    println!("target the identical posterior (rust/tests/mu_modes.rs).");
}
