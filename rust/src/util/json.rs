//! Minimal JSON emitter (and a tiny flat parser) — enough for trace files,
//! bench outputs, and config round-trips. No serde in the offline crate
//! universe, and our needs are flat records of numbers/strings.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value restricted to what this repo emits.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// a number (always emitted as f64)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object with sorted keys (deterministic emission)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object — programmer
    /// error in trace-emission code).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Array of numbers.
    pub fn arr_nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup (`None` for non-arrays / out-of-range).
    pub fn index(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize. Numbers use shortest-roundtrip `{}` formatting; NaN and
    /// infinities (illegal JSON) are emitted as null.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{}", x);
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document. Supports the full grammar this repo emits
/// (objects, arrays, strings with the escapes above, numbers, literals).
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passthrough
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::str("fig6"))
            .set("k", Json::num(8.0))
            .set("series", Json::arr_nums(&[1.0, 2.5, -3.0]));
        let s = j.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested_and_escapes() {
        let v = parse(r#"{"a": [1, {"b": "x\ny"}, null, true]}"#).unwrap();
        let arr = match v.get("a").unwrap() {
            Json::Arr(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(arr[2], Json::Null);
        assert_eq!(arr[3], Json::Bool(true));
    }

    #[test]
    fn nonfinite_numbers_become_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("12 34").is_err());
    }

    // ---- randomized round-trip properties (seeded, deterministic) ----

    use crate::rng::Pcg64;

    /// Random string over a pool that stresses every escaping path:
    /// quotes, backslashes, named escapes, raw control characters
    /// (emitted as `\u00xx`), multi-byte UTF-8, and astral-plane chars.
    fn random_string(rng: &mut Pcg64) -> String {
        const POOL: &[char] = &[
            'a', 'z', '0', ' ', '"', '\\', '\n', '\t', '\r', '\u{0}', '\u{1}', '\u{0b}',
            '\u{1f}', 'é', 'ß', '日', '本', '\u{2028}', '😀', '𝕏',
        ];
        let len = (rng.next_u64() % 24) as usize;
        (0..len)
            .map(|_| POOL[(rng.next_u64() as usize) % POOL.len()])
            .collect()
    }

    /// Random JSON value with bounded depth; numbers are always finite
    /// (non-finite emission is pinned by `nonfinite_numbers_become_null`).
    fn random_value(rng: &mut Pcg64, depth: usize) -> Json {
        let kinds = if depth == 0 { 4 } else { 6 };
        match rng.next_u64() % kinds {
            0 => Json::Null,
            1 => Json::Bool(rng.next_u64() % 2 == 0),
            2 => {
                // spread across magnitudes, including negatives, zero,
                // and integer-valued floats (emitted without a dot)
                let mag = [0.0, 1.0, 3.5, 1e-12, 1e12, 6.02e23][(rng.next_u64() % 6) as usize];
                let sign = if rng.next_u64() % 2 == 0 { 1.0 } else { -1.0 };
                Json::Num(sign * mag * rng.next_f64())
            }
            3 => Json::Str(random_string(rng)),
            4 => {
                let n = (rng.next_u64() % 4) as usize;
                Json::Arr((0..n).map(|_| random_value(rng, depth - 1)).collect())
            }
            _ => {
                let n = (rng.next_u64() % 4) as usize;
                let mut m = BTreeMap::new();
                for _ in 0..n {
                    m.insert(random_string(rng), random_value(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    #[test]
    fn property_strings_roundtrip_through_escaping() {
        let mut rng = Pcg64::seed_from(0x15);
        for _ in 0..500 {
            let s = random_string(&mut rng);
            let emitted = Json::str(&s).to_string();
            let back = parse(&emitted)
                .unwrap_or_else(|e| panic!("emitted string failed to parse: {emitted:?}: {e}"));
            assert_eq!(back.as_str(), Some(s.as_str()), "through {emitted:?}");
        }
    }

    #[test]
    fn property_values_roundtrip_and_emit_deterministically() {
        let mut rng = Pcg64::seed_from(0x16);
        for _ in 0..300 {
            let v = random_value(&mut rng, 3);
            let emitted = v.to_string();
            let back =
                parse(&emitted).unwrap_or_else(|e| panic!("failed on {emitted:?}: {e}"));
            assert_eq!(back, v, "round-trip through {emitted:?}");
            // object keys are sorted, so emission is a pure function of
            // the value: re-emitting the parse is byte-identical
            assert_eq!(back.to_string(), emitted);
        }
    }

    #[test]
    fn escaped_and_literal_backslash_sequences_stay_distinct() {
        // "a\nb" (newline) vs "a\\nb" (backslash + n) must survive the
        // round trip as different strings
        let newline = Json::str("a\nb").to_string();
        let backslash_n = Json::str("a\\nb").to_string();
        assert_ne!(newline, backslash_n);
        assert_eq!(parse(&newline).unwrap().as_str(), Some("a\nb"));
        assert_eq!(parse(&backslash_n).unwrap().as_str(), Some("a\\nb"));
    }
}
