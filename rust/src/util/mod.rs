//! Small shared utilities: a JSON-lite emitter for traces/manifests, a
//! phase timer used by the manual profiler (the container has no `perf`),
//! and misc numeric helpers.
//!
//! The dependency universe of this repo is the offline crate cache (no
//! serde, no network), so serialization is hand-rolled and deliberately
//! minimal.

pub mod json;
pub mod timer;

/// Mean of a slice (0.0 for empty — callers guard where it matters).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample variance (unbiased). 0.0 for n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
}

/// Percentile via linear interpolation on a sorted copy. `q` in [0, 1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Argmax index (first on ties). Panics on empty input.
pub fn argmax(xs: &[f64]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argmax_first_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }
}
