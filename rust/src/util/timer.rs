//! Phase timer: the manual profiler used for the §Perf pass (the
//! container has no `perf`/flamegraph). Accumulates wall-clock per named
//! phase with negligible overhead; the coordinator instruments
//! map/reduce/shuffle, the serial sampler instruments score/sample/update.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates durations per phase name.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimer {
    /// Empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    /// Manually add a duration (for phases timed across call sites).
    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
        *self.counts.entry(phase).or_default() += 1;
    }

    /// Merge another timer's accumulators into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }

    /// Accumulated wall-clock of `phase`.
    pub fn total(&self, phase: &str) -> Duration {
        self.acc.get(phase).copied().unwrap_or_default()
    }

    /// How many times `phase` was recorded.
    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or_default()
    }

    /// All phases sorted by total time, descending — the profile report.
    pub fn report(&self) -> Vec<(&'static str, Duration, u64)> {
        let mut rows: Vec<_> = self
            .acc
            .iter()
            .map(|(&k, &v)| (k, v, self.count(k)))
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1));
        rows
    }

    /// Human-readable profile table.
    pub fn render(&self) -> String {
        let grand: Duration = self.acc.values().sum();
        let mut out = String::new();
        out.push_str("phase                       total(s)    calls   share\n");
        for (name, dur, calls) in self.report() {
            let share = if grand.as_nanos() > 0 {
                dur.as_secs_f64() / grand.as_secs_f64() * 100.0
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<26} {:>9.4} {:>8} {:>6.1}%\n",
                name,
                dur.as_secs_f64(),
                calls,
                share
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut t = PhaseTimer::new();
        let x = t.time("work", || 21 * 2);
        assert_eq!(x, 42);
        t.add("work", Duration::from_millis(5));
        assert_eq!(t.count("work"), 2);
        assert!(t.total("work") >= Duration::from_millis(5));
        assert_eq!(t.count("absent"), 0);
    }

    #[test]
    fn merge_and_report_ordering() {
        let mut a = PhaseTimer::new();
        a.add("fast", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.add("slow", Duration::from_millis(50));
        a.merge(&b);
        let rows = a.report();
        assert_eq!(rows[0].0, "slow");
        assert!(a.render().contains("slow"));
    }
}
