//! The unified sampler core: one cluster store + one kernel contract
//! shared by every MCMC entry point in the repo.
//!
//! Layering (see `DESIGN.md` §"Sampler core"):
//!
//! ```text
//!   TransitionKernel  (CollapsedGibbs | WalkerSlice    — the operator
//!        │  sweeps      | SplitMerge composites)
//!        ▼
//!   Shard  (rows + assignments + private RNG + θ)      — the unit of work
//!        │  owns
//!        ▼
//!   ClusterSet  (slotted stats, free-slot reuse)       — the hot-path store
//! ```
//!
//! The serial baseline ([`crate::serial::SerialGibbs`]) is one [`Shard`]
//! over the whole dataset with `θ = α`; the parallel coordinator
//! ([`crate::coordinator::Coordinator`]) holds one shard per supercluster
//! with `θ = α·μ_k`. Both dispatch their sweeps through the same
//! [`TransitionKernel`] trait object, so:
//!
//! * a kernel is written (and optimized) exactly once,
//! * any kernel is selectable from either entry point (`--local-kernel`),
//! * K=1 coordinator ≡ serial chain holds *by construction* — asserted
//!   sweep-by-sweep in `rust/tests/k1_equivalence.rs`.

//!
//! Candidate-cluster scoring inside a sweep goes through a per-shard
//! [`ScoreMode`] dispatch (see [`score`]): either the scalar reference
//! path or the packed batched path through
//! [`crate::runtime::Scorer::score_ones_against_clusters`], with
//! move-only incremental table maintenance (DESIGN.md §8) — selected
//! from both entry points as `--scorer auto|fallback|pjrt` and proven
//! bit-identical in `rust/tests/scorer_equivalence.rs`.
//!
//! ## Example: one shard, one kernel, three sweeps
//!
//! ```
//! use clustercluster::data::synthetic::SyntheticConfig;
//! use clustercluster::model::Model;
//! use clustercluster::rng::Pcg64;
//! use clustercluster::sampler::{KernelKind, Shard, TransitionKernel};
//!
//! let ds = SyntheticConfig { n: 120, d: 8, clusters: 3, beta: 0.2, seed: 1 }
//!     .generate_with_test_fraction(0.0);
//! let model = Model::bernoulli(8, 0.5);
//! let rows: Vec<usize> = (0..ds.train.rows()).collect();
//! let mut shard = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(7));
//! let kernel = KernelKind::CollapsedGibbs.kernel();
//! for _ in 0..3 {
//!     kernel.sweep(&mut shard, (&ds.train).into(), &model);
//! }
//! assert_eq!(shard.num_rows(), 120);
//! shard.check_invariants(&ds.train).unwrap();
//! ```

pub mod cluster_set;
pub mod kernel;
pub mod score;
pub mod shard;

pub use cluster_set::ClusterSet;
pub use kernel::{
    CollapsedGibbs, KernelAssignment, KernelKind, SplitMerge, TransitionKernel, WalkerSlice,
    SPLIT_MERGE_GIBBS, SPLIT_MERGE_WALKER,
};
pub use score::{ScoreMode, TableSet, TableSetBuilder};
pub use shard::{Shard, ShardSnapshot};
