//! Sweep-side scoring dispatch: the scalar reference path vs the packed
//! batched path through [`crate::runtime::Scorer`].
//!
//! Every kernel scores each datum against its candidate clusters. The
//! **scalar** dispatch walks the live clusters one by one through each
//! cluster's cached predictive table — the pre-batching hot loop, kept
//! as the pinned bit-exact reference. The **batched** dispatch maintains
//! the same cached tables packed column-wise into the `[D, J]` weight
//! layout of the Scorer contract (`bias[s]`, `diff[d·stride + s]`,
//! `logn[s]`, one column per `ClusterSet` slot) and scores a datum's
//! whole candidate set in one
//! [`Scorer::score_ones_against_clusters`] call over its pre-decoded
//! set-bit list.
//!
//! Three properties make the batched path a drop-in (see DESIGN.md §8
//! for the full cost model):
//!
//! * **Bit-identity.** Columns are copied from the very `ClusterStats`
//!   cache the scalar path reads, in f64, and the default scorer adds
//!   the same terms in the same order (`bias`, then `diff[d]` for each
//!   set bit ascending, then `ln n_j`) — so weights, categorical picks,
//!   and the RNG stream are *bit-identical* to the scalar path
//!   (asserted in `rust/tests/scorer_equivalence.rs`).
//! * **Move-only maintenance.** A column is a deterministic function of
//!   its cluster's sufficient statistics, so it only goes stale when a
//!   datum *actually changes cluster*. Per datum, every column is
//!   scored at full membership and the one cluster the datum just left
//!   gets a scalar **held-out correction**; when the datum re-picks its
//!   own cluster (the overwhelmingly common outcome at stationarity)
//!   the stats return to their prior values and the packed tables need
//!   **zero work**. Only a real move stales the two touched columns
//!   (each re-packed `O(D)` on the next dispatch, via an O(1) stale
//!   queue — no per-datum column scan).
//! * **Eager reference mode.** [`PackedTables::eager`] re-packs the
//!   held-out column every datum — the pre-incremental engine, kept as
//!   a bench comparator and as the chain-level drift oracle (eager and
//!   incremental chains must be bit-identical; asserted in
//!   `rust/tests/scorer_equivalence.rs`).

use crate::runtime::{Scorer, ScorerKind};

/// Config-level selector for how a shard scores candidate clusters
/// inside kernel sweeps (materialized per shard as [`ScoreDispatch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreMode {
    /// Per-cluster scalar scoring through the `ClusterStats` cache — the
    /// pre-batching reference path the equivalence suite pins.
    Scalar,
    /// Packed-table scoring through
    /// [`Scorer::score_ones_against_clusters`], with the named backend.
    Batched(ScorerKind),
}

impl Default for ScoreMode {
    fn default() -> Self {
        ScoreMode::Batched(ScorerKind::Auto)
    }
}

impl ScoreMode {
    /// Display name for logs/CLI banners.
    pub fn name(self) -> &'static str {
        match self {
            ScoreMode::Scalar => "scalar",
            ScoreMode::Batched(k) => k.name(),
        }
    }

    /// Materialize the per-shard dispatch state.
    pub(crate) fn dispatch(self, dims: usize) -> ScoreDispatch {
        match self {
            ScoreMode::Scalar => ScoreDispatch::Scalar,
            ScoreMode::Batched(kind) => ScoreDispatch::Batched {
                scorer: kind.build_or_fallback(),
                tables: PackedTables::new(dims),
            },
        }
    }

    /// The dispatch shard constructors start from: batched via the
    /// pure-Rust fallback. Unlike [`ScoreMode::default`]'s `Auto`, this
    /// never probes the filesystem for artifacts — entry points that
    /// carry a configured [`ScoreMode`] install it right after
    /// construction via `Shard::set_score_mode`.
    pub(crate) fn initial_dispatch(dims: usize) -> ScoreDispatch {
        ScoreMode::Batched(ScorerKind::Fallback).dispatch(dims)
    }
}

/// Materialized per-shard scoring state (owned by the shard so the
/// scorer instance and table allocations travel with it across the
/// coordinator's map-step worker threads).
pub(crate) enum ScoreDispatch {
    Scalar,
    Batched {
        scorer: Box<dyn Scorer>,
        tables: PackedTables,
    },
}

impl ScoreDispatch {
    pub(crate) fn name(&self) -> &'static str {
        match self {
            ScoreDispatch::Scalar => "scalar",
            ScoreDispatch::Batched { scorer, .. } => scorer.name(),
        }
    }
}

/// The packed `[table_rows, J]` predictive tables of one shard: one
/// column per `ClusterSet` slot (`stride` columns allocated, grown
/// geometrically). `dims` is the model's
/// [`crate::model::ComponentModel::table_rows`] — `D` for Bernoulli, the
/// one-hot width `W` for categorical, and `2D` for the Gaussian (a
/// location plane then an inverse-scale plane).
///
/// Staleness is tracked by an O(1) queue: [`Self::invalidate`] enqueues
/// a slot (at most once, via `queued`), and
/// `ClusterSet::refresh_packed` drains the queue — so refresh cost is
/// proportional to the number of columns that actually changed, never
/// to the slot count. Dead slots keep stale columns — they are never
/// read until re-allocated, at which point the kernel re-enqueues them.
pub(crate) struct PackedTables {
    pub(crate) dims: usize,
    /// column capacity; always ≥ the cluster store's slot count
    pub(crate) stride: usize,
    /// `bias[s]` = Σ_d ln p̂(x_d = 0 | slot s) — the n_s-dependent
    /// normalizer `−D·ln(n_s + 2β)` enters this scalar once per column,
    /// not per dim (see `ClusterStats::rebuild_cache`)
    pub(crate) bias: Vec<f64>,
    /// `aux[s]`: the per-column Student-t exponent a_n+½ for the
    /// Gaussian model (0 for the bit-backed models, which never read it)
    pub(crate) aux: Vec<f64>,
    /// `logn[s]` = ln n_s (the CRP prior factor, added *after* the
    /// likelihood block to match scalar addition order)
    pub(crate) logn: Vec<f64>,
    /// bit models: `diff[d·stride + s]` = ln p̂(x_d=1|s) − ln p̂(x_d=0|s);
    /// Gaussian: rows 0..D hold m_n, rows D..2D hold κ_n/(2b_n(κ_n+1))
    pub(crate) diff: Vec<f64>,
    /// slots whose packed column is stale (each queued at most once)
    pub(crate) stale: Vec<u32>,
    /// per-column "currently on the `stale` queue" flag
    pub(crate) queued: Vec<bool>,
    /// scratch output of the last batched block (one row × stride)
    pub(crate) scores: Vec<f64>,
    /// reference/bench knob: re-pack the held-out column every datum
    /// (the pre-incremental engine) instead of move-only maintenance;
    /// bit-identical chains either way
    pub(crate) eager: bool,
}

impl PackedTables {
    pub(crate) fn new(dims: usize) -> PackedTables {
        PackedTables {
            dims,
            stride: 0,
            bias: Vec::new(),
            aux: Vec::new(),
            logn: Vec::new(),
            diff: Vec::new(),
            stale: Vec::new(),
            queued: Vec::new(),
            scores: Vec::new(),
            eager: false,
        }
    }

    /// Begin-of-sweep hook: size for `nslots` columns and enqueue every
    /// column for refresh (cluster membership and hyperparameters may
    /// have changed arbitrarily between sweeps — shuffle moves, β
    /// updates, checkpoint resume).
    pub(crate) fn begin_sweep(&mut self, nslots: usize) {
        self.ensure_stride(nslots);
        self.stale.clear();
        for f in self.queued.iter_mut() {
            *f = false;
        }
        for s in 0..nslots {
            self.stale.push(s as u32);
            self.queued[s] = true;
        }
    }

    /// Grow the column capacity to cover `nslots`, at least doubling so
    /// mid-sweep slot growth is amortized O(1). Existing columns are
    /// re-laid out; queue flags are preserved.
    pub(crate) fn ensure_stride(&mut self, nslots: usize) {
        if nslots <= self.stride {
            return;
        }
        let new_stride = (nslots + 8).max(self.stride * 2);
        let mut diff = vec![0.0f64; self.dims * new_stride];
        if self.stride > 0 {
            for d in 0..self.dims {
                diff[d * new_stride..d * new_stride + self.stride]
                    .copy_from_slice(&self.diff[d * self.stride..(d + 1) * self.stride]);
            }
        }
        self.diff = diff;
        self.bias.resize(new_stride, 0.0);
        self.aux.resize(new_stride, 0.0);
        self.logn.resize(new_stride, f64::NEG_INFINITY);
        if self.queued.len() < new_stride {
            self.queued.resize(new_stride, false);
        }
        self.stride = new_stride;
    }

    /// Membership of `slot` changed: enqueue its column for refresh
    /// before the next batched score. Idempotent (a queued slot is not
    /// re-queued); column storage for slots beyond the current capacity
    /// is grown by [`Self::ensure_stride`] at the next refresh.
    #[inline]
    pub(crate) fn invalidate(&mut self, slot: usize) {
        if slot >= self.queued.len() {
            self.queued.resize(slot + 1, false);
        }
        if !self.queued[slot] {
            self.queued[slot] = true;
            self.stale.push(slot as u32);
        }
    }

    /// Resolve the held-out policy for one datum's dispatch — the ONE
    /// place the refresh-policy invariant lives ("transiently
    /// decremented stats must never be baked into a column"). In eager
    /// reference mode the held-out column is enqueued for an immediate
    /// re-pack with its decremented stats (and scored from the table);
    /// in incremental mode the slot is returned so the caller passes it
    /// to `ClusterSet::refresh_packed` as the deferred column and
    /// corrects its weight from the cluster cache instead.
    pub(crate) fn resolve_held_out(&mut self, held_out: Option<usize>) -> Option<usize> {
        if self.eager {
            if let Some(s) = held_out {
                self.invalidate(s);
            }
            None
        } else {
            held_out
        }
    }

    /// Batched log-likelihood block of a pre-decoded datum (ascending
    /// set-bit list) against every column; the result lands in
    /// `self.scores[0..stride]`. Columns of dead slots hold stale
    /// values — callers gather live slots only.
    pub(crate) fn score_row_ones(&mut self, scorer: &mut dyn Scorer, ones: &[u32]) {
        let (dims, stride) = (self.dims, self.stride);
        scorer.score_ones_against_clusters(
            ones,
            &self.bias,
            &self.diff,
            dims,
            stride,
            &mut self.scores,
        );
    }

    /// Batched log-likelihood block of one real-valued row against every
    /// column (the Gaussian path; `self.dims` is 2·row.len()). Same
    /// output contract as [`Self::score_row_ones`].
    pub(crate) fn score_row_real(&mut self, scorer: &mut dyn Scorer, row: &[f64]) {
        debug_assert_eq!(self.dims, 2 * row.len());
        let stride = self.stride;
        scorer.score_real_against_clusters(
            row,
            &self.bias,
            &self.aux,
            &self.diff,
            stride,
            &mut self.scores,
        );
    }
}

/// An immutable, densely packed export of every live cluster's
/// predictive table — the read-only scoring surface of the serving
/// layer ([`crate::serve`]).
///
/// Unlike the sweep-side [`PackedTables`] (slot-indexed, with dead
/// columns and growth slack), a `TableSet` has exactly one column per
/// **live** cluster, in deterministic export order: shards in shard
/// order, clusters within a shard in slot order — the same canonical
/// order every host schedule produces, so a `TableSet` exported at a
/// given round is bit-identical across runs.
///
/// Columns are copied (in f64, no re-derivation) from the very
/// `ClusterStats` caches the sweep kernels score through, so
/// [`TableSet::score_rows`] via the default
/// [`Scorer::score_rows_against_clusters`] is **bit-identical** to the
/// in-sweep batched path over the same clusters — the exactness anchor
/// the snapshot-consistency gate (`rust/tests/serve_consistency.rs`)
/// pins.
#[derive(Debug, Clone, PartialEq)]
pub struct TableSet {
    /// table rows per column ([`crate::model::ComponentModel::table_rows`])
    d: usize,
    /// live cluster count (columns)
    j: usize,
    /// `bias[s]`: per-column scalar term (length `j`)
    bias: Vec<f64>,
    /// `diff[dd * j + s]`: per-(table-row, column) term, row-major
    /// (length `d * j` — no stride slack, unlike [`PackedTables`])
    diff: Vec<f64>,
    /// `logn[s]` = ln n_s, the CRP prior factor (length `j`)
    logn: Vec<f64>,
    /// `counts[s]` = n_s, the integer occupancy (length `j`)
    counts: Vec<u64>,
}

impl TableSet {
    /// Table rows per column (`D` for Bernoulli).
    pub fn table_rows(&self) -> usize {
        self.d
    }

    /// Number of live clusters (columns).
    pub fn num_clusters(&self) -> usize {
        self.j
    }

    /// Per-column bias terms (length [`Self::num_clusters`]).
    pub fn bias(&self) -> &[f64] {
        &self.bias
    }

    /// Row-major `[table_rows, J]` diff block (`diff[dd * J + s]`).
    pub fn diff(&self) -> &[f64] {
        &self.diff
    }

    /// Per-column `ln n_s` (length [`Self::num_clusters`]).
    pub fn logn(&self) -> &[f64] {
        &self.logn
    }

    /// Per-column integer occupancy `n_s` (length [`Self::num_clusters`]).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total rows across all live clusters (Σ n_s).
    pub fn total_rows(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Score `rows` of `data` against every column through `scorer` —
    /// one contiguous block of [`Self::num_clusters`] log-likelihoods
    /// per row appended to `out` (cleared first). This *is* the offline
    /// [`Scorer::score_rows_against_clusters`] reference call; the
    /// serving layer answers queries with exactly these bits.
    pub fn score_rows(
        &self,
        scorer: &mut dyn Scorer,
        data: &crate::data::BinMat,
        rows: &[usize],
        out: &mut Vec<f64>,
    ) {
        scorer.score_rows_against_clusters(
            data, rows, &self.bias, &self.diff, self.d, self.j, out,
        );
    }
}

/// Builder for [`TableSet`]: columns are pushed one live cluster at a
/// time (column-major, the order the cluster cache hands them out) and
/// transposed into the row-major scorer layout by [`Self::finish`].
#[derive(Debug)]
pub struct TableSetBuilder {
    d: usize,
    bias: Vec<f64>,
    logn: Vec<f64>,
    counts: Vec<u64>,
    /// staged columns, column-major: `cols[s * d + dd]`
    cols: Vec<f64>,
}

impl TableSetBuilder {
    /// Start a builder for tables with `d` rows per column.
    pub fn new(d: usize) -> TableSetBuilder {
        TableSetBuilder {
            d,
            bias: Vec::new(),
            logn: Vec::new(),
            counts: Vec::new(),
            cols: Vec::new(),
        }
    }

    /// Append one live cluster's column (its cached `bias`, `ln n`,
    /// integer occupancy, and length-`d` diff column).
    pub fn push_column(&mut self, bias: f64, logn: f64, n: u64, col: &[f64]) {
        assert_eq!(col.len(), self.d, "column length must equal table rows");
        self.bias.push(bias);
        self.logn.push(logn);
        self.counts.push(n);
        self.cols.extend_from_slice(col);
    }

    /// Transpose the staged columns into the row-major scorer layout.
    pub fn finish(self) -> TableSet {
        let j = self.bias.len();
        let mut diff = vec![0.0f64; self.d * j];
        for s in 0..j {
            for dd in 0..self.d {
                diff[dd * j + s] = self.cols[s * self.d + dd];
            }
        }
        TableSet {
            d: self.d,
            j,
            bias: self.bias,
            diff,
            logn: self.logn,
            counts: self.counts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::cluster_set::ClusterSet;
    use super::*;
    use crate::data::BinMat;
    use crate::model::Model;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> BinMat {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.45 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// From-scratch reference: a fresh table with every column enqueued
    /// and refreshed — what the incremental tables must equal.
    fn scratch_repack(cs: &mut ClusterSet, model: &Model, dims: usize) -> PackedTables {
        let mut t = PackedTables::new(dims);
        t.begin_sweep(cs.num_slots());
        cs.refresh_packed(model, &mut t, None);
        t
    }

    fn assert_tables_bit_equal(
        cs: &ClusterSet,
        inc: &PackedTables,
        refr: &PackedTables,
        dims: usize,
        ctx: &str,
    ) {
        for slot in cs.occupied_slots() {
            assert_eq!(
                inc.bias[slot].to_bits(),
                refr.bias[slot].to_bits(),
                "{ctx}: bias drift at slot {slot}"
            );
            assert_eq!(
                inc.aux[slot].to_bits(),
                refr.aux[slot].to_bits(),
                "{ctx}: aux drift at slot {slot}"
            );
            assert_eq!(
                inc.logn[slot].to_bits(),
                refr.logn[slot].to_bits(),
                "{ctx}: logn drift at slot {slot}"
            );
            for d in 0..dims {
                assert_eq!(
                    inc.diff[d * inc.stride + slot].to_bits(),
                    refr.diff[d * refr.stride + slot].to_bits(),
                    "{ctx}: diff drift at (dim {d}, slot {slot})"
                );
            }
        }
    }

    /// The drift gate for incremental maintenance: a randomized sequence
    /// of join/leave/alloc/free operations, with exactly the
    /// invalidations the kernels issue, leaves the incrementally
    /// maintained tables *bit-equal* to a from-scratch repack (stronger
    /// than the 1-ulp requirement: columns are copied from the
    /// deterministic per-cluster caches, never accumulated in place).
    #[test]
    fn incremental_refresh_matches_scratch_repack_bitwise() {
        let (n, d) = (60usize, 24usize);
        let data = rand_data(n, d, 31);
        let mut model = Model::bernoulli(d, 0.4);
        model.build_lut(n + 1);
        let mut rng = Pcg64::seed_from(32);
        let mut cs = ClusterSet::new(d);
        let mut inc = PackedTables::new(d);
        inc.begin_sweep(cs.num_slots());
        let mut member: Vec<Option<usize>> = vec![None; n];
        for step in 0..500 {
            let r = rng.next_below(n as u64) as usize;
            match member[r] {
                Some(slot) => {
                    // leave (the slot frees itself when it empties)
                    cs.remove_row(slot, &data, r);
                    member[r] = None;
                    inc.invalidate(slot);
                }
                None => {
                    let occ = cs.occupied_slots();
                    let slot = if occ.is_empty() || rng.next_f64() < 0.3 {
                        cs.alloc_empty()
                    } else {
                        occ[rng.next_below(occ.len() as u64) as usize]
                    };
                    cs.add_row(slot, &data, r);
                    member[r] = Some(slot);
                    inc.invalidate(slot);
                }
            }
            if step % 7 == 0 {
                cs.refresh_packed(&model, &mut inc, None);
                let refr = scratch_repack(&mut cs, &model, d);
                assert_tables_bit_equal(&cs, &inc, &refr, d, &format!("step {step}"));
            }
        }
    }

    /// A self-move (remove a datum, then re-add it to the same cluster)
    /// restores the sufficient statistics exactly, so the packed column
    /// needs no invalidation — the core of the move-only maintenance.
    #[test]
    fn self_move_needs_no_invalidation() {
        let (n, d) = (10usize, 16usize);
        let data = rand_data(n, d, 33);
        let mut model = Model::bernoulli(d, 0.5);
        model.build_lut(n + 1);
        let mut cs = ClusterSet::new(d);
        let slot = cs.alloc_empty();
        for r in 0..5 {
            cs.add_row(slot, &data, r);
        }
        let mut inc = PackedTables::new(d);
        inc.begin_sweep(cs.num_slots());
        cs.refresh_packed(&model, &mut inc, None);
        // self-move, deliberately without invalidate()
        cs.remove_row(slot, &data, 2);
        cs.add_row(slot, &data, 2);
        cs.refresh_packed(&model, &mut inc, None); // queue is empty: no work
        let refr = scratch_repack(&mut cs, &model, d);
        assert_tables_bit_equal(&cs, &inc, &refr, d, "self-move");
    }

    /// The split–merge move layer's table contract: randomized sequences
    /// of its bulk operations — `move_row` between live slots, wholesale
    /// `merge_slots`, and split-style subset moves into a fresh slot —
    /// with exactly the two-column invalidations the kernel issues leave
    /// the incrementally maintained tables bit-equal to a from-scratch
    /// repack (the same gate the per-datum ops pass above).
    #[test]
    fn split_merge_bulk_ops_keep_tables_bit_exact() {
        let (n, d) = (48usize, 16usize);
        let data = rand_data(n, d, 41);
        let mut model = Model::bernoulli(d, 0.5);
        model.build_lut(n + 1);
        let mut rng = Pcg64::seed_from(42);
        let mut cs = ClusterSet::new(d);
        let mut inc = PackedTables::new(d);
        inc.begin_sweep(cs.num_slots());
        // membership model: row -> slot
        let mut slot_of: Vec<usize> = Vec::with_capacity(n);
        for r in 0..n {
            let occ = cs.occupied_slots();
            let slot = if occ.len() < 3 {
                let s = cs.alloc_empty();
                inc.invalidate(s);
                s
            } else {
                occ[rng.next_below(occ.len() as u64) as usize]
            };
            cs.add_row(slot, &data, r);
            inc.invalidate(slot);
            slot_of.push(slot);
        }
        for step in 0..240 {
            let occ = cs.occupied_slots();
            match rng.next_below(3) {
                // move one row between two live slots (restricted scan)
                0 if occ.len() >= 2 => {
                    let r = rng.next_below(n as u64) as usize;
                    let from = slot_of[r];
                    let mut to = occ[rng.next_below(occ.len() as u64) as usize];
                    if to == from {
                        to = *occ.iter().find(|&&s| s != from).unwrap();
                    }
                    cs.move_row(from, to, &data, r);
                    slot_of[r] = to;
                    inc.invalidate(from);
                    inc.invalidate(to);
                }
                // wholesale merge of two live slots (accepted merge)
                1 if occ.len() >= 3 => {
                    let from = occ[rng.next_below(occ.len() as u64) as usize];
                    let mut into = occ[rng.next_below(occ.len() as u64) as usize];
                    if into == from {
                        into = *occ.iter().find(|&&s| s != from).unwrap();
                    }
                    cs.merge_slots(from, into);
                    for s in slot_of.iter_mut() {
                        if *s == from {
                            *s = into;
                        }
                    }
                    inc.invalidate(from);
                    inc.invalidate(into);
                }
                // split: move half a slot's rows into a fresh slot
                _ => {
                    let src = occ[rng.next_below(occ.len() as u64) as usize];
                    let members: Vec<usize> =
                        (0..n).filter(|&r| slot_of[r] == src).collect();
                    if members.len() < 2 {
                        continue;
                    }
                    let dst = cs.alloc_empty();
                    for &r in members.iter().take(members.len() / 2) {
                        cs.move_row(src, dst, &data, r);
                        slot_of[r] = dst;
                    }
                    inc.invalidate(src);
                    inc.invalidate(dst);
                }
            }
            if step % 6 == 0 {
                cs.refresh_packed(&model, &mut inc, None);
                let refr = scratch_repack(&mut cs, &model, d);
                assert_tables_bit_equal(&cs, &inc, &refr, d, &format!("bulk step {step}"));
            }
        }
        cs.check_slot_invariants().unwrap();
    }

    #[test]
    fn invalidate_is_idempotent_and_covers_unallocated_slots() {
        let mut t = PackedTables::new(4);
        t.invalidate(9); // beyond any allocated column
        t.invalidate(9);
        t.invalidate(2);
        assert_eq!(t.stale.len(), 2);
        assert!(t.queued[9] && t.queued[2]);
        // growth preserves the queue flags
        t.ensure_stride(12);
        assert!(t.queued[9] && t.queued[2]);
        assert_eq!(t.stale.len(), 2);
    }

    /// The builder's column-major → row-major transpose, and bit-equality
    /// of [`TableSet::score_rows`] against a hand-rolled bias + Σ diff
    /// evaluation in the same addition order.
    #[test]
    fn table_set_builder_transposes_and_scores_bitwise() {
        let (d, j) = (5usize, 3usize);
        let mut b = TableSetBuilder::new(d);
        let mut rng = Pcg64::seed_from(77);
        let mut cols: Vec<Vec<f64>> = Vec::new();
        for s in 0..j {
            let col: Vec<f64> = (0..d).map(|_| rng.next_f64() - 0.5).collect();
            b.push_column(-(s as f64) - 1.0, (s as f64 + 1.0).ln(), s as u64 + 1, &col);
            cols.push(col);
        }
        let t = b.finish();
        assert_eq!(t.num_clusters(), j);
        assert_eq!(t.table_rows(), d);
        assert_eq!(t.total_rows(), 1 + 2 + 3);
        for s in 0..j {
            for dd in 0..d {
                assert_eq!(t.diff()[dd * j + s].to_bits(), cols[s][dd].to_bits());
            }
        }
        let data = rand_data(4, d, 78);
        let mut scorer = crate::runtime::FallbackScorer::new();
        let rows: Vec<usize> = (0..4).collect();
        let mut got = Vec::new();
        t.score_rows(&mut scorer, &data, &rows, &mut got);
        assert_eq!(got.len(), 4 * j);
        // reference: same addition order as the default scorer path
        // (bias first, then diff terms for ascending set bits)
        for (ri, &r) in rows.iter().enumerate() {
            let mut want = vec![0.0f64; j];
            want.copy_from_slice(t.bias());
            data.for_each_one(r, |dd| {
                for s in 0..j {
                    want[s] += t.diff()[dd * j + s];
                }
            });
            for s in 0..j {
                assert_eq!(got[ri * j + s].to_bits(), want[s].to_bits(), "row {r} col {s}");
            }
        }
    }
}
