//! Sweep-side scoring dispatch: the scalar reference path vs the packed
//! batched path through [`crate::runtime::Scorer`].
//!
//! Every kernel scores each datum against its candidate clusters. The
//! **scalar** dispatch walks the live clusters one by one through each
//! cluster's cached predictive table — the pre-batching hot loop, kept
//! as the bit-exact reference. The **batched** dispatch maintains the
//! same cached tables packed column-wise into the `[D, J]` weight layout
//! of the Scorer contract (`bias[s]`, `diff[d·stride + s]`, `logn[s]`,
//! one column per `ClusterSet` slot) and scores a datum's whole
//! candidate set in one [`Scorer::score_rows_against_clusters`] call.
//!
//! Two properties make the batched path a drop-in:
//!
//! * **Bit-identity.** Columns are copied from the very `ClusterStats`
//!   cache the scalar path reads, in f64, and the default scorer adds
//!   the same terms in the same order (`bias`, then `diff[d]` for each
//!   set bit ascending, then `ln n_j`) — so weights, categorical picks,
//!   and the RNG stream are *bit-identical* to the scalar path
//!   (asserted in `rust/tests/scorer_equivalence.rs`).
//! * **Incremental updates.** Per datum at most two clusters change (the
//!   one the datum left, the one it joined), so only those columns are
//!   re-packed (`O(D)` each) and the per-datum table maintenance stays
//!   `O(J + D)`, not `O(D·J)`. A full re-pack happens once per sweep.

use crate::runtime::{Scorer, ScorerKind};

/// Config-level selector for how a shard scores candidate clusters
/// inside kernel sweeps (materialized per shard as [`ScoreDispatch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreMode {
    /// Per-cluster scalar scoring through the `ClusterStats` cache — the
    /// pre-batching reference path the equivalence suite pins.
    Scalar,
    /// Packed-table scoring through
    /// [`Scorer::score_rows_against_clusters`], with the named backend.
    Batched(ScorerKind),
}

impl Default for ScoreMode {
    fn default() -> Self {
        ScoreMode::Batched(ScorerKind::Auto)
    }
}

impl ScoreMode {
    /// Display name for logs/CLI banners.
    pub fn name(self) -> &'static str {
        match self {
            ScoreMode::Scalar => "scalar",
            ScoreMode::Batched(k) => k.name(),
        }
    }

    /// Materialize the per-shard dispatch state.
    pub(crate) fn dispatch(self, dims: usize) -> ScoreDispatch {
        match self {
            ScoreMode::Scalar => ScoreDispatch::Scalar,
            ScoreMode::Batched(kind) => ScoreDispatch::Batched {
                scorer: kind.build_or_fallback(),
                tables: PackedTables::new(dims),
            },
        }
    }

    /// The dispatch shard constructors start from: batched via the
    /// pure-Rust fallback. Unlike [`ScoreMode::default`]'s `Auto`, this
    /// never probes the filesystem for artifacts — entry points that
    /// carry a configured [`ScoreMode`] install it right after
    /// construction via `Shard::set_score_mode`.
    pub(crate) fn initial_dispatch(dims: usize) -> ScoreDispatch {
        ScoreMode::Batched(ScorerKind::Fallback).dispatch(dims)
    }
}

/// Materialized per-shard scoring state (owned by the shard so the
/// scorer instance and table allocations travel with it across the
/// coordinator's map-step worker threads).
pub(crate) enum ScoreDispatch {
    Scalar,
    Batched {
        scorer: Box<dyn Scorer>,
        tables: PackedTables,
    },
}

impl ScoreDispatch {
    pub(crate) fn name(&self) -> &'static str {
        match self {
            ScoreDispatch::Scalar => "scalar",
            ScoreDispatch::Batched { scorer, .. } => scorer.name(),
        }
    }
}

/// The packed `[D, J]` predictive tables of one shard: one column per
/// `ClusterSet` slot (`stride` columns allocated, grown geometrically),
/// refreshed lazily from the per-cluster caches via the dirty flags.
/// Dead slots keep stale columns — they are never read.
pub(crate) struct PackedTables {
    pub(crate) dims: usize,
    /// column capacity; always ≥ the cluster store's slot count
    pub(crate) stride: usize,
    /// `bias[s]` = Σ_d ln p̂(x_d = 0 | slot s)
    pub(crate) bias: Vec<f64>,
    /// `logn[s]` = ln n_s (the CRP prior factor, added *after* the
    /// likelihood block to match scalar addition order)
    pub(crate) logn: Vec<f64>,
    /// `diff[d·stride + s]` = ln p̂(x_d=1|s) − ln p̂(x_d=0|s)
    pub(crate) diff: Vec<f64>,
    /// column needs a re-pack before the next batched score
    pub(crate) dirty: Vec<bool>,
    /// scratch output of the last batched block (one row × stride)
    pub(crate) scores: Vec<f64>,
}

impl PackedTables {
    pub(crate) fn new(dims: usize) -> PackedTables {
        PackedTables {
            dims,
            stride: 0,
            bias: Vec::new(),
            logn: Vec::new(),
            diff: Vec::new(),
            dirty: Vec::new(),
            scores: Vec::new(),
        }
    }

    /// Begin-of-sweep hook: size for `nslots` columns and mark every
    /// column stale (cluster membership may have changed arbitrarily
    /// between sweeps — shuffle moves, hyper updates, checkpoint resume).
    pub(crate) fn begin_sweep(&mut self, nslots: usize) {
        self.ensure_stride(nslots);
        for f in self.dirty.iter_mut() {
            *f = true;
        }
    }

    /// Grow the column capacity to cover `nslots`, at least doubling so
    /// mid-sweep slot growth is amortized O(1). Existing columns are
    /// re-laid out; new columns start dirty.
    pub(crate) fn ensure_stride(&mut self, nslots: usize) {
        if nslots <= self.stride {
            return;
        }
        let new_stride = (nslots + 8).max(self.stride * 2);
        let mut diff = vec![0.0f64; self.dims * new_stride];
        if self.stride > 0 {
            for d in 0..self.dims {
                diff[d * new_stride..d * new_stride + self.stride]
                    .copy_from_slice(&self.diff[d * self.stride..(d + 1) * self.stride]);
            }
        }
        self.diff = diff;
        self.bias.resize(new_stride, 0.0);
        self.logn.resize(new_stride, f64::NEG_INFINITY);
        self.dirty.resize(new_stride, true);
        self.stride = new_stride;
    }

    /// Membership of `slot` changed: stale its column. Slots beyond the
    /// current capacity are covered by [`Self::ensure_stride`], which
    /// marks every new column dirty.
    #[inline]
    pub(crate) fn mark_dirty(&mut self, slot: usize) {
        if slot < self.stride {
            self.dirty[slot] = true;
        }
    }

    /// Batched log-likelihood block of data row `r` against every
    /// column; the result lands in `self.scores[0..stride]`. Columns of
    /// dead slots hold stale values — callers gather live slots only.
    pub(crate) fn score_row(
        &mut self,
        scorer: &mut dyn Scorer,
        data: &crate::data::BinMat,
        r: usize,
    ) {
        let rows = [r];
        scorer.score_rows_against_clusters(
            data,
            &rows,
            &self.bias,
            &self.diff,
            self.dims,
            self.stride,
            &mut self.scores,
        );
    }
}
