//! The slotted cluster store shared by every sampler entry point.
//!
//! Clusters live in stable *slots* (`Vec<Option<ClusterStats>>`): a
//! datum's assignment is a slot index that stays valid across sweeps, a
//! cluster that empties returns its slot to a free list, and a new
//! cluster reuses the lowest-recently-freed slot before growing the
//! vector. This keeps the per-sweep allocation profile flat (the Gibbs
//! hot loop never allocates after warm-up) and makes assignment vectors
//! cheap to persist.
//!
//! Invariants (checked by [`ClusterSet::check_slot_invariants`] and the
//! property suite in `rust/tests/property_invariants.rs`):
//!
//! * every `None` slot is on the free list exactly once;
//! * every free-list entry points at a `None` slot;
//! * no occupied slot holds an empty cluster — except transiently inside
//!   a Walker sweep, which uses [`ClusterSet::remove_row_keep_slot`] and
//!   restores the invariant with [`ClusterSet::compact_free_slots`].

use super::score::PackedTables;
use crate::data::DataRef;
use crate::model::{BetaBernoulli, ClusterStats, Model};

/// Largest number of emptied [`ClusterStats`] kept for reuse: a freshly
/// emptied cluster's count vectors are already zeroed, so recycling them
/// makes new-table picks allocation-free after warm-up.
const GRAVEYARD_CAP: usize = 8;

/// Slotted storage for the clusters of one shard.
#[derive(Debug, Clone)]
pub struct ClusterSet {
    slots: Vec<Option<ClusterStats>>,
    free: Vec<usize>,
    dims: usize,
    /// recycle pool of emptied stats (n = 0, counts zeroed, cache
    /// invalid) so the kernel hot loop never re-allocates the O(D)
    /// vectors on a new-table pick
    graveyard: Vec<ClusterStats>,
}

impl ClusterSet {
    /// An empty store for `dims`-dimensional sufficient statistics.
    pub fn new(dims: usize) -> ClusterSet {
        ClusterSet {
            slots: Vec::new(),
            free: Vec::new(),
            dims,
            graveyard: Vec::new(),
        }
    }

    /// Rebuild from raw slots (checkpoint resume); recomputes the free list.
    pub(crate) fn from_slots(slots: Vec<Option<ClusterStats>>, dims: usize) -> ClusterSet {
        let free = slots
            .iter()
            .enumerate()
            .filter_map(|(s, c)| c.is_none().then_some(s))
            .collect();
        ClusterSet {
            slots,
            free,
            dims,
            graveyard: Vec::new(),
        }
    }

    /// Park an emptied cluster's stats for reuse (counts are already
    /// zeroed — the datum removals that emptied it did the zeroing).
    fn recycle(&mut self, stats: ClusterStats) {
        debug_assert!(stats.is_empty());
        if self.graveyard.len() < GRAVEYARD_CAP {
            self.graveyard.push(stats);
        }
    }

    /// Sufficient-statistic width every cluster's stats are sized for
    /// (the model's `stat_dims` / the data's [`DataRef::dims`]).
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Number of occupied slots (live clusters).
    pub fn num_active(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Total slot-vector length (occupied + free).
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Current free-list length (introspection for the property tests).
    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    /// Stats of `slot`, or `None` for a free/out-of-range slot.
    pub fn get(&self, slot: usize) -> Option<&ClusterStats> {
        self.slots.get(slot).and_then(|c| c.as_ref())
    }

    /// Datum count of `slot` (0 for a dead or empty slot).
    pub fn n_of(&self, slot: usize) -> u64 {
        self.get(slot).map(|c| c.n()).unwrap_or(0)
    }

    /// Materialize a fresh empty cluster, reusing a freed slot (and a
    /// recycled stats allocation) if any.
    pub fn alloc_empty(&mut self) -> usize {
        let stats = self
            .graveyard
            .pop()
            .unwrap_or_else(|| ClusterStats::empty(self.dims));
        self.insert(stats)
    }

    /// Insert fully-formed stats (shuffle moves, single-cluster init).
    pub fn insert(&mut self, stats: ClusterStats) -> usize {
        match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(stats);
                s
            }
            None => {
                self.slots.push(Some(stats));
                self.slots.len() - 1
            }
        }
    }

    /// Add datum (row `r` of `data`) to the cluster in `slot`.
    pub fn add_row<'a>(&mut self, slot: usize, data: impl Into<DataRef<'a>>, r: usize) {
        self.slots[slot]
            .as_mut()
            .expect("add_row to dead slot")
            .add(data, r);
    }

    /// Remove datum from its cluster, freeing the slot if it empties
    /// (the emptied stats are recycled for later `alloc_empty` calls).
    pub fn remove_row<'a>(&mut self, slot: usize, data: impl Into<DataRef<'a>>, r: usize) {
        let c = self.slots[slot]
            .as_mut()
            .expect("remove_row from dead slot");
        c.remove(data, r);
        if c.is_empty() {
            let stats = self.slots[slot].take().expect("slot just emptied");
            self.recycle(stats);
            self.free.push(slot);
        }
    }

    /// Remove datum WITHOUT freeing an emptied slot (Walker keeps emptied
    /// tables selectable through their stick until the end of the sweep;
    /// call [`Self::compact_free_slots`] afterwards).
    pub fn remove_row_keep_slot<'a>(
        &mut self,
        slot: usize,
        data: impl Into<DataRef<'a>>,
        r: usize,
    ) {
        self.slots[slot]
            .as_mut()
            .expect("remove_row from dead slot")
            .remove(data, r);
    }

    /// Move one datum between two distinct slots: remove it from `from`
    /// (freeing and recycling the slot if it empties, exactly like
    /// [`Self::remove_row`]) and add it to the live slot `to`. This is
    /// the split-side primitive of the split–merge kernel: launch-state
    /// construction, restricted Gibbs scans, and the rejection rollback
    /// are all sequences of `move_row` calls, and because the sufficient
    /// statistics are integer counts a move followed by the reverse move
    /// restores them *bit-exactly* (property-tested in
    /// `rust/tests/property_invariants.rs`).
    ///
    /// ```
    /// use clustercluster::data::BinMat;
    /// use clustercluster::sampler::ClusterSet;
    ///
    /// let data = BinMat::from_dense(2, 3, &[1, 0, 1, 0, 1, 0]);
    /// let mut cs = ClusterSet::new(3);
    /// let a = cs.alloc_empty();
    /// cs.add_row(a, &data, 0);
    /// cs.add_row(a, &data, 1);
    /// let b = cs.alloc_empty();
    /// cs.add_row(b, &data, 0); // anchor so `b` stays live
    /// cs.move_row(a, b, &data, 1);
    /// assert_eq!(cs.n_of(a), 1);
    /// assert_eq!(cs.n_of(b), 2);
    /// cs.check_slot_invariants().unwrap();
    /// ```
    pub fn move_row<'a>(&mut self, from: usize, to: usize, data: impl Into<DataRef<'a>>, r: usize) {
        debug_assert_ne!(from, to, "move_row between distinct slots");
        let data = data.into();
        self.remove_row(from, data, r);
        self.add_row(to, data, r);
    }

    /// Merge the cluster in `from` wholesale into `into`: absorb its
    /// sufficient statistics (integer adds — bit-identical to re-adding
    /// the member rows one by one) and return `from`'s slot to the free
    /// list. The merge-side primitive of the split–merge kernel; callers
    /// retarget the member rows' assignment entries themselves, and —
    /// under the batched scoring dispatch — enqueue both touched packed
    /// columns for refresh.
    ///
    /// # Panics
    ///
    /// Panics if `from == into` or either slot is dead.
    pub fn merge_slots(&mut self, from: usize, into: usize) {
        assert_ne!(from, into, "merge_slots between distinct slots");
        let stats = self.slots[from].take().expect("merge from dead slot");
        self.free.push(from);
        self.slots[into]
            .as_mut()
            .expect("merge into dead slot")
            .absorb(&stats);
    }

    /// Free every empty-but-alive slot (end of a Walker sweep).
    pub fn compact_free_slots(&mut self) {
        for s in 0..self.slots.len() {
            let empty = matches!(&self.slots[s], Some(c) if c.is_empty());
            if empty {
                let stats = self.slots[s].take().expect("slot checked live");
                self.recycle(stats);
                self.free.push(s);
            }
        }
    }

    /// Occupied slots in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &ClusterStats)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(s, c)| c.as_ref().map(|c| (s, c)))
    }

    /// Occupied slots in slot order, mutably (cached scoring).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (usize, &mut ClusterStats)> {
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(|(s, c)| c.as_mut().map(|c| (s, c)))
    }

    /// Occupied slot indices in slot order.
    pub fn occupied_slots(&self) -> Vec<usize> {
        self.iter().map(|(s, _)| s).collect()
    }

    /// Collapsed predictive log-likelihood of row `r` under `slot`
    /// (empty-but-alive clusters score as fresh tables).
    pub fn score_slot<'a>(
        &mut self,
        slot: usize,
        model: &Model,
        data: impl Into<DataRef<'a>>,
        r: usize,
    ) -> f64 {
        self.slots[slot]
            .as_mut()
            .expect("score_slot on dead slot")
            .score(model, data, r)
    }

    /// Refresh the stale columns of the packed `[D, J]` predictive
    /// tables from each live cluster's cached table — the export the
    /// batched sweep dispatch scores through. The stale *queue* is
    /// drained (dead slots are skipped: their columns are never read
    /// until re-allocated, which re-enqueues them), so the cost is
    /// O(D) per column that actually changed since the last dispatch —
    /// zero for the self-move common case — with no per-datum scan over
    /// the slot vector.
    ///
    /// `defer` names the held-out cluster of the datum being scored: its
    /// stats are transiently decremented, so re-packing it NOW would
    /// bake the held-out table into the column (and a subsequent
    /// self-move would leave it stale). A deferred slot stays on the
    /// queue untouched — its (unused) column is refreshed on the next
    /// dispatch, when the stats are settled again.
    pub(crate) fn refresh_packed(
        &mut self,
        model: &Model,
        tables: &mut PackedTables,
        defer: Option<usize>,
    ) {
        tables.ensure_stride(self.slots.len());
        let stride = tables.stride;
        let mut deferred: Option<u32> = None;
        while let Some(slot) = tables.stale.pop() {
            let s = slot as usize;
            if Some(s) == defer {
                // at most one queue entry per slot: stash and re-queue
                deferred = Some(slot);
                continue;
            }
            tables.queued[s] = false;
            let c = match self.slots.get_mut(s) {
                Some(Some(c)) => c,
                _ => continue, // dead slot: never read until reused
            };
            let ln_n = c.log_n();
            let (bias, aux, dtab) = c.cached_table(model);
            tables.bias[s] = bias;
            tables.aux[s] = aux;
            tables.logn[s] = ln_n;
            for (dd, &v) in dtab.iter().enumerate() {
                tables.diff[dd * stride + s] = v;
            }
        }
        if let Some(slot) = deferred {
            tables.stale.push(slot); // queued flag is still set
        }
    }

    /// Append each live cluster's predictive log-weight column
    /// (`ln p̂1`, `ln p̂0`) and log mixture mass `ln(n_j / denom)` into
    /// the packed row-major `[D, stride]` matrices starting at column
    /// `col` — the f32 `[D, J]` layout the Scorer contract defines.
    /// Returns the next free column.
    #[allow(clippy::too_many_arguments)] // mirrors the Scorer weight ABI
    pub fn export_weight_columns(
        &self,
        model: &BetaBernoulli,
        denom: f64,
        w1: &mut [f32],
        w0: &mut [f32],
        logpi: &mut [f32],
        stride: usize,
        mut col: usize,
    ) -> usize {
        assert_eq!(w1.len(), self.dims * stride);
        assert_eq!(w0.len(), self.dims * stride);
        assert_eq!(logpi.len(), stride);
        let mut p1 = vec![0.0f32; self.dims];
        for (_, c) in self.iter() {
            c.predictive_p1(model, &mut p1);
            for dd in 0..self.dims {
                w1[dd * stride + col] = p1[dd].ln();
                w0[dd * stride + col] = (1.0 - p1[dd]).ln();
            }
            logpi[col] = ((c.n() as f64 / denom).ln()) as f32;
            col += 1;
        }
        col
    }

    /// Push `(n_j, c_jd)` for every live cluster into `out` (reduce-step
    /// sufficient statistics for dimension `d`).
    pub fn collect_dim_stats(&self, d: usize, out: &mut Vec<(u64, u32)>) {
        for (_, c) in self.iter() {
            out.push((c.n(), c.ones()[d]));
        }
    }

    /// Invalidate every cluster's predictive cache (hypers changed).
    pub fn invalidate_caches(&mut self) {
        for (_, c) in self.iter_mut() {
            c.invalidate_cache();
        }
    }

    /// Take the raw slot vector, leaving this store empty (shuffle drain).
    pub(crate) fn take_all(&mut self) -> Vec<Option<ClusterStats>> {
        self.free.clear();
        std::mem::take(&mut self.slots)
    }

    /// Verify the slot/free-list bookkeeping invariants.
    pub fn check_slot_invariants(&self) -> Result<(), String> {
        let mut on_free = vec![0usize; self.slots.len()];
        for &s in &self.free {
            if s >= self.slots.len() {
                return Err(format!("free-list entry {s} out of range"));
            }
            on_free[s] += 1;
        }
        for (s, c) in self.slots.iter().enumerate() {
            match c {
                None if on_free[s] != 1 => {
                    return Err(format!(
                        "dead slot {s} appears {} times on the free list",
                        on_free[s]
                    ));
                }
                Some(_) if on_free[s] != 0 => {
                    return Err(format!("live slot {s} is on the free list"));
                }
                Some(c) if c.is_empty() => {
                    return Err(format!("slot {s} empty but not freed"));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::BinMat;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> BinMat {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.4 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    #[test]
    fn alloc_reuses_freed_slots() {
        let data = rand_data(4, 8, 1);
        let mut cs = ClusterSet::new(8);
        let a = cs.alloc_empty();
        cs.add_row(a, &data, 0);
        let b = cs.alloc_empty();
        cs.add_row(b, &data, 1);
        assert_eq!(cs.num_slots(), 2);
        cs.remove_row(a, &data, 0);
        assert_eq!(cs.num_active(), 1);
        assert_eq!(cs.num_free(), 1);
        let c = cs.alloc_empty();
        assert_eq!(c, a, "freed slot must be reused before growing");
        cs.add_row(c, &data, 2);
        assert_eq!(cs.num_slots(), 2);
        cs.check_slot_invariants().unwrap();
    }

    #[test]
    fn keep_slot_then_compact_frees_empties() {
        let data = rand_data(3, 8, 2);
        let mut cs = ClusterSet::new(8);
        let a = cs.alloc_empty();
        cs.add_row(a, &data, 0);
        cs.remove_row_keep_slot(a, &data, 0);
        // transiently empty-but-alive: slot invariant deliberately broken
        assert!(cs.check_slot_invariants().is_err());
        assert_eq!(cs.n_of(a), 0);
        cs.compact_free_slots();
        cs.check_slot_invariants().unwrap();
        assert_eq!(cs.num_active(), 0);
        assert_eq!(cs.num_free(), 1);
    }

    #[test]
    fn iter_orders_by_slot_and_skips_dead() {
        let data = rand_data(6, 8, 3);
        let mut cs = ClusterSet::new(8);
        for r in 0..3 {
            let s = cs.alloc_empty();
            cs.add_row(s, &data, r);
        }
        cs.remove_row(1, &data, 1);
        let slots: Vec<usize> = cs.iter().map(|(s, _)| s).collect();
        assert_eq!(slots, vec![0, 2]);
        assert_eq!(cs.occupied_slots(), vec![0, 2]);
    }

    #[test]
    fn recycled_stats_come_back_clean() {
        let data = rand_data(4, 8, 5);
        let mut cs = ClusterSet::new(8);
        let a = cs.alloc_empty();
        cs.add_row(a, &data, 0);
        cs.remove_row(a, &data, 0); // empties → stats parked for reuse
        let b = cs.alloc_empty(); // must come back as a clean empty
        assert_eq!(b, a, "freed slot reused");
        assert_eq!(cs.n_of(b), 0);
        cs.add_row(b, &data, 1);
        let mut fresh = crate::model::ClusterStats::empty(8);
        fresh.add(&data, 1);
        let got = cs.get(b).unwrap();
        assert_eq!(got.n(), fresh.n());
        assert_eq!(got.ones(), fresh.ones());
        cs.check_slot_invariants().unwrap();
    }

    #[test]
    fn move_row_roundtrip_is_bit_exact_and_frees_emptied_source() {
        let data = rand_data(6, 8, 6);
        let mut cs = ClusterSet::new(8);
        let a = cs.alloc_empty();
        for r in 0..4 {
            cs.add_row(a, &data, r);
        }
        let b = cs.alloc_empty();
        cs.add_row(b, &data, 4);
        let snap_n = cs.get(a).unwrap().n();
        let snap_ones = cs.get(a).unwrap().ones().to_vec();
        // move out and back: integer stats restore exactly
        cs.move_row(a, b, &data, 2);
        assert_eq!(cs.n_of(a), 3);
        assert_eq!(cs.n_of(b), 2);
        cs.move_row(b, a, &data, 2);
        assert_eq!(cs.get(a).unwrap().n(), snap_n);
        assert_eq!(cs.get(a).unwrap().ones(), &snap_ones[..]);
        cs.check_slot_invariants().unwrap();
        // draining a slot through move_row frees it like remove_row does
        cs.move_row(b, a, &data, 4);
        assert!(cs.get(b).is_none());
        assert_eq!(cs.num_free(), 1);
        cs.check_slot_invariants().unwrap();
    }

    #[test]
    fn merge_slots_equals_adding_all_rows_and_frees_source() {
        let data = rand_data(7, 8, 7);
        let mut cs = ClusterSet::new(8);
        let a = cs.alloc_empty();
        for r in 0..3 {
            cs.add_row(a, &data, r);
        }
        let b = cs.alloc_empty();
        for r in 3..7 {
            cs.add_row(b, &data, r);
        }
        cs.merge_slots(a, b);
        assert!(cs.get(a).is_none());
        assert_eq!(cs.num_active(), 1);
        assert_eq!(cs.num_free(), 1);
        cs.check_slot_invariants().unwrap();
        let mut all = crate::model::ClusterStats::empty(8);
        for r in 0..7 {
            all.add(&data, r);
        }
        let got = cs.get(b).unwrap();
        assert_eq!(got.n(), all.n());
        assert_eq!(got.ones(), all.ones());
        // the freed slot is reused before the store grows
        let c = cs.alloc_empty();
        assert_eq!(c, a);
        cs.add_row(c, &data, 0);
        cs.check_slot_invariants().unwrap();
    }

    #[test]
    fn take_all_empties_the_store() {
        let data = rand_data(2, 8, 4);
        let mut cs = ClusterSet::new(8);
        let s = cs.alloc_empty();
        cs.add_row(s, &data, 0);
        let slots = cs.take_all();
        assert_eq!(slots.len(), 1);
        assert_eq!(cs.num_slots(), 0);
        assert_eq!(cs.num_free(), 0);
        cs.check_slot_invariants().unwrap();
    }
}
