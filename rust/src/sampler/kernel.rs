//! The pluggable per-shard transition operators.
//!
//! The paper's §4 point — and the architectural point of Williamson et
//! al. (arXiv:1211.7120) and Dinari et al. (arXiv:2204.08988) — is that
//! *any* standard DPM transition operator applies unmodified inside a
//! supercluster, because each supercluster is a conditionally
//! independent `DP(αμ_k, H)`. [`TransitionKernel`] is that contract: a
//! kernel sees one [`Shard`] (rows + assignments + private RNG +
//! concentration θ) and leaves the shard's local DPM posterior
//! invariant. The serial chain (one shard, θ = α) and the parallel
//! coordinator (one shard per supercluster, θ = αμ_k) both dispatch
//! through it, so a kernel written once runs from both entry points.
//!
//! Implementations:
//!
//! * [`CollapsedGibbs`] — Neal (2000) Algorithm 3. Per datum: remove
//!   from its cluster, score every extant cluster (`n_j · p(x|stats_j)`
//!   in log space) and a fresh one (`θ · p(x|∅)`), sample, reinsert.
//! * [`WalkerSlice`] — Walker (2007) slice sampling (slice-efficient
//!   variant, coin weights kept collapsed). One sweep:
//!   1. impute explicit weights from the **posterior DP** (Ferguson):
//!      the occupied-atom masses plus the continuous remainder are
//!      jointly `(w_1..w_J, w_rest) ~ Dirichlet(n_1..n_J, θ)`, realized
//!      by stick-breaking `v_j ~ Beta(n_j, θ + Σ_{l>j} n_l)` in
//!      appearance-order labeling (note: NOT the blocked-Gibbs
//!      `Beta(1+n_j, ·)`, which is only correct with persistent stick
//!      labels — the enumeration gate caught that variant at TV ≈ 0.18);
//!   2. per datum, a slice `u_i ~ U(0, π_{z_i})`;
//!   3. break the remainder with empty sticks `v ~ Beta(1, θ)` until the
//!      leftover mass is below `min_i u_i` (finite truncation, exact);
//!   4. Gibbs each `z_i` over the *eligible* set `{j : π_j > u_i}` with
//!      collapsed predictive weights (likelihood only — π enters through
//!      eligibility, not the weights). Sticks/slices are discarded after
//!      the sweep (auxiliary variables).
//!
//! Both kernels score a datum's candidate clusters through the shard's
//! [`crate::sampler::ScoreMode`] dispatch: the scalar per-cluster
//! reference path, or one batched
//! [`crate::runtime::Scorer::score_ones_against_clusters`] call over the
//! shard's packed predictive tables (bit-identical by construction —
//! see `rust/src/sampler/score.rs` and DESIGN.md §7). Table maintenance
//! is *move-only*: the kernels invalidate a packed column only when a
//! datum actually changes cluster (plus the one held-out correction per
//! datum), so the self-move common case does zero table work. Neither
//! kernel allocates after warm-up: Gibbs runs on the shard's scratch
//! buffers, Walker on the persistent [`WalkerScratch`].
//!
//! Exactness of both kernels — through both entry points — is certified
//! by the posterior-enumeration gate in `rust/tests/posterior_exactness.rs`.

use super::shard::Shard;
use crate::data::BinMat;
use crate::model::BetaBernoulli;
use crate::rng::{beta as beta_draw, categorical_log_inplace};

/// A per-shard DPM transition operator: one sweep must leave the shard's
/// local `DP(θ, H)` mixture posterior invariant. Kernels are stateless
/// (all chain state lives in the [`Shard`]), hence shareable across the
/// coordinator's worker threads.
pub trait TransitionKernel: Send + Sync {
    /// Implementation name for logs/CLI.
    fn name(&self) -> &'static str;

    /// One full sweep over the shard's resident rows, driven by the
    /// shard's private RNG stream and concentration θ.
    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli);
}

/// Neal (2000) Algorithm 3: collapsed Gibbs.
pub struct CollapsedGibbs;

impl TransitionKernel for CollapsedGibbs {
    fn name(&self) -> &'static str {
        "collapsed-gibbs"
    }

    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli) {
        let log_theta = shard.theta.max(1e-300).ln();
        let empty_ll = model.empty_cluster_loglik();
        shard.scoring_begin_sweep();
        let eager = shard.scoring_eager();
        for i in 0..shard.rows.len() {
            let r = shard.rows[i];
            let old = shard.assign[i] as usize;
            shard.clusters.remove_row(old, data, r);
            // the cluster the datum left (if it survived): scored from
            // its decremented cache, while its packed column keeps the
            // full-membership table in case the datum moves back
            let held = if shard.clusters.get(old).is_some() {
                Some(old)
            } else {
                None
            };
            // score the whole candidate set through the shard's scoring
            // dispatch (scalar reference, or one batched Scorer call)
            shard.score_crp_candidates(data, r, model, held);
            shard.scratch_ids.push(u32::MAX);
            shard.scratch_logw.push(log_theta + empty_ll);
            let pick = categorical_log_inplace(&mut shard.rng, &mut shard.scratch_logw);
            let slot = shard.place_pick(pick, data, r) as usize;
            // self-move (the stationary common case): stats are restored
            // exactly, the packed tables need zero work. Only a real
            // move — or a re-allocated slot after the old cluster died —
            // stales the two touched columns.
            if slot != old || held.is_none() || eager {
                shard.scoring_invalidate(old);
                shard.scoring_invalidate(slot);
            }
            shard.assign[i] = slot as u32;
        }
    }
}

/// Persistent per-sweep state of the Walker kernel, owned by the shard
/// (`Shard::walker`) so repeated sweeps are allocation-free after
/// warm-up: stick weights/slots, the slice variables, per-datum
/// candidate buffers, and the appearance-order scratch.
#[derive(Debug, Default)]
pub(crate) struct WalkerScratch {
    /// stick weights π, occupied (appearance order) then empty
    pub(crate) stick_pi: Vec<f64>,
    /// cluster slot per stick (`usize::MAX` = still unmaterialized)
    pub(crate) stick_slot: Vec<usize>,
    /// slot → stick index (`usize::MAX` = no stick)
    pub(crate) slot_to_stick: Vec<usize>,
    /// per-datum slice variables u_i
    pub(crate) u: Vec<f64>,
    /// eligible stick indices of the current datum
    pub(crate) cand: Vec<usize>,
    /// eligible cluster slots (`u32::MAX` = unmaterialized stick)
    pub(crate) cand_slots: Vec<u32>,
    /// candidate log-weights of the current datum
    pub(crate) logw: Vec<f64>,
    /// occupied-stick member counts (appearance order)
    pub(crate) counts: Vec<u64>,
    /// suffix sums Σ_{l>j} n_l over `counts`
    pub(crate) tail: Vec<u64>,
    /// occupied slots in appearance order
    pub(crate) appear: Vec<usize>,
    /// appearance-order dedup scratch
    pub(crate) seen: Vec<bool>,
}

/// Walker (2007) slice sampling (slice-efficient, collapsed coins).
///
/// The stick-extension loop (step 3) runs under an explicit θ-scaled
/// budget of `10_000 + 700·θ` empty sticks (capped at 1e6): the
/// leftover mass decays like `exp(−sticks/θ)` (each `v ~ Beta(1, θ)`
/// removes a `1/θ` fraction in expectation, so large θ shrinks it
/// *slowly*), and `700·θ` covers every representable slice
/// (`ln 1e-300 ≈ −690`). Exhausting the budget is an explicit error
/// path — logged and counted on the shard
/// (`Shard::stick_overflow_events`), never a silent truncation.
pub struct WalkerSlice;

impl TransitionKernel for WalkerSlice {
    fn name(&self) -> &'static str {
        "walker-slice"
    }

    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli) {
        let theta = shard.theta.max(1e-12);
        if shard.rows.is_empty() {
            return;
        }
        // the scratch moves out for the sweep so the shard's scoring
        // methods can be called while it is borrowed; it returns (with
        // its capacities) at the end
        let mut scratch = std::mem::take(&mut shard.walker);

        // ---- 1. sticks for occupied clusters in APPEARANCE order ----
        // Given the partition of an exchangeable DP sample, the posterior
        // of the stick weights in order-of-appearance labeling is
        // v_j ~ Beta(n_j, θ + Σ_{l>j} n_l) independently (Pitman's
        // size-biased representation). An arbitrary fixed order is NOT a
        // draw from p(labels | z) and biases the chain.
        shard.slots_by_appearance_into(&mut scratch.seen, &mut scratch.appear);
        scratch.counts.clear();
        for &s in &scratch.appear {
            scratch.counts.push(shard.clusters.n_of(s));
        }
        let nst = scratch.appear.len();
        scratch.tail.clear();
        scratch.tail.resize(nst, 0);
        let mut acc = 0u64;
        for i in (0..nst).rev() {
            scratch.tail[i] = acc;
            acc += scratch.counts[i];
        }
        scratch.stick_pi.clear();
        scratch.stick_slot.clear();
        let mut remaining = 1.0f64;
        for i in 0..nst {
            let v = beta_draw(
                &mut shard.rng,
                scratch.counts[i] as f64,
                theta + scratch.tail[i] as f64,
            );
            scratch.stick_pi.push(remaining * v);
            scratch.stick_slot.push(scratch.appear[i]);
            remaining *= 1.0 - v;
        }

        // ---- 2. slice per datum: u_i ~ U(0, π_{z_i}) ----
        let n = shard.rows.len();
        scratch.slot_to_stick.clear();
        scratch.slot_to_stick.resize(shard.clusters.num_slots(), usize::MAX);
        for (idx, &s) in scratch.stick_slot.iter().enumerate() {
            scratch.slot_to_stick[s] = idx;
        }
        scratch.u.clear();
        scratch.u.reserve(n);
        let mut u_min = f64::INFINITY;
        for i in 0..n {
            let zi = shard.assign[i] as usize;
            let pz = scratch.stick_pi[scratch.slot_to_stick[zi]].max(1e-300);
            let ui = shard.rng.next_f64_open() * pz;
            scratch.u.push(ui);
            if ui < u_min {
                u_min = ui;
            }
        }

        // ---- 3. extend with empty sticks v ~ Beta(1, θ) until the
        //         leftover mass cannot contain any slice, under the
        //         θ-scaled budget (see the type-level docs) ----
        let max_sticks = (10_000.0 + 700.0 * theta).min(1_000_000.0) as usize;
        let mut extended = 0usize;
        while remaining > u_min {
            if extended >= max_sticks {
                shard.note_stick_overflow(theta, remaining, u_min, extended);
                break;
            }
            let v = beta_draw(&mut shard.rng, 1.0, theta);
            scratch.stick_pi.push(remaining * v);
            scratch.stick_slot.push(usize::MAX);
            remaining *= 1.0 - v;
            extended += 1;
        }

        // ---- 4. Gibbs each datum over its eligible sticks ----
        // weights: collapsed predictive (likelihood only — π enters via
        // eligibility). Emptied clusters keep their stick and score as
        // empty tables; picking an unmaterialized stick creates its
        // cluster, which later data in the same sweep can then join.
        let empty_loglik = model.empty_cluster_loglik();
        shard.scoring_begin_sweep();
        let eager = shard.scoring_eager();
        for i in 0..n {
            let r = shard.rows[i];
            let old_slot = shard.assign[i] as usize;
            let old_stick = scratch.slot_to_stick[old_slot];
            shard.clusters.remove_row_keep_slot(old_slot, data, r);

            // collect the eligible sticks, then score them through the
            // shard's dispatch (one batched block per datum); the old
            // cluster keeps its slot, so it is always the held-out one
            scratch.cand.clear();
            scratch.cand_slots.clear();
            for idx in 0..scratch.stick_pi.len() {
                if scratch.stick_pi[idx] > scratch.u[i] {
                    scratch.cand.push(idx);
                    scratch.cand_slots.push(match scratch.stick_slot[idx] {
                        usize::MAX => u32::MAX,
                        s => s as u32,
                    });
                }
            }
            scratch.logw.clear();
            shard.score_slots_for_row(
                data,
                r,
                model,
                &scratch.cand_slots,
                empty_loglik,
                Some(old_slot),
                &mut scratch.logw,
            );
            // float-tail guard: the datum's own stick is eligible by
            // construction, but keep a fallback anyway
            if scratch.cand.is_empty() {
                scratch.cand.push(old_stick);
                scratch.logw.push(0.0);
            }
            let ci = categorical_log_inplace(&mut shard.rng, &mut scratch.logw);
            let pick = scratch.cand[ci];
            match scratch.stick_slot[pick] {
                usize::MAX => {
                    let s = shard.clusters.alloc_empty();
                    shard.clusters.add_row(s, data, r);
                    shard.scoring_invalidate(old_slot);
                    shard.scoring_invalidate(s);
                    shard.assign[i] = s as u32;
                    scratch.stick_slot[pick] = s;
                    if scratch.slot_to_stick.len() <= s {
                        scratch.slot_to_stick.resize(s + 1, usize::MAX);
                    }
                    scratch.slot_to_stick[s] = pick;
                }
                s => {
                    shard.clusters.add_row(s, data, r);
                    // move-only maintenance: a self-move restores the
                    // stats exactly and needs no table work
                    if s != old_slot || eager {
                        shard.scoring_invalidate(old_slot);
                        shard.scoring_invalidate(s);
                    }
                    shard.assign[i] = s as u32;
                }
            }
        }
        shard.clusters.compact_free_slots();
        // a pathological sweep (huge θ) can grow the stick buffers — and
        // the per-datum candidate buffers, whose eligible sets span the
        // same stick range — into the hundreds of thousands; don't pin
        // that memory forever
        const SCRATCH_CAP: usize = 1 << 17;
        if scratch.stick_pi.capacity() > SCRATCH_CAP {
            scratch.stick_pi.shrink_to(SCRATCH_CAP);
            scratch.stick_slot.shrink_to(SCRATCH_CAP);
        }
        if scratch.cand.capacity() > SCRATCH_CAP {
            scratch.cand.shrink_to(SCRATCH_CAP);
            scratch.cand_slots.shrink_to(SCRATCH_CAP);
            scratch.logw.shrink_to(SCRATCH_CAP);
        }
        shard.walker = scratch;
    }
}

/// CLI/config-level kernel selector, resolvable to the shared static
/// kernel instances. This is what `--local-kernel` parses into from both
/// the serial and the parallel entry points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Neal (2000) Algorithm 3 collapsed Gibbs (default).
    #[default]
    CollapsedGibbs,
    /// Walker (2007) slice sampling (slice-efficient, collapsed coins).
    WalkerSlice,
}

impl KernelKind {
    /// The shared kernel instance this selector names.
    pub fn kernel(self) -> &'static dyn TransitionKernel {
        match self {
            KernelKind::CollapsedGibbs => &CollapsedGibbs,
            KernelKind::WalkerSlice => &WalkerSlice,
        }
    }

    /// Display name of the kernel this selector names.
    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    /// Parse a `--local-kernel` value.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "gibbs" | "collapsed" | "collapsed-gibbs" | "neal" => Ok(KernelKind::CollapsedGibbs),
            "walker" | "slice" | "walker-slice" => Ok(KernelKind::WalkerSlice),
            other => Err(format!(
                "unknown kernel {other:?} (expected \"gibbs\" or \"walker\")"
            )),
        }
    }
}

/// How transition kernels are assigned to the coordinator's shards
/// (paper §4 / Williamson et al.: each supercluster is an independent
/// `DP(αμ_k, H)`, so *different* standard DPM operators may run on
/// different superclusters within one chain without affecting
/// exactness). This is the config-level selector behind
/// `--local-kernel gibbs,walker,…` on the CLI; the coordinator resolves
/// it to one [`KernelKind`] per shard at construction via
/// [`KernelAssignment::resolve`].
///
/// ```
/// use clustercluster::sampler::{KernelAssignment, KernelKind};
///
/// // one kernel everywhere (the default)
/// let all = KernelAssignment::AllSame(KernelKind::CollapsedGibbs);
/// assert_eq!(all.resolve(3).unwrap(), vec![KernelKind::CollapsedGibbs; 3]);
///
/// // `--local-kernel gibbs,walker` cycles the list over the shards
/// let mixed = KernelAssignment::parse("gibbs,walker").unwrap();
/// assert_eq!(
///     mixed.resolve(3).unwrap(),
///     vec![
///         KernelKind::CollapsedGibbs,
///         KernelKind::WalkerSlice,
///         KernelKind::CollapsedGibbs,
///     ],
/// );
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelAssignment {
    /// Every shard runs the same kernel.
    AllSame(KernelKind),
    /// Explicit kernel per shard; the vector length must equal the
    /// worker count (checked by [`KernelAssignment::resolve`]).
    PerShard(Vec<KernelKind>),
    /// Cycle a non-empty kernel list over the shards in order — what a
    /// comma-separated `--local-kernel` value parses into.
    RoundRobin(Vec<KernelKind>),
}

impl Default for KernelAssignment {
    fn default() -> Self {
        KernelAssignment::AllSame(KernelKind::default())
    }
}

impl KernelAssignment {
    /// Resolve to one kernel selector per shard, validating shape.
    pub fn resolve(&self, workers: usize) -> Result<Vec<KernelKind>, String> {
        match self {
            KernelAssignment::AllSame(k) => Ok(vec![*k; workers]),
            KernelAssignment::PerShard(v) => {
                if v.len() == workers {
                    Ok(v.clone())
                } else {
                    Err(format!(
                        "per-shard kernel list has {} entries for {} workers",
                        v.len(),
                        workers
                    ))
                }
            }
            KernelAssignment::RoundRobin(v) => {
                if v.is_empty() {
                    Err("round-robin kernel list is empty".into())
                } else {
                    Ok((0..workers).map(|i| v[i % v.len()]).collect())
                }
            }
        }
    }

    /// Parse a `--local-kernel` value: a single kernel name maps to
    /// [`KernelAssignment::AllSame`], a comma-separated list to
    /// [`KernelAssignment::RoundRobin`] over the shards.
    pub fn parse(s: &str) -> Result<KernelAssignment, String> {
        let kinds: Result<Vec<KernelKind>, String> =
            s.split(',').map(|tok| KernelKind::parse(tok.trim())).collect();
        let kinds = kinds?;
        match kinds.as_slice() {
            [] => Err("empty kernel list".into()),
            [one] => Ok(KernelAssignment::AllSame(*one)),
            _ => Ok(KernelAssignment::RoundRobin(kinds)),
        }
    }

    /// Human-readable description for run banners and logs.
    pub fn describe(&self) -> String {
        match self {
            KernelAssignment::AllSame(k) => k.name().to_string(),
            KernelAssignment::PerShard(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("per-shard[{}]", names.join(","))
            }
            KernelAssignment::RoundRobin(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("round-robin[{}]", names.join(","))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::rng::Pcg64;

    #[test]
    fn assignment_parses_and_resolves() {
        assert_eq!(
            KernelAssignment::parse("gibbs").unwrap(),
            KernelAssignment::AllSame(KernelKind::CollapsedGibbs)
        );
        let mixed = KernelAssignment::parse(" gibbs , walker ").unwrap();
        assert_eq!(
            mixed,
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
        );
        assert_eq!(
            mixed.resolve(5).unwrap(),
            vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
            ]
        );
        assert!(KernelAssignment::parse("gibbs,metropolis").is_err());
        assert!(KernelAssignment::PerShard(vec![KernelKind::WalkerSlice])
            .resolve(2)
            .is_err());
        assert!(KernelAssignment::RoundRobin(Vec::new()).resolve(2).is_err());
        assert_eq!(
            KernelAssignment::default().resolve(2).unwrap(),
            vec![KernelKind::CollapsedGibbs; 2]
        );
    }

    #[test]
    fn assignment_describe_names_every_variant() {
        assert_eq!(
            KernelAssignment::AllSame(KernelKind::WalkerSlice).describe(),
            "walker-slice"
        );
        assert_eq!(
            KernelAssignment::PerShard(vec![KernelKind::CollapsedGibbs]).describe(),
            "per-shard[collapsed-gibbs]"
        );
        assert_eq!(
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
            .describe(),
            "round-robin[collapsed-gibbs,walker-slice]"
        );
    }

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(KernelKind::parse("gibbs").unwrap(), KernelKind::CollapsedGibbs);
        assert_eq!(KernelKind::parse("Walker").unwrap(), KernelKind::WalkerSlice);
        assert!(KernelKind::parse("metropolis").is_err());
        assert_eq!(KernelKind::CollapsedGibbs.name(), "collapsed-gibbs");
        assert_eq!(KernelKind::WalkerSlice.name(), "walker-slice");
    }

    #[test]
    fn walker_sweep_preserves_invariants() {
        let ds = SyntheticConfig {
            n: 300,
            d: 16,
            clusters: 4,
            beta: 0.15,
            seed: 3,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(16, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(1));
        for _ in 0..5 {
            WalkerSlice.sweep(&mut st, &ds.train, &model);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 300);
    }

    #[test]
    fn walker_finds_structure() {
        let ds = SyntheticConfig {
            n: 400,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 4.0, Pcg64::seed_from(5));
        for _ in 0..30 {
            WalkerSlice.sweep(&mut st, &ds.train, &model);
        }
        let j = st.num_clusters();
        assert!((2..=16).contains(&j), "Walker found {j} clusters, expected ~4");
    }

    /// Regression for the old silent `guard < 10_000` cutoff: at large θ
    /// the leftover stick mass shrinks *slowly* (each empty stick
    /// removes only a ~1/θ fraction in expectation), so covering the
    /// smallest slice needs ≈ θ·ln(1/u_min) sticks — far past the old
    /// cutoff, which silently truncated the eligible sets. The θ-scaled
    /// budget must complete the extension without an overflow event.
    #[test]
    fn walker_slow_shrink_regime_completes_without_overflow() {
        let ds = SyntheticConfig {
            n: 40,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 11,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(12));
        st.set_theta(20_000.0);
        WalkerSlice.sweep(&mut st, &ds.train, &model);
        assert_eq!(
            st.stick_overflow_events(),
            0,
            "θ-scaled budget must cover the slow-shrink regime"
        );
        // the sweep really needed more sticks than the old silent cutoff
        assert!(
            st.walker.stick_pi.len() > 10_000,
            "expected > 10k sticks at θ=2e4, got {} (regime not exercised)",
            st.walker.stick_pi.len()
        );
        st.check_invariants(&ds.train).unwrap();
    }

    /// At absurd θ even the capped budget cannot drain the leftover
    /// mass: the sweep must hit the explicit error path (logged +
    /// counted), not loop forever or truncate silently, and the chain
    /// state must remain valid.
    #[test]
    fn walker_stick_budget_exhaustion_is_counted() {
        let ds = SyntheticConfig {
            n: 6,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 13,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(14));
        st.set_theta(1.0e12);
        WalkerSlice.sweep(&mut st, &ds.train, &model);
        assert!(
            st.stick_overflow_events() > 0,
            "budget exhaustion must be recorded, not silent"
        );
        st.check_invariants(&ds.train).unwrap();
        assert_eq!(st.num_rows(), 6);
    }

    #[test]
    fn kernels_handle_empty_shard() {
        let ds = SyntheticConfig {
            n: 10,
            d: 8,
            clusters: 2,
            beta: 0.5,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let model = BetaBernoulli::symmetric(8, 0.5);
        let mut st = Shard::init_from_prior(&ds.train, Vec::new(), 0.5, Pcg64::seed_from(7));
        WalkerSlice.sweep(&mut st, &ds.train, &model);
        CollapsedGibbs.sweep(&mut st, &ds.train, &model);
        assert_eq!(st.num_rows(), 0);
    }

    #[test]
    fn both_kernels_run_through_the_trait_object() {
        let ds = SyntheticConfig {
            n: 120,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 8,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        for kind in [KernelKind::CollapsedGibbs, KernelKind::WalkerSlice] {
            let rows: Vec<usize> = (0..ds.train.rows()).collect();
            let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(9));
            let kernel = kind.kernel();
            for _ in 0..3 {
                kernel.sweep(&mut st, &ds.train, &model);
                st.check_invariants(&ds.train).unwrap();
            }
            assert_eq!(st.num_rows(), ds.train.rows());
        }
    }
}
