//! The pluggable per-shard transition operators.
//!
//! The paper's §4 point — and the architectural point of Williamson et
//! al. (arXiv:1211.7120) and Dinari et al. (arXiv:2204.08988) — is that
//! *any* standard DPM transition operator applies unmodified inside a
//! supercluster, because each supercluster is a conditionally
//! independent `DP(αμ_k, H)`. [`TransitionKernel`] is that contract: a
//! kernel sees one [`Shard`] (rows + assignments + private RNG +
//! concentration θ) and leaves the shard's local DPM posterior
//! invariant. The serial chain (one shard, θ = α) and the parallel
//! coordinator (one shard per supercluster, θ = αμ_k) both dispatch
//! through it, so a kernel written once runs from both entry points.
//!
//! Implementations:
//!
//! * [`CollapsedGibbs`] — Neal (2000) Algorithm 3. Per datum: remove
//!   from its cluster, score every extant cluster (`n_j · p(x|stats_j)`
//!   in log space) and a fresh one (`θ · p(x|∅)`), sample, reinsert.
//! * [`WalkerSlice`] — Walker (2007) slice sampling (slice-efficient
//!   variant, coin weights kept collapsed). One sweep:
//!   1. impute explicit weights from the **posterior DP** (Ferguson):
//!      the occupied-atom masses plus the continuous remainder are
//!      jointly `(w_1..w_J, w_rest) ~ Dirichlet(n_1..n_J, θ)`, realized
//!      by stick-breaking `v_j ~ Beta(n_j, θ + Σ_{l>j} n_l)` in
//!      appearance-order labeling (note: NOT the blocked-Gibbs
//!      `Beta(1+n_j, ·)`, which is only correct with persistent stick
//!      labels — the enumeration gate caught that variant at TV ≈ 0.18);
//!   2. per datum, a slice `u_i ~ U(0, π_{z_i})`;
//!   3. break the remainder with empty sticks `v ~ Beta(1, θ)` until the
//!      leftover mass is below `min_i u_i` (finite truncation, exact);
//!   4. Gibbs each `z_i` over the *eligible* set `{j : π_j > u_i}` with
//!      collapsed predictive weights (likelihood only — π enters through
//!      eligibility, not the weights). Sticks/slices are discarded after
//!      the sweep (auxiliary variables).
//!
//! Both kernels score a datum's candidate clusters through the shard's
//! [`crate::sampler::ScoreMode`] dispatch: the scalar per-cluster
//! reference path, or one
//! batched [`crate::runtime::Scorer::score_rows_against_clusters`] call
//! over the shard's packed predictive tables (bit-identical by
//! construction — see `rust/src/sampler/score.rs`).
//!
//! Exactness of both kernels — through both entry points — is certified
//! by the posterior-enumeration gate in `rust/tests/posterior_exactness.rs`.

use super::shard::Shard;
use crate::data::BinMat;
use crate::model::BetaBernoulli;
use crate::rng::{beta as beta_draw, categorical_log_inplace};

/// A per-shard DPM transition operator: one sweep must leave the shard's
/// local `DP(θ, H)` mixture posterior invariant. Kernels are stateless
/// (all chain state lives in the [`Shard`]), hence shareable across the
/// coordinator's worker threads.
pub trait TransitionKernel: Send + Sync {
    /// Implementation name for logs/CLI.
    fn name(&self) -> &'static str;

    /// One full sweep over the shard's resident rows, driven by the
    /// shard's private RNG stream and concentration θ.
    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli);
}

/// Neal (2000) Algorithm 3: collapsed Gibbs.
pub struct CollapsedGibbs;

impl TransitionKernel for CollapsedGibbs {
    fn name(&self) -> &'static str {
        "collapsed-gibbs"
    }

    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli) {
        let log_theta = shard.theta.max(1e-300).ln();
        let empty_ll = model.empty_cluster_loglik();
        shard.scoring_begin_sweep();
        for i in 0..shard.rows.len() {
            let r = shard.rows[i];
            let old = shard.assign[i] as usize;
            shard.clusters.remove_row(old, data, r);
            shard.scoring_mark_dirty(old);
            // score the whole candidate set through the shard's scoring
            // dispatch (scalar reference, or one batched Scorer call)
            shard.score_crp_candidates(data, r, model);
            shard.scratch_ids.push(u32::MAX);
            shard.scratch_logw.push(log_theta + empty_ll);
            let pick = categorical_log_inplace(&mut shard.rng, &mut shard.scratch_logw);
            let slot = shard.place_pick(pick, data, r);
            shard.scoring_mark_dirty(slot as usize);
            shard.assign[i] = slot;
        }
    }
}

/// One stick of the truncated representation: its weight and, once
/// materialized, the cluster slot it points at (`None` = still empty).
#[derive(Debug, Clone, Copy)]
struct Stick {
    pi: f64,
    slot: Option<usize>,
}

/// Walker (2007) slice sampling (slice-efficient, collapsed coins).
pub struct WalkerSlice;

impl TransitionKernel for WalkerSlice {
    fn name(&self) -> &'static str {
        "walker-slice"
    }

    fn sweep(&self, shard: &mut Shard, data: &BinMat, model: &BetaBernoulli) {
        let theta = shard.theta.max(1e-12);
        if shard.rows.is_empty() {
            return;
        }

        // ---- 1. sticks for occupied clusters in APPEARANCE order ----
        // Given the partition of an exchangeable DP sample, the posterior
        // of the stick weights in order-of-appearance labeling is
        // v_j ~ Beta(n_j, θ + Σ_{l>j} n_l) independently (Pitman's
        // size-biased representation). An arbitrary fixed order is NOT a
        // draw from p(labels | z) and biases the chain.
        let slots: Vec<usize> = shard.slots_by_appearance();
        let counts: Vec<u64> = slots.iter().map(|&s| shard.clusters.n_of(s)).collect();
        let mut tail: Vec<u64> = vec![0; counts.len()];
        let mut acc = 0u64;
        for i in (0..counts.len()).rev() {
            tail[i] = acc;
            acc += counts[i];
        }
        let mut sticks: Vec<Stick> = Vec::with_capacity(slots.len() + 8);
        let mut remaining = 1.0f64;
        for i in 0..slots.len() {
            let v = beta_draw(&mut shard.rng, counts[i] as f64, theta + tail[i] as f64);
            sticks.push(Stick {
                pi: remaining * v,
                slot: Some(slots[i]),
            });
            remaining *= 1.0 - v;
        }

        // ---- 2. slice per datum: u_i ~ U(0, π_{z_i}) ----
        let n = shard.rows.len();
        let mut slot_to_stick = vec![usize::MAX; shard.clusters.num_slots()];
        for (idx, st) in sticks.iter().enumerate() {
            slot_to_stick[st.slot.unwrap()] = idx;
        }
        let mut u = vec![0.0f64; n];
        let mut u_min = f64::INFINITY;
        for i in 0..n {
            let zi = shard.assign[i] as usize;
            let pz = sticks[slot_to_stick[zi]].pi.max(1e-300);
            u[i] = shard.rng.next_f64_open() * pz;
            if u[i] < u_min {
                u_min = u[i];
            }
        }

        // ---- 3. extend with empty sticks v ~ Beta(1, θ) until the
        //         leftover mass cannot contain any slice ----
        let mut guard = 0;
        while remaining > u_min && guard < 10_000 {
            let v = beta_draw(&mut shard.rng, 1.0, theta);
            sticks.push(Stick {
                pi: remaining * v,
                slot: None,
            });
            remaining *= 1.0 - v;
            guard += 1;
        }

        // ---- 4. Gibbs each datum over its eligible sticks ----
        // weights: collapsed predictive (likelihood only — π enters via
        // eligibility). Emptied clusters keep their stick and score as
        // empty tables; picking an unmaterialized stick creates its
        // cluster, which later data in the same sweep can then join.
        let empty_loglik = model.empty_cluster_loglik();
        let mut cand: Vec<usize> = Vec::new();
        let mut cand_slots: Vec<u32> = Vec::new();
        let mut logw: Vec<f64> = Vec::new();
        shard.scoring_begin_sweep();
        for i in 0..n {
            let r = shard.rows[i];
            let old_slot = shard.assign[i] as usize;
            let old_stick = slot_to_stick[old_slot];
            shard.clusters.remove_row_keep_slot(old_slot, data, r);
            shard.scoring_mark_dirty(old_slot);

            // collect the eligible sticks, then score them through the
            // shard's dispatch (one batched block per datum)
            cand.clear();
            cand_slots.clear();
            for (idx, st) in sticks.iter().enumerate() {
                if st.pi > u[i] {
                    cand.push(idx);
                    cand_slots.push(match st.slot {
                        Some(s) => s as u32,
                        None => u32::MAX,
                    });
                }
            }
            logw.clear();
            shard.score_slots_for_row(data, r, model, &cand_slots, empty_loglik, &mut logw);
            // float-tail guard: the datum's own stick is eligible by
            // construction, but keep a fallback anyway
            if cand.is_empty() {
                cand.push(old_stick);
                logw.push(0.0);
            }
            let pick = cand[categorical_log_inplace(&mut shard.rng, &mut logw)];
            match sticks[pick].slot {
                Some(s) => {
                    shard.clusters.add_row(s, data, r);
                    shard.scoring_mark_dirty(s);
                    shard.assign[i] = s as u32;
                }
                None => {
                    let s = shard.clusters.alloc_empty();
                    shard.clusters.add_row(s, data, r);
                    shard.scoring_mark_dirty(s);
                    shard.assign[i] = s as u32;
                    sticks[pick].slot = Some(s);
                    if slot_to_stick.len() <= s {
                        slot_to_stick.resize(s + 1, usize::MAX);
                    }
                    slot_to_stick[s] = pick;
                }
            }
        }
        shard.clusters.compact_free_slots();
    }
}

/// CLI/config-level kernel selector, resolvable to the shared static
/// kernel instances. This is what `--local-kernel` parses into from both
/// the serial and the parallel entry points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Neal (2000) Algorithm 3 collapsed Gibbs (default).
    #[default]
    CollapsedGibbs,
    /// Walker (2007) slice sampling (slice-efficient, collapsed coins).
    WalkerSlice,
}

impl KernelKind {
    /// The shared kernel instance this selector names.
    pub fn kernel(self) -> &'static dyn TransitionKernel {
        match self {
            KernelKind::CollapsedGibbs => &CollapsedGibbs,
            KernelKind::WalkerSlice => &WalkerSlice,
        }
    }

    /// Display name of the kernel this selector names.
    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    /// Parse a `--local-kernel` value.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "gibbs" | "collapsed" | "collapsed-gibbs" | "neal" => Ok(KernelKind::CollapsedGibbs),
            "walker" | "slice" | "walker-slice" => Ok(KernelKind::WalkerSlice),
            other => Err(format!(
                "unknown kernel {other:?} (expected \"gibbs\" or \"walker\")"
            )),
        }
    }
}

/// How transition kernels are assigned to the coordinator's shards
/// (paper §4 / Williamson et al.: each supercluster is an independent
/// `DP(αμ_k, H)`, so *different* standard DPM operators may run on
/// different superclusters within one chain without affecting
/// exactness). This is the config-level selector behind
/// `--local-kernel gibbs,walker,…` on the CLI; the coordinator resolves
/// it to one [`KernelKind`] per shard at construction via
/// [`KernelAssignment::resolve`].
///
/// ```
/// use clustercluster::sampler::{KernelAssignment, KernelKind};
///
/// // one kernel everywhere (the default)
/// let all = KernelAssignment::AllSame(KernelKind::CollapsedGibbs);
/// assert_eq!(all.resolve(3).unwrap(), vec![KernelKind::CollapsedGibbs; 3]);
///
/// // `--local-kernel gibbs,walker` cycles the list over the shards
/// let mixed = KernelAssignment::parse("gibbs,walker").unwrap();
/// assert_eq!(
///     mixed.resolve(3).unwrap(),
///     vec![
///         KernelKind::CollapsedGibbs,
///         KernelKind::WalkerSlice,
///         KernelKind::CollapsedGibbs,
///     ],
/// );
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelAssignment {
    /// Every shard runs the same kernel.
    AllSame(KernelKind),
    /// Explicit kernel per shard; the vector length must equal the
    /// worker count (checked by [`KernelAssignment::resolve`]).
    PerShard(Vec<KernelKind>),
    /// Cycle a non-empty kernel list over the shards in order — what a
    /// comma-separated `--local-kernel` value parses into.
    RoundRobin(Vec<KernelKind>),
}

impl Default for KernelAssignment {
    fn default() -> Self {
        KernelAssignment::AllSame(KernelKind::default())
    }
}

impl KernelAssignment {
    /// Resolve to one kernel selector per shard, validating shape.
    pub fn resolve(&self, workers: usize) -> Result<Vec<KernelKind>, String> {
        match self {
            KernelAssignment::AllSame(k) => Ok(vec![*k; workers]),
            KernelAssignment::PerShard(v) => {
                if v.len() == workers {
                    Ok(v.clone())
                } else {
                    Err(format!(
                        "per-shard kernel list has {} entries for {} workers",
                        v.len(),
                        workers
                    ))
                }
            }
            KernelAssignment::RoundRobin(v) => {
                if v.is_empty() {
                    Err("round-robin kernel list is empty".into())
                } else {
                    Ok((0..workers).map(|i| v[i % v.len()]).collect())
                }
            }
        }
    }

    /// Parse a `--local-kernel` value: a single kernel name maps to
    /// [`KernelAssignment::AllSame`], a comma-separated list to
    /// [`KernelAssignment::RoundRobin`] over the shards.
    pub fn parse(s: &str) -> Result<KernelAssignment, String> {
        let kinds: Result<Vec<KernelKind>, String> =
            s.split(',').map(|tok| KernelKind::parse(tok.trim())).collect();
        let kinds = kinds?;
        match kinds.as_slice() {
            [] => Err("empty kernel list".into()),
            [one] => Ok(KernelAssignment::AllSame(*one)),
            _ => Ok(KernelAssignment::RoundRobin(kinds)),
        }
    }

    /// Human-readable description for run banners and logs.
    pub fn describe(&self) -> String {
        match self {
            KernelAssignment::AllSame(k) => k.name().to_string(),
            KernelAssignment::PerShard(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("per-shard[{}]", names.join(","))
            }
            KernelAssignment::RoundRobin(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("round-robin[{}]", names.join(","))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::rng::Pcg64;

    #[test]
    fn assignment_parses_and_resolves() {
        assert_eq!(
            KernelAssignment::parse("gibbs").unwrap(),
            KernelAssignment::AllSame(KernelKind::CollapsedGibbs)
        );
        let mixed = KernelAssignment::parse(" gibbs , walker ").unwrap();
        assert_eq!(
            mixed,
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
        );
        assert_eq!(
            mixed.resolve(5).unwrap(),
            vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
            ]
        );
        assert!(KernelAssignment::parse("gibbs,metropolis").is_err());
        assert!(KernelAssignment::PerShard(vec![KernelKind::WalkerSlice])
            .resolve(2)
            .is_err());
        assert!(KernelAssignment::RoundRobin(Vec::new()).resolve(2).is_err());
        assert_eq!(
            KernelAssignment::default().resolve(2).unwrap(),
            vec![KernelKind::CollapsedGibbs; 2]
        );
    }

    #[test]
    fn assignment_describe_names_every_variant() {
        assert_eq!(
            KernelAssignment::AllSame(KernelKind::WalkerSlice).describe(),
            "walker-slice"
        );
        assert_eq!(
            KernelAssignment::PerShard(vec![KernelKind::CollapsedGibbs]).describe(),
            "per-shard[collapsed-gibbs]"
        );
        assert_eq!(
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
            .describe(),
            "round-robin[collapsed-gibbs,walker-slice]"
        );
    }

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(KernelKind::parse("gibbs").unwrap(), KernelKind::CollapsedGibbs);
        assert_eq!(KernelKind::parse("Walker").unwrap(), KernelKind::WalkerSlice);
        assert!(KernelKind::parse("metropolis").is_err());
        assert_eq!(KernelKind::CollapsedGibbs.name(), "collapsed-gibbs");
        assert_eq!(KernelKind::WalkerSlice.name(), "walker-slice");
    }

    #[test]
    fn walker_sweep_preserves_invariants() {
        let ds = SyntheticConfig {
            n: 300,
            d: 16,
            clusters: 4,
            beta: 0.15,
            seed: 3,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(16, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(1));
        for _ in 0..5 {
            WalkerSlice.sweep(&mut st, &ds.train, &model);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 300);
    }

    #[test]
    fn walker_finds_structure() {
        let ds = SyntheticConfig {
            n: 400,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 4.0, Pcg64::seed_from(5));
        for _ in 0..30 {
            WalkerSlice.sweep(&mut st, &ds.train, &model);
        }
        let j = st.num_clusters();
        assert!((2..=16).contains(&j), "Walker found {j} clusters, expected ~4");
    }

    #[test]
    fn kernels_handle_empty_shard() {
        let ds = SyntheticConfig {
            n: 10,
            d: 8,
            clusters: 2,
            beta: 0.5,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let model = BetaBernoulli::symmetric(8, 0.5);
        let mut st = Shard::init_from_prior(&ds.train, Vec::new(), 0.5, Pcg64::seed_from(7));
        WalkerSlice.sweep(&mut st, &ds.train, &model);
        CollapsedGibbs.sweep(&mut st, &ds.train, &model);
        assert_eq!(st.num_rows(), 0);
    }

    #[test]
    fn both_kernels_run_through_the_trait_object() {
        let ds = SyntheticConfig {
            n: 120,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 8,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        for kind in [KernelKind::CollapsedGibbs, KernelKind::WalkerSlice] {
            let rows: Vec<usize> = (0..ds.train.rows()).collect();
            let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(9));
            let kernel = kind.kernel();
            for _ in 0..3 {
                kernel.sweep(&mut st, &ds.train, &model);
                st.check_invariants(&ds.train).unwrap();
            }
            assert_eq!(st.num_rows(), ds.train.rows());
        }
    }
}
