//! The pluggable per-shard transition operators.
//!
//! The paper's §4 point — and the architectural point of Williamson et
//! al. (arXiv:1211.7120) and Dinari et al. (arXiv:2204.08988) — is that
//! *any* standard DPM transition operator applies unmodified inside a
//! supercluster, because each supercluster is a conditionally
//! independent `DP(αμ_k, H)`. [`TransitionKernel`] is that contract: a
//! kernel sees one [`Shard`] (rows + assignments + private RNG +
//! concentration θ) and leaves the shard's local DPM posterior
//! invariant. The serial chain (one shard, θ = α) and the parallel
//! coordinator (one shard per supercluster, θ = αμ_k) both dispatch
//! through it, so a kernel written once runs from both entry points.
//!
//! Implementations, each mapped to its source algorithm:
//!
//! | kernel | CLI spec | paper algorithm |
//! |---|---|---|
//! | [`CollapsedGibbs`] | `gibbs` | Neal (2000) Algorithm 3: per-datum collapsed Gibbs |
//! | [`WalkerSlice`] | `walker` | Walker (2007) slice sampling, slice-efficient variant |
//! | [`SplitMerge`] (Gibbs base) | `split_merge:gibbs` | Jain & Neal (2004) restricted-Gibbs split–merge MH + Neal Alg. 3 sweep |
//! | [`SplitMerge`] (Walker base) | `split_merge:walker` | Jain & Neal (2004) restricted-Gibbs split–merge MH + Walker sweep |
//!
//! * [`CollapsedGibbs`] — Neal (2000) Algorithm 3. Per datum: remove
//!   from its cluster, score every extant cluster (`n_j · p(x|stats_j)`
//!   in log space) and a fresh one (`θ · p(x|∅)`), sample, reinsert.
//! * [`WalkerSlice`] — Walker (2007) slice sampling (slice-efficient
//!   variant, coin weights kept collapsed). One sweep:
//!   1. impute explicit weights from the **posterior DP** (Ferguson):
//!      the occupied-atom masses plus the continuous remainder are
//!      jointly `(w_1..w_J, w_rest) ~ Dirichlet(n_1..n_J, θ)`, realized
//!      by stick-breaking `v_j ~ Beta(n_j, θ + Σ_{l>j} n_l)` in
//!      appearance-order labeling (note: NOT the blocked-Gibbs
//!      `Beta(1+n_j, ·)`, which is only correct with persistent stick
//!      labels — the enumeration gate caught that variant at TV ≈ 0.18);
//!   2. per datum, a slice `u_i ~ U(0, π_{z_i})`;
//!   3. break the remainder with empty sticks `v ~ Beta(1, θ)` until the
//!      leftover mass is below `min_i u_i` (finite truncation, exact);
//!   4. Gibbs each `z_i` over the *eligible* set `{j : π_j > u_i}` with
//!      collapsed predictive weights (likelihood only — π enters through
//!      eligibility, not the weights). Sticks/slices are discarded after
//!      the sweep (auxiliary variables).
//! * [`SplitMerge`] — the Jain & Neal (2004) restricted-Gibbs
//!   split–merge Metropolis–Hastings moves, composed with one of the
//!   per-datum kernels above so the composite remains irreducible. Each
//!   move picks two anchor data, builds a launch state by `t` restricted
//!   Gibbs scans over the anchors' member set, and accepts the proposed
//!   split (or merge) under the exact collapsed acceptance ratio
//!   `θ · Γ(n₁)Γ(n₂)/Γ(n₁+n₂) · m(x₁)m(x₂)/m(x₁₂)` — creating and
//!   dissolving whole clusters in one step, which the incremental
//!   kernels can only do datum by datum (the slow-mixing mode the
//!   composite exists to fix; see DESIGN.md §7 for the selection guide).
//!
//! Every kernel — the split–merge restricted scans included — scores a
//! datum's candidate clusters through the shard's
//! [`crate::sampler::ScoreMode`] dispatch: the scalar per-cluster
//! reference path, or one batched
//! [`crate::runtime::Scorer::score_ones_against_clusters`] call over the
//! shard's packed predictive tables (bit-identical by construction —
//! see `rust/src/sampler/score.rs` and DESIGN.md §8). Table maintenance
//! is *move-only*: the kernels invalidate a packed column only when a
//! datum actually changes cluster (plus the one held-out correction per
//! datum), so the self-move common case does zero table work. No
//! kernel allocates after warm-up: Gibbs runs on the shard's scratch
//! buffers, Walker on the persistent [`WalkerScratch`], the split–merge
//! layer on the persistent [`SplitMergeScratch`].
//!
//! Exactness of every kernel — through both entry points — is certified
//! by the posterior-enumeration gate in `rust/tests/posterior_exactness.rs`.

use super::shard::Shard;
use crate::data::DataRef;
use crate::model::Model;
use crate::rng::{beta as beta_draw, categorical_log_inplace};
use crate::special::{lgamma, logsumexp};

/// A per-shard DPM transition operator: one sweep must leave the shard's
/// local `DP(θ, H)` mixture posterior invariant. Kernels are stateless
/// (all chain state lives in the [`Shard`]), hence shareable across the
/// coordinator's worker threads.
pub trait TransitionKernel: Send + Sync {
    /// Implementation name for logs/CLI.
    fn name(&self) -> &'static str;

    /// One full sweep over the shard's resident rows, driven by the
    /// shard's private RNG stream and concentration θ. `data` is the
    /// likelihood-agnostic [`DataRef`] view (pass `(&binmat).into()` /
    /// `(&catmat).into()` / `(&realmat).into()`); `model` must match the
    /// data kind (see [`crate::model::ModelSpec::build`]).
    fn sweep(&self, shard: &mut Shard, data: DataRef<'_>, model: &Model);
}

/// Neal (2000) Algorithm 3: collapsed Gibbs.
pub struct CollapsedGibbs;

impl TransitionKernel for CollapsedGibbs {
    fn name(&self) -> &'static str {
        "collapsed-gibbs"
    }

    fn sweep(&self, shard: &mut Shard, data: DataRef<'_>, model: &Model) {
        let log_theta = shard.theta.max(1e-300).ln();
        shard.scoring_begin_sweep();
        let eager = shard.scoring_eager();
        for i in 0..shard.rows.len() {
            let r = shard.rows[i];
            let old = shard.assign[i] as usize;
            shard.clusters.remove_row(old, data, r);
            // the cluster the datum left (if it survived): scored from
            // its decremented cache, while its packed column keeps the
            // full-membership table in case the datum moves back
            let held = if shard.clusters.get(old).is_some() {
                Some(old)
            } else {
                None
            };
            // score the whole candidate set through the shard's scoring
            // dispatch (scalar reference, or one batched Scorer call)
            shard.score_crp_candidates(data, r, model, held);
            shard.scratch_ids.push(u32::MAX);
            shard.scratch_logw.push(log_theta + model.log_pred_empty(data, r));
            let pick = categorical_log_inplace(&mut shard.rng, &mut shard.scratch_logw);
            let slot = shard.place_pick(pick, data, r) as usize;
            // self-move (the stationary common case): stats are restored
            // exactly, the packed tables need zero work. Only a real
            // move — or a re-allocated slot after the old cluster died —
            // stales the two touched columns.
            if slot != old || held.is_none() || eager {
                shard.scoring_invalidate(old);
                shard.scoring_invalidate(slot);
            }
            shard.assign[i] = slot as u32;
        }
    }
}

/// Persistent per-sweep state of the Walker kernel, owned by the shard
/// (`Shard::walker`) so repeated sweeps are allocation-free after
/// warm-up: stick weights/slots, the slice variables, per-datum
/// candidate buffers, and the appearance-order scratch.
#[derive(Debug, Default)]
pub(crate) struct WalkerScratch {
    /// stick weights π, occupied (appearance order) then empty
    pub(crate) stick_pi: Vec<f64>,
    /// cluster slot per stick (`usize::MAX` = still unmaterialized)
    pub(crate) stick_slot: Vec<usize>,
    /// slot → stick index (`usize::MAX` = no stick)
    pub(crate) slot_to_stick: Vec<usize>,
    /// per-datum slice variables u_i
    pub(crate) u: Vec<f64>,
    /// eligible stick indices of the current datum
    pub(crate) cand: Vec<usize>,
    /// eligible cluster slots (`u32::MAX` = unmaterialized stick)
    pub(crate) cand_slots: Vec<u32>,
    /// candidate log-weights of the current datum
    pub(crate) logw: Vec<f64>,
    /// occupied-stick member counts (appearance order)
    pub(crate) counts: Vec<u64>,
    /// suffix sums Σ_{l>j} n_l over `counts`
    pub(crate) tail: Vec<u64>,
    /// occupied slots in appearance order
    pub(crate) appear: Vec<usize>,
    /// appearance-order dedup scratch
    pub(crate) seen: Vec<bool>,
}

/// Walker (2007) slice sampling (slice-efficient, collapsed coins).
///
/// The stick-extension loop (step 3) runs under an explicit θ-scaled
/// budget of `10_000 + 700·θ` empty sticks (capped at 1e6): the
/// leftover mass decays like `exp(−sticks/θ)` (each `v ~ Beta(1, θ)`
/// removes a `1/θ` fraction in expectation, so large θ shrinks it
/// *slowly*), and `700·θ` covers every representable slice
/// (`ln 1e-300 ≈ −690`). Exhausting the budget is an explicit error
/// path — logged and counted on the shard
/// (`Shard::stick_overflow_events`), never a silent truncation.
pub struct WalkerSlice;

impl TransitionKernel for WalkerSlice {
    fn name(&self) -> &'static str {
        "walker-slice"
    }

    fn sweep(&self, shard: &mut Shard, data: DataRef<'_>, model: &Model) {
        let theta = shard.theta.max(1e-12);
        if shard.rows.is_empty() {
            return;
        }
        // the scratch moves out for the sweep so the shard's scoring
        // methods can be called while it is borrowed; it returns (with
        // its capacities) at the end
        let mut scratch = std::mem::take(&mut shard.walker);

        // ---- 1. sticks for occupied clusters in APPEARANCE order ----
        // Given the partition of an exchangeable DP sample, the posterior
        // of the stick weights in order-of-appearance labeling is
        // v_j ~ Beta(n_j, θ + Σ_{l>j} n_l) independently (Pitman's
        // size-biased representation). An arbitrary fixed order is NOT a
        // draw from p(labels | z) and biases the chain.
        shard.slots_by_appearance_into(&mut scratch.seen, &mut scratch.appear);
        scratch.counts.clear();
        for &s in &scratch.appear {
            scratch.counts.push(shard.clusters.n_of(s));
        }
        let nst = scratch.appear.len();
        scratch.tail.clear();
        scratch.tail.resize(nst, 0);
        let mut acc = 0u64;
        for i in (0..nst).rev() {
            scratch.tail[i] = acc;
            acc += scratch.counts[i];
        }
        scratch.stick_pi.clear();
        scratch.stick_slot.clear();
        let mut remaining = 1.0f64;
        for i in 0..nst {
            let v = beta_draw(
                &mut shard.rng,
                scratch.counts[i] as f64,
                theta + scratch.tail[i] as f64,
            );
            scratch.stick_pi.push(remaining * v);
            scratch.stick_slot.push(scratch.appear[i]);
            remaining *= 1.0 - v;
        }

        // ---- 2. slice per datum: u_i ~ U(0, π_{z_i}) ----
        let n = shard.rows.len();
        scratch.slot_to_stick.clear();
        scratch.slot_to_stick.resize(shard.clusters.num_slots(), usize::MAX);
        for (idx, &s) in scratch.stick_slot.iter().enumerate() {
            scratch.slot_to_stick[s] = idx;
        }
        scratch.u.clear();
        scratch.u.reserve(n);
        let mut u_min = f64::INFINITY;
        for i in 0..n {
            let zi = shard.assign[i] as usize;
            let pz = scratch.stick_pi[scratch.slot_to_stick[zi]].max(1e-300);
            let ui = shard.rng.next_f64_open() * pz;
            scratch.u.push(ui);
            if ui < u_min {
                u_min = ui;
            }
        }

        // ---- 3. extend with empty sticks v ~ Beta(1, θ) until the
        //         leftover mass cannot contain any slice, under the
        //         θ-scaled budget (see the type-level docs) ----
        let max_sticks = (10_000.0 + 700.0 * theta).min(1_000_000.0) as usize;
        let mut extended = 0usize;
        while remaining > u_min {
            if extended >= max_sticks {
                shard.note_stick_overflow(theta, remaining, u_min, extended);
                break;
            }
            let v = beta_draw(&mut shard.rng, 1.0, theta);
            scratch.stick_pi.push(remaining * v);
            scratch.stick_slot.push(usize::MAX);
            remaining *= 1.0 - v;
            extended += 1;
        }

        // ---- 4. Gibbs each datum over its eligible sticks ----
        // weights: collapsed predictive (likelihood only — π enters via
        // eligibility). Emptied clusters keep their stick and score as
        // empty tables; picking an unmaterialized stick creates its
        // cluster, which later data in the same sweep can then join.
        shard.scoring_begin_sweep();
        let eager = shard.scoring_eager();
        for i in 0..n {
            let r = shard.rows[i];
            let old_slot = shard.assign[i] as usize;
            let old_stick = scratch.slot_to_stick[old_slot];
            shard.clusters.remove_row_keep_slot(old_slot, data, r);

            // collect the eligible sticks, then score them through the
            // shard's dispatch (one batched block per datum); the old
            // cluster keeps its slot, so it is always the held-out one
            scratch.cand.clear();
            scratch.cand_slots.clear();
            for idx in 0..scratch.stick_pi.len() {
                if scratch.stick_pi[idx] > scratch.u[i] {
                    scratch.cand.push(idx);
                    scratch.cand_slots.push(match scratch.stick_slot[idx] {
                        usize::MAX => u32::MAX,
                        s => s as u32,
                    });
                }
            }
            scratch.logw.clear();
            shard.score_slots_for_row(
                data,
                r,
                model,
                &scratch.cand_slots,
                Some(old_slot),
                &mut scratch.logw,
            );
            // float-tail guard: the datum's own stick is eligible by
            // construction, but keep a fallback anyway
            if scratch.cand.is_empty() {
                scratch.cand.push(old_stick);
                scratch.logw.push(0.0);
            }
            let ci = categorical_log_inplace(&mut shard.rng, &mut scratch.logw);
            let pick = scratch.cand[ci];
            match scratch.stick_slot[pick] {
                usize::MAX => {
                    let s = shard.clusters.alloc_empty();
                    shard.clusters.add_row(s, data, r);
                    shard.scoring_invalidate(old_slot);
                    shard.scoring_invalidate(s);
                    shard.assign[i] = s as u32;
                    scratch.stick_slot[pick] = s;
                    if scratch.slot_to_stick.len() <= s {
                        scratch.slot_to_stick.resize(s + 1, usize::MAX);
                    }
                    scratch.slot_to_stick[s] = pick;
                }
                s => {
                    shard.clusters.add_row(s, data, r);
                    // move-only maintenance: a self-move restores the
                    // stats exactly and needs no table work
                    if s != old_slot || eager {
                        shard.scoring_invalidate(old_slot);
                        shard.scoring_invalidate(s);
                    }
                    shard.assign[i] = s as u32;
                }
            }
        }
        shard.clusters.compact_free_slots();
        // a pathological sweep (huge θ) can grow the stick buffers — and
        // the per-datum candidate buffers, whose eligible sets span the
        // same stick range — into the hundreds of thousands; don't pin
        // that memory forever
        const SCRATCH_CAP: usize = 1 << 17;
        if scratch.stick_pi.capacity() > SCRATCH_CAP {
            scratch.stick_pi.shrink_to(SCRATCH_CAP);
            scratch.stick_slot.shrink_to(SCRATCH_CAP);
        }
        if scratch.cand.capacity() > SCRATCH_CAP {
            scratch.cand.shrink_to(SCRATCH_CAP);
            scratch.cand_slots.shrink_to(SCRATCH_CAP);
            scratch.logw.shrink_to(SCRATCH_CAP);
        }
        shard.walker = scratch;
    }
}

/// Persistent state of the split–merge move layer, owned by the shard
/// (`Shard::sm`): the member-index/side buffers (reused across moves so
/// the layer is allocation-free after warm-up) and the
/// proposal/acceptance counters behind `Shard::split_merge_stats`.
#[derive(Debug, Default)]
pub(crate) struct SplitMergeScratch {
    /// shard-local indices of the movable (non-anchor) members
    pub(crate) members: Vec<usize>,
    /// original side per member (`true` = anchor i's cluster) — the
    /// target configuration of a merge move's ghost pass
    pub(crate) sides: Vec<bool>,
    /// two-candidate log-likelihood buffer for the restricted scans
    pub(crate) logw: Vec<f64>,
    /// persistent union-stats scratch for scoring a merge proposal's
    /// merged marginal (populated on first merge proposal, then reused
    /// via `ClusterStats::copy_from` — no steady-state allocation)
    pub(crate) merged: Option<crate::model::ClusterStats>,
    /// split–merge MH proposals attempted on this shard
    pub(crate) proposals: u64,
    /// accepted split proposals
    pub(crate) split_accepts: u64,
    /// accepted merge proposals
    pub(crate) merge_accepts: u64,
}

/// Default split–merge MH proposals per composite sweep.
const SM_MOVES_PER_SWEEP: usize = 4;
/// Default number of intermediate restricted Gibbs scans `t` used to
/// build the launch state (Jain & Neal 2004 §4.2; more scans buy higher
/// acceptance at linear cost in the anchors' member count).
const SM_RESTRICTED_SCANS: usize = 2;

/// Jain & Neal (2004) restricted-Gibbs split–merge moves composed with a
/// per-datum base kernel — the third [`TransitionKernel`].
///
/// Incremental single-datum kernels mix slowly when a whole cluster must
/// be created or dissolved: moving `m` data through the intermediate
/// states costs `O(exp(−Δ))`-improbable steps. A split–merge move jumps
/// there directly: pick two anchor data `(i, j)` uniformly; if they
/// share a cluster, propose splitting it (anchor `i` seeds a fresh
/// cluster), else propose merging their two clusters. The proposal is
/// shaped by a *launch state* — the non-anchor members coin-flipped
/// between the two sides, then refined by `t` restricted Gibbs scans —
/// and a final restricted scan whose sequential conditionals give the
/// proposal density `q`. With the base measure collapsed (any
/// [`Model`] likelihood — the marginals come through
/// [`crate::model::ComponentModel::log_marginal`]), the MH ratio is
/// exact:
///
/// ```text
///   P(split) / P(merged) = θ · Γ(n₁)Γ(n₂)/Γ(n₁+n₂) · m(x₁)m(x₂)/m(x₁₂)
/// ```
///
/// (`m(·)` = collapsed cluster marginals via `ClusterStats::log_marginal`;
/// θ = the shard's local concentration, so inside a supercluster the
/// move targets the shard's conditional `DP(αμ_k, H)` posterior exactly
/// as the paper's §4 argument requires — global moves parallelize across
/// shards like any other standard DPM operator, the architectural point
/// of Dinari et al. (2022)'s distributed split–merge sampler).
///
/// The restricted scans score their two candidate sides through the
/// shard's [`crate::sampler::ScoreMode`] dispatch — the same packed-table
/// SIMD path (and the same scalar held-out correction for the side a
/// datum just left) the per-datum sweeps use, with move-only
/// invalidation of the two touched columns. Rejected proposals roll the
/// integer sufficient statistics back bit-exactly, so a rejected move
/// leaves chain state (stats, assignments, packed tables) untouched.
///
/// One `sweep()` = one sweep of the base kernel followed by
/// `SM_MOVES_PER_SWEEP` MH moves; both components leave the shard's
/// `DP(θ, H)` posterior invariant, hence so does the composition
/// (certified by the 203-partition gate in
/// `rust/tests/posterior_exactness.rs`, serial and K=3 — including
/// mixed per-shard assignments). Acceptance counters are exposed via
/// `Shard::split_merge_stats`.
pub struct SplitMerge {
    base: &'static dyn TransitionKernel,
    name: &'static str,
    moves: usize,
    scans: usize,
}

impl SplitMerge {
    /// A custom composite over `base`: `moves` MH proposals per sweep,
    /// each building its launch state with `scans` intermediate
    /// restricted Gibbs scans — the tuning knobs of the selection guide
    /// (DESIGN.md §7: low acceptance on a workload usually means `scans`
    /// is too small for the launch state to decorrelate from its
    /// coin-flip initialization). The CLI specs resolve to the shared
    /// [`SPLIT_MERGE_GIBBS`]/[`SPLIT_MERGE_WALKER`] defaults; custom
    /// composites run through the same [`TransitionKernel`] seam.
    ///
    /// ```
    /// use clustercluster::sampler::{CollapsedGibbs, SplitMerge, TransitionKernel};
    ///
    /// // a more aggressive composite: 8 proposals/sweep, 4 launch scans
    /// let aggressive = SplitMerge::new(&CollapsedGibbs, "split-merge:gibbs:x8", 8, 4);
    /// assert_eq!(aggressive.name(), "split-merge:gibbs:x8");
    /// ```
    pub const fn new(
        base: &'static dyn TransitionKernel,
        name: &'static str,
        moves: usize,
        scans: usize,
    ) -> SplitMerge {
        SplitMerge {
            base,
            name,
            moves,
            scans,
        }
    }
}

/// The shared `split_merge:gibbs` composite: split–merge MH moves + one
/// [`CollapsedGibbs`] sweep.
pub static SPLIT_MERGE_GIBBS: SplitMerge = SplitMerge {
    base: &CollapsedGibbs,
    name: "split-merge:gibbs",
    moves: SM_MOVES_PER_SWEEP,
    scans: SM_RESTRICTED_SCANS,
};

/// The shared `split_merge:walker` composite: split–merge MH moves + one
/// [`WalkerSlice`] sweep.
pub static SPLIT_MERGE_WALKER: SplitMerge = SplitMerge {
    base: &WalkerSlice,
    name: "split-merge:walker",
    moves: SM_MOVES_PER_SWEEP,
    scans: SM_RESTRICTED_SCANS,
};

impl TransitionKernel for SplitMerge {
    fn name(&self) -> &'static str {
        self.name
    }

    fn sweep(&self, shard: &mut Shard, data: DataRef<'_>, model: &Model) {
        // base sweep first: ITS begin-of-sweep hook re-enqueues every
        // packed column (cluster membership may have changed arbitrarily
        // since the last sweep — shuffle moves, resume), so the move
        // layer afterwards runs on live tables and maintains them
        // incrementally — one full repack per composite sweep, not two
        self.base.sweep(shard, data, model);
        split_merge_moves(shard, data, model, self.moves, self.scans);
    }
}

/// Run `moves` split–merge MH proposals on the shard (the move layer of
/// [`SplitMerge`], callable without the base sweep for tests). Assumes
/// `Shard::scoring_begin_sweep` has run since the last external state
/// change.
pub(crate) fn split_merge_moves(
    shard: &mut Shard,
    data: DataRef<'_>,
    model: &Model,
    moves: usize,
    scans: usize,
) {
    if shard.rows.len() < 2 {
        return;
    }
    for _ in 0..moves {
        shard.sm.proposals += 1;
        let n = shard.rows.len();
        // two distinct anchor data, uniform over ordered pairs — the
        // selection probability is state-independent, so it cancels in
        // the MH ratio
        let i = shard.rng.next_below(n as u64) as usize;
        let mut j = shard.rng.next_below(n as u64 - 1) as usize;
        if j >= i {
            j += 1;
        }
        let zi = shard.assign[i] as usize;
        let zj = shard.assign[j] as usize;
        if zi == zj {
            propose_split(shard, data, model, scans, (i, j), zi);
        } else {
            propose_merge(shard, data, model, scans, (i, j), (zi, zj));
        }
    }
}

/// One restricted Gibbs pass over `members` between the two live sides
/// `(side_i, side_j)`: each member is removed from its current side,
/// both sides are scored `n_side · p(x | side)` through the shard's
/// scoring dispatch (the side the datum just left gets the scalar
/// held-out correction), and the datum is placed — sampled from the
/// two-way conditional, or, when `forced` is given, deterministically on
/// its recorded original side (`true` = `side_i`). Returns the summed
/// log-probability of the realized choices under the conditionals: the
/// proposal density of a sampled final scan, or the reverse-proposal
/// density `q(original split | launch)` of a merge move's ghost pass.
/// Anchors never move, so neither side can empty mid-scan.
fn restricted_scan(
    shard: &mut Shard,
    data: DataRef<'_>,
    model: &Model,
    members: &[usize],
    side_i: usize,
    side_j: usize,
    forced: Option<&[bool]>,
) -> f64 {
    let eager = shard.scoring_eager();
    let mut logw = std::mem::take(&mut shard.sm.logw);
    let mut log_q = 0.0;
    for (k, &midx) in members.iter().enumerate() {
        let r = shard.rows[midx];
        let cur = shard.assign[midx] as usize;
        shard.clusters.remove_row(cur, data, r);
        logw.clear();
        shard.score_slots_for_row(
            data,
            r,
            model,
            &[side_i as u32, side_j as u32],
            Some(cur),
            &mut logw,
        );
        let wi = (shard.clusters.n_of(side_i) as f64).ln() + logw[0];
        let wj = (shard.clusters.n_of(side_j) as f64).ln() + logw[1];
        let lse = logsumexp(&[wi, wj]);
        let to_i = match forced {
            Some(sides) => sides[k],
            None => shard.rng.next_f64() < (wi - lse).exp(),
        };
        log_q += if to_i { wi - lse } else { wj - lse };
        let dst = if to_i { side_i } else { side_j };
        shard.clusters.add_row(dst, data, r);
        // move-only table maintenance, exactly as in the per-datum
        // kernels: a self-move restores the stats and needs no work
        // (except under the eager reference policy, whose held-out
        // column was just re-packed with decremented stats)
        if dst != cur || eager {
            shard.scoring_invalidate(cur);
            shard.scoring_invalidate(dst);
            shard.assign[midx] = dst as u32;
        }
    }
    shard.sm.logw = logw;
    log_q
}

/// Propose splitting cluster `c` (holding both anchors) around the
/// anchor pair: anchor `i` seeds a fresh cluster, the launch state is
/// built by coin flips + `scans` restricted passes, the final sampled
/// pass is the proposal. On rejection every move is rolled back
/// bit-exactly (the emptied fresh slot returns to the free list).
fn propose_split(
    shard: &mut Shard,
    data: DataRef<'_>,
    model: &Model,
    scans: usize,
    (i, j): (usize, usize),
    c: usize,
) {
    let theta = shard.theta.max(1e-300);
    let (n_merged, lm_merged) = {
        let st = shard.clusters.get(c).expect("anchor cluster live");
        (st.n(), st.log_marginal(model))
    };
    let mut members = std::mem::take(&mut shard.sm.members);
    members.clear();
    for (idx, &a) in shard.assign.iter().enumerate() {
        if a as usize == c && idx != i && idx != j {
            members.push(idx);
        }
    }
    // launch: anchor i opens a fresh cluster, members coin-flip sides
    let c_new = shard.clusters.alloc_empty();
    shard.clusters.move_row(c, c_new, data, shard.rows[i]);
    shard.assign[i] = c_new as u32;
    for &midx in &members {
        if shard.rng.next_f64() < 0.5 {
            shard.clusters.move_row(c, c_new, data, shard.rows[midx]);
            shard.assign[midx] = c_new as u32;
        }
    }
    shard.scoring_invalidate(c);
    shard.scoring_invalidate(c_new);
    for _ in 0..scans {
        restricted_scan(shard, data, model, &members, c_new, c, None);
    }
    // final scan = the proposal; its conditionals are the density q
    let log_q = restricted_scan(shard, data, model, &members, c_new, c, None);

    let (n1, lm1) = {
        let st = shard.clusters.get(c_new).expect("split side live");
        (st.n(), st.log_marginal(model))
    };
    let (n2, lm2) = {
        let st = shard.clusters.get(c).expect("split side live");
        (st.n(), st.log_marginal(model))
    };
    // P(split)/P(merged) = θ·Γ(n1)Γ(n2)/Γ(n_m) · m1·m2/m12; the reverse
    // (merge) proposal is deterministic, so q appears only forward
    let log_ratio = theta.ln() + lgamma(n1 as f64) + lgamma(n2 as f64)
        - lgamma(n_merged as f64)
        + lm1
        + lm2
        - lm_merged;
    let log_acc = log_ratio - log_q;
    if shard.rng.next_f64_open().ln() < log_acc {
        shard.sm.split_accepts += 1;
    } else {
        // rollback: every row returns to c; the last removal empties
        // c_new, freeing and recycling its slot
        for &midx in &members {
            if shard.assign[midx] as usize == c_new {
                shard.clusters.move_row(c_new, c, data, shard.rows[midx]);
                shard.assign[midx] = c as u32;
            }
        }
        shard.clusters.move_row(c_new, c, data, shard.rows[i]);
        shard.assign[i] = c as u32;
        shard.scoring_invalidate(c_new);
        shard.scoring_invalidate(c);
    }
    shard.sm.members = members;
}

/// Propose merging anchor `i`'s cluster `a` into anchor `j`'s cluster
/// `b`. The reverse-split proposal density is scored by building the
/// same launch state over the union and walking a ghost restricted pass
/// that forces each member to its original side — which also restores
/// the pre-move state bit-exactly, so rejection needs no further work.
fn propose_merge(
    shard: &mut Shard,
    data: DataRef<'_>,
    model: &Model,
    scans: usize,
    (i, j): (usize, usize),
    (a, b): (usize, usize),
) {
    let theta = shard.theta.max(1e-300);
    let (n_a, lm_a) = {
        let st = shard.clusters.get(a).expect("anchor cluster live");
        (st.n(), st.log_marginal(model))
    };
    let (n_b, lm_b) = {
        let st = shard.clusters.get(b).expect("anchor cluster live");
        (st.n(), st.log_marginal(model))
    };
    let lm_merged = {
        let a_stats = shard.clusters.get(a).expect("anchor cluster live");
        let b_stats = shard.clusters.get(b).expect("anchor cluster live");
        // union stats on the persistent scratch (allocates once, on the
        // shard's first merge proposal)
        match &mut shard.sm.merged {
            Some(m) => {
                m.copy_from(a_stats);
                m.absorb(b_stats);
                m.log_marginal(model)
            }
            slot @ None => {
                let mut m = a_stats.clone();
                m.absorb(b_stats);
                let lm = m.log_marginal(model);
                *slot = Some(m);
                lm
            }
        }
    };
    let mut members = std::mem::take(&mut shard.sm.members);
    let mut sides = std::mem::take(&mut shard.sm.sides);
    members.clear();
    sides.clear();
    for (idx, &z) in shard.assign.iter().enumerate() {
        let s = z as usize;
        if (s == a || s == b) && idx != i && idx != j {
            members.push(idx);
            sides.push(s == a);
        }
    }
    // launch over the union: coin-flip each member between the sides,
    // then refine with the restricted scans — the same construction the
    // forward split uses, so the launch distribution cancels in the
    // MH ratio
    for &midx in &members {
        let cur = shard.assign[midx] as usize;
        let dst = if shard.rng.next_f64() < 0.5 { a } else { b };
        if dst != cur {
            shard.clusters.move_row(cur, dst, data, shard.rows[midx]);
            shard.assign[midx] = dst as u32;
        }
    }
    shard.scoring_invalidate(a);
    shard.scoring_invalidate(b);
    for _ in 0..scans {
        restricted_scan(shard, data, model, &members, a, b, None);
    }
    // ghost pass: force the original configuration, accumulating the
    // reverse-proposal density q(original split | launch); afterwards
    // the chain state equals the pre-move state exactly
    let log_q_rev = restricted_scan(shard, data, model, &members, a, b, Some(&sides));

    let log_ratio_split = theta.ln() + lgamma(n_a as f64) + lgamma(n_b as f64)
        - lgamma((n_a + n_b) as f64)
        + lm_a
        + lm_b
        - lm_merged;
    // P(merged)/P(split) is the inverse ratio; the merge proposal itself
    // is deterministic, so only the reverse q enters
    let log_acc = log_q_rev - log_ratio_split;
    if shard.rng.next_f64_open().ln() < log_acc {
        shard.sm.merge_accepts += 1;
        // retarget exactly the dissolved cluster's rows — after the
        // ghost-pass restore those are anchor i plus the members
        // recorded on side a — rather than scanning the whole shard
        shard.assign[i] = b as u32;
        for (k, &midx) in members.iter().enumerate() {
            if sides[k] {
                shard.assign[midx] = b as u32;
            }
        }
        shard.clusters.merge_slots(a, b);
        shard.scoring_invalidate(a);
        shard.scoring_invalidate(b);
    }
    shard.sm.members = members;
    shard.sm.sides = sides;
}

/// CLI/config-level kernel selector, resolvable to the shared static
/// kernel instances. This is what `--local-kernel` parses into from both
/// the serial and the parallel entry points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Neal (2000) Algorithm 3 collapsed Gibbs (default).
    #[default]
    CollapsedGibbs,
    /// Walker (2007) slice sampling (slice-efficient, collapsed coins).
    WalkerSlice,
    /// Jain & Neal (2004) split–merge MH moves + a collapsed-Gibbs sweep
    /// (the `split_merge:gibbs` composite).
    SplitMergeGibbs,
    /// Jain & Neal (2004) split–merge MH moves + a Walker slice sweep
    /// (the `split_merge:walker` composite).
    SplitMergeWalker,
}

impl KernelKind {
    /// The shared kernel instance this selector names.
    pub fn kernel(self) -> &'static dyn TransitionKernel {
        match self {
            KernelKind::CollapsedGibbs => &CollapsedGibbs,
            KernelKind::WalkerSlice => &WalkerSlice,
            KernelKind::SplitMergeGibbs => &SPLIT_MERGE_GIBBS,
            KernelKind::SplitMergeWalker => &SPLIT_MERGE_WALKER,
        }
    }

    /// Display name of the kernel this selector names.
    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    /// Parse a `--local-kernel` value. Composite split–merge specs name
    /// their base sweep after a colon (`split_merge:gibbs`,
    /// `split_merge:walker`); a bare `split_merge` defaults the base to
    /// collapsed Gibbs, and `-`/`_` are interchangeable throughout.
    ///
    /// ```
    /// use clustercluster::sampler::KernelKind;
    ///
    /// assert_eq!(KernelKind::parse("gibbs").unwrap(), KernelKind::CollapsedGibbs);
    /// assert_eq!(
    ///     KernelKind::parse("split_merge:walker").unwrap(),
    ///     KernelKind::SplitMergeWalker,
    /// );
    /// assert_eq!(
    ///     KernelKind::parse("split-merge").unwrap(),
    ///     KernelKind::SplitMergeGibbs,
    /// );
    /// assert!(KernelKind::parse("split_merge:metropolis").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        let norm = s.to_ascii_lowercase().replace('_', "-");
        match norm.as_str() {
            "gibbs" | "collapsed" | "collapsed-gibbs" | "neal" => Ok(KernelKind::CollapsedGibbs),
            "walker" | "slice" | "walker-slice" => Ok(KernelKind::WalkerSlice),
            "split-merge" | "sm" | "jain-neal" | "split-merge:gibbs" | "sm:gibbs" => {
                Ok(KernelKind::SplitMergeGibbs)
            }
            "split-merge:walker" | "sm:walker" => Ok(KernelKind::SplitMergeWalker),
            other => Err(format!(
                "unknown kernel {other:?} (expected \"gibbs\", \"walker\", \
                 \"split_merge:gibbs\", or \"split_merge:walker\")"
            )),
        }
    }
}

/// How transition kernels are assigned to the coordinator's shards
/// (paper §4 / Williamson et al.: each supercluster is an independent
/// `DP(αμ_k, H)`, so *different* standard DPM operators may run on
/// different superclusters within one chain without affecting
/// exactness). This is the config-level selector behind
/// `--local-kernel gibbs,walker,…` on the CLI; the coordinator resolves
/// it to one [`KernelKind`] per shard at construction via
/// [`KernelAssignment::resolve`].
///
/// ```
/// use clustercluster::sampler::{KernelAssignment, KernelKind};
///
/// // one kernel everywhere (the default)
/// let all = KernelAssignment::AllSame(KernelKind::CollapsedGibbs);
/// assert_eq!(all.resolve(3).unwrap(), vec![KernelKind::CollapsedGibbs; 3]);
///
/// // `--local-kernel gibbs,walker` cycles the list over the shards
/// let mixed = KernelAssignment::parse("gibbs,walker").unwrap();
/// assert_eq!(
///     mixed.resolve(3).unwrap(),
///     vec![
///         KernelKind::CollapsedGibbs,
///         KernelKind::WalkerSlice,
///         KernelKind::CollapsedGibbs,
///     ],
/// );
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelAssignment {
    /// Every shard runs the same kernel.
    AllSame(KernelKind),
    /// Explicit kernel per shard; the vector length must equal the
    /// worker count (checked by [`KernelAssignment::resolve`]).
    PerShard(Vec<KernelKind>),
    /// Cycle a non-empty kernel list over the shards in order — what a
    /// comma-separated `--local-kernel` value parses into.
    RoundRobin(Vec<KernelKind>),
}

impl Default for KernelAssignment {
    fn default() -> Self {
        KernelAssignment::AllSame(KernelKind::default())
    }
}

impl KernelAssignment {
    /// Resolve to one kernel selector per shard, validating shape.
    pub fn resolve(&self, workers: usize) -> Result<Vec<KernelKind>, String> {
        match self {
            KernelAssignment::AllSame(k) => Ok(vec![*k; workers]),
            KernelAssignment::PerShard(v) => {
                if v.len() == workers {
                    Ok(v.clone())
                } else {
                    Err(format!(
                        "per-shard kernel list has {} entries for {} workers",
                        v.len(),
                        workers
                    ))
                }
            }
            KernelAssignment::RoundRobin(v) => {
                if v.is_empty() {
                    Err("round-robin kernel list is empty".into())
                } else {
                    Ok((0..workers).map(|i| v[i % v.len()]).collect())
                }
            }
        }
    }

    /// Parse a `--local-kernel` value: a single kernel name maps to
    /// [`KernelAssignment::AllSame`], a comma-separated list to
    /// [`KernelAssignment::RoundRobin`] over the shards.
    pub fn parse(s: &str) -> Result<KernelAssignment, String> {
        let kinds: Result<Vec<KernelKind>, String> =
            s.split(',').map(|tok| KernelKind::parse(tok.trim())).collect();
        let kinds = kinds?;
        match kinds.as_slice() {
            [] => Err("empty kernel list".into()),
            [one] => Ok(KernelAssignment::AllSame(*one)),
            _ => Ok(KernelAssignment::RoundRobin(kinds)),
        }
    }

    /// Human-readable description for run banners and logs.
    pub fn describe(&self) -> String {
        match self {
            KernelAssignment::AllSame(k) => k.name().to_string(),
            KernelAssignment::PerShard(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("per-shard[{}]", names.join(","))
            }
            KernelAssignment::RoundRobin(v) => {
                let names: Vec<&str> = v.iter().map(|k| k.name()).collect();
                format!("round-robin[{}]", names.join(","))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::data::BinMat;
    use crate::rng::Pcg64;

    #[test]
    fn assignment_parses_and_resolves() {
        assert_eq!(
            KernelAssignment::parse("gibbs").unwrap(),
            KernelAssignment::AllSame(KernelKind::CollapsedGibbs)
        );
        let mixed = KernelAssignment::parse(" gibbs , walker ").unwrap();
        assert_eq!(
            mixed,
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
        );
        assert_eq!(
            mixed.resolve(5).unwrap(),
            vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
            ]
        );
        assert!(KernelAssignment::parse("gibbs,metropolis").is_err());
        assert!(KernelAssignment::PerShard(vec![KernelKind::WalkerSlice])
            .resolve(2)
            .is_err());
        assert!(KernelAssignment::RoundRobin(Vec::new()).resolve(2).is_err());
        assert_eq!(
            KernelAssignment::default().resolve(2).unwrap(),
            vec![KernelKind::CollapsedGibbs; 2]
        );
    }

    #[test]
    fn assignment_describe_names_every_variant() {
        assert_eq!(
            KernelAssignment::AllSame(KernelKind::WalkerSlice).describe(),
            "walker-slice"
        );
        assert_eq!(
            KernelAssignment::PerShard(vec![KernelKind::CollapsedGibbs]).describe(),
            "per-shard[collapsed-gibbs]"
        );
        assert_eq!(
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ])
            .describe(),
            "round-robin[collapsed-gibbs,walker-slice]"
        );
    }

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(KernelKind::parse("gibbs").unwrap(), KernelKind::CollapsedGibbs);
        assert_eq!(KernelKind::parse("Walker").unwrap(), KernelKind::WalkerSlice);
        assert!(KernelKind::parse("metropolis").is_err());
        assert_eq!(KernelKind::CollapsedGibbs.name(), "collapsed-gibbs");
        assert_eq!(KernelKind::WalkerSlice.name(), "walker-slice");
        assert_eq!(KernelKind::SplitMergeGibbs.name(), "split-merge:gibbs");
        assert_eq!(KernelKind::SplitMergeWalker.name(), "split-merge:walker");
    }

    #[test]
    fn composite_specs_parse_with_either_separator() {
        for spec in ["split_merge:gibbs", "split-merge:gibbs", "sm:gibbs", "split_merge", "sm"] {
            assert_eq!(
                KernelKind::parse(spec).unwrap(),
                KernelKind::SplitMergeGibbs,
                "{spec}"
            );
        }
        for spec in ["split_merge:walker", "split-merge:walker", "SM:Walker"] {
            assert_eq!(
                KernelKind::parse(spec).unwrap(),
                KernelKind::SplitMergeWalker,
                "{spec}"
            );
        }
        assert!(KernelKind::parse("split_merge:metropolis").is_err());
        // comma lists mix composites with plain kernels (the colon is
        // part of the token, not a list separator)
        let mixed = KernelAssignment::parse("gibbs,split_merge:walker").unwrap();
        assert_eq!(
            mixed,
            KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::SplitMergeWalker,
            ])
        );
        assert_eq!(
            mixed.resolve(3).unwrap(),
            vec![
                KernelKind::CollapsedGibbs,
                KernelKind::SplitMergeWalker,
                KernelKind::CollapsedGibbs,
            ]
        );
        assert_eq!(mixed.describe(), "round-robin[collapsed-gibbs,split-merge:walker]");
    }

    #[test]
    fn walker_sweep_preserves_invariants() {
        let ds = SyntheticConfig {
            n: 300,
            d: 16,
            clusters: 4,
            beta: 0.15,
            seed: 3,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(16, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(1));
        for _ in 0..5 {
            WalkerSlice.sweep(&mut st, (&ds.train).into(), &model);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 300);
    }

    #[test]
    fn walker_finds_structure() {
        let ds = SyntheticConfig {
            n: 400,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 4.0, Pcg64::seed_from(5));
        for _ in 0..30 {
            WalkerSlice.sweep(&mut st, (&ds.train).into(), &model);
        }
        let j = st.num_clusters();
        assert!((2..=16).contains(&j), "Walker found {j} clusters, expected ~4");
    }

    /// Regression for the old silent `guard < 10_000` cutoff: at large θ
    /// the leftover stick mass shrinks *slowly* (each empty stick
    /// removes only a ~1/θ fraction in expectation), so covering the
    /// smallest slice needs ≈ θ·ln(1/u_min) sticks — far past the old
    /// cutoff, which silently truncated the eligible sets. The θ-scaled
    /// budget must complete the extension without an overflow event.
    #[test]
    fn walker_slow_shrink_regime_completes_without_overflow() {
        let ds = SyntheticConfig {
            n: 40,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 11,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(12));
        st.set_theta(20_000.0);
        WalkerSlice.sweep(&mut st, (&ds.train).into(), &model);
        assert_eq!(
            st.stick_overflow_events(),
            0,
            "θ-scaled budget must cover the slow-shrink regime"
        );
        // the sweep really needed more sticks than the old silent cutoff
        assert!(
            st.walker.stick_pi.len() > 10_000,
            "expected > 10k sticks at θ=2e4, got {} (regime not exercised)",
            st.walker.stick_pi.len()
        );
        st.check_invariants(&ds.train).unwrap();
    }

    /// At absurd θ even the capped budget cannot drain the leftover
    /// mass: the sweep must hit the explicit error path (logged +
    /// counted), not loop forever or truncate silently, and the chain
    /// state must remain valid.
    #[test]
    fn walker_stick_budget_exhaustion_is_counted() {
        let ds = SyntheticConfig {
            n: 6,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 13,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(14));
        st.set_theta(1.0e12);
        WalkerSlice.sweep(&mut st, (&ds.train).into(), &model);
        assert!(
            st.stick_overflow_events() > 0,
            "budget exhaustion must be recorded, not silent"
        );
        st.check_invariants(&ds.train).unwrap();
        assert_eq!(st.num_rows(), 6);
    }

    #[test]
    fn kernels_handle_empty_shard() {
        let ds = SyntheticConfig {
            n: 10,
            d: 8,
            clusters: 2,
            beta: 0.5,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let model = Model::bernoulli(8, 0.5);
        let mut st = Shard::init_from_prior(&ds.train, Vec::new(), 0.5, Pcg64::seed_from(7));
        WalkerSlice.sweep(&mut st, (&ds.train).into(), &model);
        CollapsedGibbs.sweep(&mut st, (&ds.train).into(), &model);
        SPLIT_MERGE_GIBBS.sweep(&mut st, (&ds.train).into(), &model);
        SPLIT_MERGE_WALKER.sweep(&mut st, (&ds.train).into(), &model);
        assert_eq!(st.num_rows(), 0);
    }

    #[test]
    fn all_kernels_run_through_the_trait_object() {
        let ds = SyntheticConfig {
            n: 120,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 8,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(8, 0.5);
        model.build_lut(ds.train.rows() + 1);
        for kind in [
            KernelKind::CollapsedGibbs,
            KernelKind::WalkerSlice,
            KernelKind::SplitMergeGibbs,
            KernelKind::SplitMergeWalker,
        ] {
            let rows: Vec<usize> = (0..ds.train.rows()).collect();
            let mut st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(9));
            let kernel = kind.kernel();
            for _ in 0..3 {
                kernel.sweep(&mut st, (&ds.train).into(), &model);
                st.check_invariants(&ds.train).unwrap();
            }
            assert_eq!(st.num_rows(), ds.train.rows());
        }
    }

    /// Hand-computable acceptance check: with two data the partition
    /// space is {together, apart}, and the exact posterior odds are
    /// `P(apart)/P(together) = θ · m(x₁)m(x₂)/m(x₁₂)` (Γ factors are all
    /// Γ(1) = Γ(2)/1 = 1). A chain of split–merge moves ALONE must
    /// reproduce those odds — any error in the MH acceptance ratio shows
    /// up directly.
    #[test]
    fn split_merge_acceptance_matches_hand_computed_two_point_odds() {
        use crate::model::ClusterStats;
        let data = BinMat::from_dense(2, 3, &[1, 1, 0, 0, 0, 1]);
        let mut model = Model::bernoulli(3, 0.7);
        model.build_lut(3);
        let theta = 0.8f64;
        // exact odds from the collapsed marginals
        let (m1, m2, m12) = {
            let mut a = ClusterStats::empty(3);
            a.add(&data, 0);
            let mut b = ClusterStats::empty(3);
            b.add(&data, 1);
            let mut ab = ClusterStats::empty(3);
            ab.add(&data, 0);
            ab.add(&data, 1);
            (
                a.log_marginal(&model),
                b.log_marginal(&model),
                ab.log_marginal(&model),
            )
        };
        let odds = (theta.ln() + m1 + m2 - m12).exp();
        let want_p_apart = odds / (1.0 + odds);

        let mut sh = Shard::init_from_prior(
            &data,
            vec![0, 1],
            theta,
            Pcg64::seed_from(31),
        );
        let samples = 60_000u64;
        let mut apart = 0u64;
        for _ in 0..samples {
            sh.scoring_begin_sweep();
            split_merge_moves(&mut sh, (&data).into(), &model, 1, 2);
            if sh.num_clusters() == 2 {
                apart += 1;
            }
        }
        sh.check_invariants(&data).unwrap();
        let got = apart as f64 / samples as f64;
        assert!(
            (got - want_p_apart).abs() < 0.02,
            "P(apart): chain {got:.4} vs exact {want_p_apart:.4}"
        );
        let (proposals, splits, merges) = sh.split_merge_stats();
        assert_eq!(proposals, samples);
        assert!(splits > 0 && merges > 0, "both move types must fire");
    }

    /// The move layer alone is irreducible on ≥3 data (split the pair,
    /// merge any two singletons, …), so a moves-only chain must converge
    /// to the exactly enumerated 3-point posterior (Bell(3) = 5
    /// partitions) — the acceptance-ratio gate on a state space with
    /// non-trivial launch states and restricted scans.
    #[test]
    fn split_merge_moves_alone_match_the_exact_three_point_posterior() {
        use crate::testing::{canonical_partition, enumerate_posterior, partition_tv_distance};
        use std::collections::HashMap;
        let data = BinMat::from_dense(3, 4, &[1, 1, 0, 0, 1, 0, 0, 1, 0, 0, 1, 1]);
        let mut model = Model::bernoulli(4, 0.6);
        model.build_lut(4);
        let alpha = 1.1;
        let truth = enumerate_posterior(&data, &model, alpha);
        assert_eq!(truth.len(), 5); // Bell(3)

        let mut sh = Shard::init_from_prior(&data, vec![0, 1, 2], alpha, Pcg64::seed_from(33));
        let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
        let burn = 2_000u64;
        let samples = 60_000u64;
        for it in 0..(burn + samples) {
            sh.scoring_begin_sweep();
            split_merge_moves(&mut sh, (&data).into(), &model, 2, 2);
            if it >= burn {
                *counts
                    .entry(canonical_partition(sh.assignments_local()))
                    .or_default() += 1;
            }
        }
        sh.check_invariants(&data).unwrap();
        let tv = partition_tv_distance(&truth, &counts, samples);
        assert!(tv < 0.05, "moves-only TV distance {tv} too large");
    }

    /// Split–merge sweeps on realistic data: invariants hold, rows are
    /// conserved, rejected proposals leave no residue, and structure is
    /// still found (the composite must not hurt the base kernel).
    #[test]
    fn split_merge_composite_preserves_invariants_and_finds_structure() {
        let ds = SyntheticConfig {
            n: 400,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 14,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_from_prior(&ds.train, rows, 4.0, Pcg64::seed_from(15));
        for _ in 0..30 {
            SPLIT_MERGE_GIBBS.sweep(&mut st, (&ds.train).into(), &model);
            st.check_invariants(&ds.train).unwrap();
        }
        assert_eq!(st.num_rows(), 400);
        let j = st.num_clusters();
        assert!((2..=16).contains(&j), "composite found {j} clusters, expected ~4");
        let (proposals, _, _) = st.split_merge_stats();
        assert_eq!(proposals, 30 * SM_MOVES_PER_SWEEP as u64);
    }

    /// Worst-case start for incremental kernels: every datum in ONE
    /// cluster. Split moves must break it apart far faster than
    /// single-datum escapes would — the mixing rationale for the
    /// composite (a handful of sweeps suffice where plain Gibbs needs
    /// the slow datum-by-datum nucleation path).
    #[test]
    fn split_moves_escape_the_single_cluster_trap() {
        let ds = SyntheticConfig {
            n: 300,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 16,
        }
        .generate_with_test_fraction(0.0);
        let mut model = Model::bernoulli(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = Shard::init_single_cluster(&ds.train, rows, 1.0, Pcg64::seed_from(17));
        assert_eq!(st.num_clusters(), 1);
        for _ in 0..15 {
            SPLIT_MERGE_GIBBS.sweep(&mut st, (&ds.train).into(), &model);
        }
        st.check_invariants(&ds.train).unwrap();
        let (_, splits, _) = st.split_merge_stats();
        assert!(splits > 0, "no split was ever accepted from the merged start");
        assert!(
            st.num_clusters() >= 2,
            "composite failed to leave the single-cluster mode"
        );
    }
}
