//! One shard of the latent state: the unit every [`TransitionKernel`]
//! operates on.
//!
//! A shard owns a set of data rows, their cluster assignments, the
//! [`ClusterSet`] those assignments index into, a *private* RNG stream
//! (so chains are deterministic regardless of thread scheduling), and a
//! concentration `θ`. The serial sampler is exactly one shard with
//! `θ = α`; each supercluster of the parallel coordinator is a shard
//! with `θ = α·μ_k`. That both are literally the same type is what makes
//! the K=1 ≡ serial equivalence structural (asserted chain-exactly in
//! `rust/tests/k1_equivalence.rs`) rather than coincidental.
//!
//! [`TransitionKernel`]: crate::sampler::TransitionKernel

use super::cluster_set::ClusterSet;
use super::kernel::{SplitMergeScratch, WalkerScratch};
use super::score::{ScoreDispatch, ScoreMode};
use crate::data::DataRef;
use crate::model::{ClusterStats, Model};
use crate::rng::{categorical_log, Pcg64};

/// One shard (= the serial chain, or one supercluster / compute node).
pub struct Shard {
    /// global row ids resident on this shard
    pub(crate) rows: Vec<usize>,
    /// cluster slot per resident row (parallel to `rows`)
    pub(crate) assign: Vec<u32>,
    /// slotted local clusters
    pub(crate) clusters: ClusterSet,
    /// private RNG stream driving the transition kernel
    pub(crate) rng: Pcg64,
    /// concentration θ the kernel sweeps with (α serial, α·μ_k parallel)
    pub(crate) theta: f64,
    /// candidate-cluster scoring dispatch (scalar reference or packed
    /// batched tables + a Scorer backend); travels with the shard across
    /// the coordinator's map-step threads
    pub(crate) scoring: ScoreDispatch,
    /// packed-table rows per cluster column for this shard's data kind
    /// (stat width for the bit-backed models, 2·D real — see
    /// [`DataRef::table_rows`]); what [`Self::set_score_mode`] sizes
    /// fresh dispatch tables with
    pub(crate) table_rows: usize,
    // scratch buffers (reused across sweeps; never on the alloc hot path)
    pub(crate) scratch_ids: Vec<u32>,
    pub(crate) scratch_logw: Vec<f64>,
    pub(crate) scratch_ones: Vec<u32>,
    /// persistent per-sweep state of the Walker kernel (sticks, slices,
    /// candidate buffers) — lives on the shard so Walker sweeps are
    /// allocation-free after warm-up
    pub(crate) walker: WalkerScratch,
    /// persistent state of the split–merge move layer: member/side
    /// buffers (so repeated moves are allocation-free after warm-up)
    /// plus the proposal/acceptance counters behind
    /// [`Self::split_merge_stats`]
    pub(crate) sm: SplitMergeScratch,
    /// times a Walker sweep exhausted its stick-extension budget (see
    /// [`Self::stick_overflow_events`])
    pub(crate) stick_overflows: u64,
    /// cumulative work-stealing bonus sweeps this shard has run under
    /// `--overlap on` (observability, like `stick_overflows`; not
    /// checkpointed) — see [`Self::bonus_sweeps`]
    pub(crate) bonus_sweeps: u64,
}

/// A bit-exact restore point of one shard's chain state, captured by
/// [`Shard::snapshot`] before a supervised round's sweeps so a crashed
/// or stalled attempt can be retried from exactly where it started.
///
/// What is captured: everything the transition kernels read or write —
/// resident rows, assignments, the slotted [`ClusterSet`] **cloned
/// as-is** (slot layout, free list, and graveyard included: a
/// rebuild-from-assignments would reorder slot allocation and change
/// downstream draws), the private RNG stream, θ, and the observability
/// counters. What is *not*: the scoring dispatch (consumes no
/// randomness; the restoring owner re-applies its score mode) and the
/// Walker/split–merge scratch buffers (rebuilt from scratch at the top
/// of every sweep, so fresh `Default` ones are bit-equivalent).
#[derive(Clone)]
pub struct ShardSnapshot {
    rows: Vec<usize>,
    assign: Vec<u32>,
    clusters: ClusterSet,
    rng: Pcg64,
    theta: f64,
    table_rows: usize,
    /// (proposals, split_accepts, merge_accepts)
    sm_counters: (u64, u64, u64),
    stick_overflows: u64,
    bonus_sweeps: u64,
}

impl ShardSnapshot {
    /// Rebuild a live shard in exactly the captured chain state. The
    /// scoring dispatch comes back in its initial mode — callers that
    /// run a non-default [`ScoreMode`] must re-apply it via
    /// [`Shard::set_score_mode`] (which consumes no randomness).
    pub fn restore(&self) -> Shard {
        let mut sh = Shard {
            rows: self.rows.clone(),
            assign: self.assign.clone(),
            clusters: self.clusters.clone(),
            rng: self.rng.clone(),
            theta: self.theta,
            scoring: ScoreMode::initial_dispatch(self.table_rows),
            table_rows: self.table_rows,
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
            walker: WalkerScratch::default(),
            sm: SplitMergeScratch::default(),
            stick_overflows: self.stick_overflows,
            bonus_sweeps: self.bonus_sweeps,
        };
        sh.sm.proposals = self.sm_counters.0;
        sh.sm.split_accepts = self.sm_counters.1;
        sh.sm.merge_accepts = self.sm_counters.2;
        sh
    }
}

impl Shard {
    /// Capture a [`ShardSnapshot`] of the current chain state (see its
    /// docs for exactly what is and isn't carried).
    pub fn snapshot(&self) -> ShardSnapshot {
        ShardSnapshot {
            rows: self.rows.clone(),
            assign: self.assign.clone(),
            clusters: self.clusters.clone(),
            rng: self.rng.clone(),
            theta: self.theta,
            table_rows: self.table_rows,
            sm_counters: (self.sm.proposals, self.sm.split_accepts, self.sm.merge_accepts),
            stick_overflows: self.stick_overflows,
            bonus_sweeps: self.bonus_sweeps,
        }
    }

    /// Initialize by a sequential draw from the local CRP(θ) prior — the
    /// paper's §5 initialization ("initialize the clustering via a draw
    /// from the prior using the local Chinese restaurant process"). The
    /// draw consumes the shard's private stream.
    pub fn init_from_prior<'a>(
        data: impl Into<DataRef<'a>>,
        rows: Vec<usize>,
        theta: f64,
        rng: Pcg64,
    ) -> Shard {
        let data = data.into();
        let n = rows.len();
        let mut sh = Shard {
            rows,
            assign: vec![0; n],
            clusters: ClusterSet::new(data.dims()),
            rng,
            theta,
            scoring: ScoreMode::initial_dispatch(data.table_rows()),
            table_rows: data.table_rows(),
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
            walker: WalkerScratch::default(),
            sm: SplitMergeScratch::default(),
            stick_overflows: 0,
            bonus_sweeps: 0,
        };
        // sequential CRP: P(new) ∝ θ, P(j) ∝ n_j (prior draw — the data
        // likelihood enters only through subsequent kernel sweeps)
        for i in 0..n {
            let r = sh.rows[i];
            sh.scratch_ids.clear();
            sh.scratch_logw.clear();
            for (slot, c) in sh.clusters.iter() {
                sh.scratch_ids.push(slot as u32);
                sh.scratch_logw.push((c.n() as f64).ln());
            }
            sh.scratch_ids.push(u32::MAX);
            sh.scratch_logw.push(theta.max(1e-300).ln());
            let pick = categorical_log(&mut sh.rng, &sh.scratch_logw);
            let slot = sh.place_pick(pick, data, r);
            sh.assign[i] = slot;
        }
        sh
    }

    /// Initialize with every resident row in a single cluster (worst-case
    /// start, used by convergence tests).
    pub fn init_single_cluster<'a>(
        data: impl Into<DataRef<'a>>,
        rows: Vec<usize>,
        theta: f64,
        rng: Pcg64,
    ) -> Shard {
        let data = data.into();
        let n = rows.len();
        let mut clusters = ClusterSet::new(data.dims());
        if n > 0 {
            let mut c = ClusterStats::empty(data.dims());
            for &r in &rows {
                c.add(data, r);
            }
            clusters.insert(c);
        }
        Shard {
            rows,
            assign: vec![0; n],
            clusters,
            rng,
            theta,
            scoring: ScoreMode::initial_dispatch(data.table_rows()),
            table_rows: data.table_rows(),
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
            walker: WalkerScratch::default(),
            sm: SplitMergeScratch::default(),
            stick_overflows: 0,
            bonus_sweeps: 0,
        }
    }

    /// Rebuild a shard from persisted (rows, assign) — cluster stats are
    /// recomputed from the data (checkpoint resume). `theta` is set by
    /// the owner before the next sweep.
    pub fn from_parts<'a>(
        data: impl Into<DataRef<'a>>,
        rows: Vec<usize>,
        assign: Vec<u32>,
        rng: Pcg64,
    ) -> Result<Shard, String> {
        let data = data.into();
        if rows.len() != assign.len() {
            return Err("rows/assign length mismatch".into());
        }
        let nslots = assign.iter().map(|&a| a as usize + 1).max().unwrap_or(0);
        let mut slots: Vec<Option<ClusterStats>> = (0..nslots).map(|_| None).collect();
        for (i, &slot) in assign.iter().enumerate() {
            let c = slots[slot as usize].get_or_insert_with(|| ClusterStats::empty(data.dims()));
            if rows[i] >= data.rows() {
                return Err(format!("row id {} out of range", rows[i]));
            }
            c.add(data, rows[i]);
        }
        Ok(Shard {
            rows,
            assign,
            clusters: ClusterSet::from_slots(slots, data.dims()),
            rng,
            theta: 0.0,
            scoring: ScoreMode::initial_dispatch(data.table_rows()),
            table_rows: data.table_rows(),
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
            walker: WalkerScratch::default(),
            sm: SplitMergeScratch::default(),
            stick_overflows: 0,
            bonus_sweeps: 0,
        })
    }

    /// Resolve a categorical pick over `scratch_ids` (sentinel `u32::MAX`
    /// = "new table") into a cluster slot and add datum `r` to it.
    pub(crate) fn place_pick(&mut self, pick: usize, data: DataRef<'_>, r: usize) -> u32 {
        let slot = if self.scratch_ids[pick] == u32::MAX {
            self.clusters.alloc_empty()
        } else {
            self.scratch_ids[pick] as usize
        };
        self.clusters.add_row(slot, data, r);
        slot as u32
    }

    /// Set the concentration for subsequent kernel sweeps.
    pub fn set_theta(&mut self, theta: f64) {
        self.theta = theta;
    }

    /// Run `n` consecutive kernel sweeps over this shard's data. This is
    /// the re-enterable sweep entry the concurrent coordinator uses: a
    /// shard's base sweeps and any mid-round bonus grants are separate
    /// `run_sweeps` calls (possibly on different pool threads), and
    /// because every sweep consumes only the shard's **private** RNG
    /// stream, the resulting shard state is a pure function of how many
    /// sweeps ran — independent of which thread ran them or how the
    /// calls interleaved with other shards' work.
    pub fn run_sweeps<'a>(
        &mut self,
        kernel: &dyn super::kernel::TransitionKernel,
        data: impl Into<DataRef<'a>>,
        model: &Model,
        n: usize,
    ) {
        let data = data.into();
        for _ in 0..n {
            kernel.sweep(self, data, model);
        }
    }

    /// Select how kernel sweeps score candidate clusters (scalar
    /// reference vs batched Scorer path). Consumes no randomness, so it
    /// never perturbs the chain's RNG streams.
    pub fn set_score_mode(&mut self, mode: ScoreMode) {
        self.scoring = mode.dispatch(self.table_rows);
    }

    /// Display name of the active scoring dispatch.
    pub fn score_dispatch_name(&self) -> &'static str {
        self.scoring.name()
    }

    /// Select the packed-table refresh policy of the batched dispatch:
    /// `true` re-packs the held-out column every datum (the
    /// pre-incremental engine, kept as a bench comparator and drift
    /// oracle), `false` (default) refreshes a column only when a datum
    /// actually moves cluster. Both policies produce bit-identical
    /// chains (asserted in `rust/tests/scorer_equivalence.rs`); no-op
    /// under the scalar dispatch. Survives until the next
    /// [`Self::set_score_mode`] call.
    pub fn set_eager_repack(&mut self, eager: bool) {
        if let ScoreDispatch::Batched { tables, .. } = &mut self.scoring {
            tables.eager = eager;
        }
    }

    /// Whether the batched dispatch is in the eager per-datum repack
    /// reference mode (see [`Self::set_eager_repack`]).
    #[inline]
    pub(crate) fn scoring_eager(&self) -> bool {
        matches!(&self.scoring, ScoreDispatch::Batched { tables, .. } if tables.eager)
    }

    /// Split–merge move-layer counters for this shard:
    /// `(proposals, accepted splits, accepted merges)`. All zero unless
    /// the shard runs one of the [`crate::sampler::SplitMerge`]
    /// composites (`split_merge:gibbs` / `split_merge:walker`). The MH
    /// acceptance rate of the global moves is
    /// `(splits + merges) / proposals` — the observable for tuning the
    /// composite on a workload.
    pub fn split_merge_stats(&self) -> (u64, u64, u64) {
        (self.sm.proposals, self.sm.split_accepts, self.sm.merge_accepts)
    }

    /// Times a Walker sweep on this shard hit its stick-extension budget
    /// before the leftover stick mass fell below the smallest slice (the
    /// eligible candidate sets of that sweep may have been truncated).
    /// Always 0 for healthy θ; see the budget note on
    /// [`crate::sampler::WalkerSlice`].
    pub fn stick_overflow_events(&self) -> u64 {
        self.stick_overflows
    }

    /// Cumulative work-stealing bonus sweeps this shard has run under
    /// `--overlap on`: extra local kernel sweeps granted to lightly
    /// loaded shards so they work instead of idling at the barrier.
    /// Always 0 with overlap off. Observability only — the counter is
    /// not part of checkpoint state.
    pub fn bonus_sweeps(&self) -> u64 {
        self.bonus_sweeps
    }

    /// Record `n` bonus sweeps granted to this shard this round.
    pub(crate) fn note_bonus_sweeps(&mut self, n: u64) {
        self.bonus_sweeps += n;
    }

    /// Record (and, on first occurrence, log) a Walker stick-budget
    /// exhaustion — the explicit error path replacing the old silent
    /// fixed-iteration cutoff.
    pub(crate) fn note_stick_overflow(
        &mut self,
        theta: f64,
        remaining: f64,
        u_min: f64,
        sticks: usize,
    ) {
        self.stick_overflows += 1;
        if self.stick_overflows == 1 {
            eprintln!(
                "[walker] stick-extension budget exhausted after {sticks} empty sticks at \
                 θ={theta:.3e}: leftover mass {remaining:.3e} still above the smallest slice \
                 {u_min:.3e}; eligible candidate sets may be truncated this sweep (further \
                 occurrences on this shard are counted silently — see \
                 Shard::stick_overflow_events)"
            );
        }
    }

    /// Begin-of-sweep hook for the scoring dispatch: (re)size the packed
    /// tables and enqueue every column for refresh.
    pub(crate) fn scoring_begin_sweep(&mut self) {
        if let ScoreDispatch::Batched { tables, .. } = &mut self.scoring {
            tables.begin_sweep(self.clusters.num_slots());
        }
    }

    /// Membership of `slot` changed under a real move: enqueue its
    /// packed column for refresh. Kernels call this only when a datum
    /// actually changed cluster (or a slot was re-allocated) — the
    /// self-move common case restores the sufficient statistics exactly
    /// and therefore needs no table work at all.
    #[inline]
    pub(crate) fn scoring_invalidate(&mut self, slot: usize) {
        if let ScoreDispatch::Batched { tables, .. } = &mut self.scoring {
            tables.invalidate(slot);
        }
    }

    /// Fill `scratch_ids`/`scratch_logw` with `(slot, ln n_j + ln p(x_r |
    /// cluster))` for every live cluster in slot order, through the
    /// configured dispatch. Both scratch vectors are cleared first; the
    /// kernel appends its own new-table candidate afterwards.
    ///
    /// `held_out` names the cluster datum `r` was just removed from (if
    /// it survived the removal): its packed column still holds the
    /// full-membership table, so under the incremental batched dispatch
    /// its weight is computed from the decremented `ClusterStats` cache
    /// instead — the exact scalar-path value. Every other column is
    /// untouched by the removal and is scored straight from the block.
    pub(crate) fn score_crp_candidates(
        &mut self,
        data: DataRef<'_>,
        r: usize,
        model: &Model,
        held_out: Option<usize>,
    ) {
        self.scratch_ids.clear();
        self.scratch_logw.clear();
        if let Some(bits) = data.bits() {
            // decode the datum's set bits ONCE; every dispatch scores all
            // local clusters from the same index list
            self.scratch_ones.clear();
            bits.for_each_one(r, |d| self.scratch_ones.push(d as u32));
            match &mut self.scoring {
                ScoreDispatch::Scalar => {
                    for (slot, c) in self.clusters.iter_mut() {
                        self.scratch_ids.push(slot as u32);
                        self.scratch_logw
                            .push(c.log_n() + c.score_ones(model, &self.scratch_ones));
                    }
                }
                ScoreDispatch::Batched { scorer, tables } => {
                    // Columns are indexed by slot id and the slot vector
                    // never shrinks, so after a transient cluster peak the
                    // block would keep scoring mostly-dead columns. When
                    // live clusters are a small fraction of a LARGE column
                    // capacity, score them directly from the same caches —
                    // bit-identical values, purely a cost cutover (the size
                    // floor keeps small workloads, and every test regime,
                    // on the block path).
                    if tables.stride > 32 && self.clusters.num_active() * 4 < tables.stride {
                        for (slot, c) in self.clusters.iter_mut() {
                            self.scratch_ids.push(slot as u32);
                            self.scratch_logw
                                .push(c.log_n() + c.score_ones(model, &self.scratch_ones));
                        }
                        return;
                    }
                    let table_skip = tables.resolve_held_out(held_out);
                    self.clusters.refresh_packed(model, tables, table_skip);
                    tables.score_row_ones(scorer.as_mut(), &self.scratch_ones);
                    for (slot, c) in self.clusters.iter_mut() {
                        self.scratch_ids.push(slot as u32);
                        let w = if Some(slot) == table_skip {
                            // held-out correction: same code path (and bits)
                            // as the scalar reference for this one cluster
                            c.log_n() + c.score_ones(model, &self.scratch_ones)
                        } else {
                            tables.logn[slot] + tables.scores[slot]
                        };
                        self.scratch_logw.push(w);
                    }
                }
            }
        } else {
            // dense real row: same dispatch structure, moment-cache
            // scalar scoring vs the two-plane packed block
            let row = data.real().expect("bit-less data kind must be real").row(r);
            match &mut self.scoring {
                ScoreDispatch::Scalar => {
                    for (slot, c) in self.clusters.iter_mut() {
                        self.scratch_ids.push(slot as u32);
                        self.scratch_logw.push(c.log_n() + c.score_real(model, row));
                    }
                }
                ScoreDispatch::Batched { scorer, tables } => {
                    // same live-fraction cost cutover as the bit path
                    if tables.stride > 32 && self.clusters.num_active() * 4 < tables.stride {
                        for (slot, c) in self.clusters.iter_mut() {
                            self.scratch_ids.push(slot as u32);
                            self.scratch_logw.push(c.log_n() + c.score_real(model, row));
                        }
                        return;
                    }
                    let table_skip = tables.resolve_held_out(held_out);
                    self.clusters.refresh_packed(model, tables, table_skip);
                    tables.score_row_real(scorer.as_mut(), row);
                    for (slot, c) in self.clusters.iter_mut() {
                        self.scratch_ids.push(slot as u32);
                        let w = if Some(slot) == table_skip {
                            c.log_n() + c.score_real(model, row)
                        } else {
                            tables.logn[slot] + tables.scores[slot]
                        };
                        self.scratch_logw.push(w);
                    }
                }
            }
        }
    }

    /// Append the log-likelihood of row `r` under each requested slot to
    /// `out` (`u32::MAX` = an unmaterialized table, scored by the
    /// model's empty-cluster predictive), through the configured
    /// dispatch — under the batched dispatch this is one block
    /// evaluation per call, with the `held_out` cluster (the one datum
    /// `r` just left) corrected from its decremented `ClusterStats`
    /// cache exactly as in [`Self::score_crp_candidates`].
    pub(crate) fn score_slots_for_row(
        &mut self,
        data: DataRef<'_>,
        r: usize,
        model: &Model,
        slots: &[u32],
        held_out: Option<usize>,
        out: &mut Vec<f64>,
    ) {
        let empty_loglik = model.log_pred_empty(data, r);
        match &mut self.scoring {
            ScoreDispatch::Scalar => {
                for &s in slots {
                    out.push(if s == u32::MAX {
                        empty_loglik
                    } else {
                        self.clusters.score_slot(s as usize, model, data, r)
                    });
                }
            }
            ScoreDispatch::Batched { scorer, tables } => {
                // The dense block pays only when the candidate set is a
                // decent fraction of the live clusters. Tiny eligible
                // sets on LARGE shards (Walker's common regime once
                // slices tighten) score directly from the same
                // per-cluster caches the block would be packed from —
                // bit-identical values, purely a cost cutover; the size
                // floor keeps small workloads, and every test regime,
                // on the block path.
                if self.clusters.num_active() > 32 && slots.len() * 4 < self.clusters.num_active()
                {
                    for &s in slots {
                        out.push(if s == u32::MAX {
                            empty_loglik
                        } else {
                            self.clusters.score_slot(s as usize, model, data, r)
                        });
                    }
                    return;
                }
                let table_skip = tables.resolve_held_out(held_out);
                self.clusters.refresh_packed(model, tables, table_skip);
                if let Some(bits) = data.bits() {
                    self.scratch_ones.clear();
                    bits.for_each_one(r, |d| self.scratch_ones.push(d as u32));
                    tables.score_row_ones(scorer.as_mut(), &self.scratch_ones);
                } else {
                    let row = data.real().expect("bit-less data kind must be real").row(r);
                    tables.score_row_real(scorer.as_mut(), row);
                }
                for &s in slots {
                    out.push(if s == u32::MAX {
                        empty_loglik
                    } else if Some(s as usize) == table_skip {
                        self.clusters.score_slot(s as usize, model, data, r)
                    } else {
                        tables.scores[s as usize]
                    });
                }
            }
        }
    }

    /// The concentration θ the kernel sweeps with (α serial, α·μ_k parallel).
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Number of live (non-empty) clusters on this shard.
    pub fn num_clusters(&self) -> usize {
        self.clusters.num_active()
    }

    /// Number of data rows resident on this shard.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Global ids of the rows resident on this shard.
    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    /// The slotted cluster store (read-only view).
    pub fn cluster_set(&self) -> &ClusterSet {
        &self.clusters
    }

    /// Live cluster stats in slot order.
    pub fn clusters(&self) -> impl Iterator<Item = &ClusterStats> {
        self.clusters.iter().map(|(_, c)| c)
    }

    /// Live clusters with their slots, in slot order.
    pub fn active_clusters(&self) -> impl Iterator<Item = (usize, &ClusterStats)> {
        self.clusters.iter()
    }

    /// Append this shard's live clusters as serving-table columns to a
    /// [`TableSetBuilder`](super::score::TableSetBuilder), in slot
    /// order — the round-boundary snapshot-export hook of the serving
    /// layer ([`crate::serve`]). `&mut self` only because the
    /// per-cluster predictive caches are (re)built on demand
    /// ([`ClusterStats::cached_table`]); no RNG is consumed and no
    /// chain state changes, so exporting is invisible to the sampler's
    /// draw sequence.
    pub(crate) fn export_table_columns(
        &mut self,
        model: &Model,
        out: &mut super::score::TableSetBuilder,
    ) {
        for (_slot, c) in self.clusters.iter_mut() {
            let ln_n = c.log_n();
            let n = c.n();
            let (bias, _aux, dtab) = c.cached_table(model);
            out.push_column(bias, ln_n, n, dtab);
        }
    }

    /// Local cluster-slot assignment per resident row (aligned with
    /// [`Self::rows`]; for the serial whole-dataset shard this IS the
    /// global assignment vector).
    pub fn assignments_local(&self) -> &[u32] {
        &self.assign
    }

    /// Push (n_j, c_jd) for every local cluster into `out` (reduce-step
    /// sufficient statistics for dimension `d`).
    pub fn collect_dim_stats(&self, d: usize, out: &mut Vec<(u64, u32)>) {
        self.clusters.collect_dim_stats(d, out);
    }

    /// Drop every per-cluster score cache (call after β changes).
    pub fn invalidate_caches(&mut self) {
        self.clusters.invalidate_caches();
    }

    /// Remove and return every cluster as (stats, member-row-ids); leaves
    /// this shard empty. Used by the coordinator's shuffle step.
    pub fn drain_clusters(&mut self) -> Vec<(ClusterStats, Vec<usize>)> {
        let nslots = self.clusters.num_slots();
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); nslots];
        for (i, &slot) in self.assign.iter().enumerate() {
            members[slot as usize].push(self.rows[i]);
        }
        let mut out = Vec::new();
        for (slot, c) in self.clusters.take_all().into_iter().enumerate() {
            if let Some(c) = c {
                out.push((c, std::mem::take(&mut members[slot])));
            }
        }
        self.rows.clear();
        self.assign.clear();
        out
    }

    /// Insert a cluster (stats + member rows) into this shard.
    pub fn insert_cluster(&mut self, stats: ClusterStats, member_rows: Vec<usize>) {
        debug_assert_eq!(stats.n() as usize, member_rows.len());
        let slot = self.clusters.insert(stats);
        for r in member_rows {
            self.rows.push(r);
            self.assign.push(slot as u32);
        }
    }

    /// Write this shard's assignments into the global z vector with
    /// globally-unique ids starting at `next_id`; returns the next free id.
    pub fn export_assignments(&self, z: &mut [u32], mut next_id: u32) -> u32 {
        let mut slot_to_id: Vec<Option<u32>> = vec![None; self.clusters.num_slots()];
        for (i, &slot) in self.assign.iter().enumerate() {
            let id = *slot_to_id[slot as usize].get_or_insert_with(|| {
                let id = next_id;
                next_id += 1;
                id
            });
            z[self.rows[i]] = id;
        }
        next_id
    }

    /// Append `ln(n_j/(N+α)) + ln p(x_r | cluster)` for every local
    /// cluster (mutable for the score cache).
    pub fn score_against_all<'a>(
        &mut self,
        model: &Model,
        test: impl Into<DataRef<'a>>,
        r: usize,
        n_total: f64,
        out: &mut Vec<f64>,
    ) {
        let test = test.into();
        for (_, c) in self.clusters.iter_mut() {
            out.push((c.n() as f64 / n_total).ln() + c.score(model, test, r));
        }
    }

    /// Occupied cluster slots in order of first appearance along the
    /// shard's datum sequence (the labeling under which Pitman's
    /// size-biased stick posterior applies — see the Walker kernel).
    /// Fills caller-owned buffers so the Walker sweep stays
    /// allocation-free after warm-up.
    pub(crate) fn slots_by_appearance_into(&self, seen: &mut Vec<bool>, out: &mut Vec<usize>) {
        out.clear();
        seen.clear();
        seen.resize(self.clusters.num_slots(), false);
        for &slot in &self.assign {
            let s = slot as usize;
            if !seen[s] {
                seen[s] = true;
                out.push(s);
            }
        }
    }

    /// Integrity check: stats match the member rows (bit counts exactly;
    /// real-valued moments to fp tolerance, since incremental add/remove
    /// accumulates round-off a fresh rebuild doesn't), the slot
    /// machinery is consistent.
    pub fn check_invariants<'a>(&self, data: impl Into<DataRef<'a>>) -> Result<(), String> {
        let data = data.into();
        if self.rows.len() != self.assign.len() {
            return Err("rows/assign length mismatch".into());
        }
        self.clusters.check_slot_invariants()?;
        let nslots = self.clusters.num_slots();
        let mut rebuilt: Vec<ClusterStats> =
            (0..nslots).map(|_| ClusterStats::empty(data.dims())).collect();
        for (i, &slot) in self.assign.iter().enumerate() {
            let slot = slot as usize;
            if slot >= nslots || self.clusters.get(slot).is_none() {
                return Err(format!("row idx {i} assigned to dead slot {slot}"));
            }
            rebuilt[slot].add(data, self.rows[i]);
        }
        // moment vectors are sized lazily, so compare by index with an
        // implicit 0.0 past either end
        let moments_close = |a: &[f64], b: &[f64]| {
            (0..a.len().max(b.len())).all(|i| {
                let x = a.get(i).copied().unwrap_or(0.0);
                let y = b.get(i).copied().unwrap_or(0.0);
                (x - y).abs() <= 1e-6 * (1.0 + x.abs().max(y.abs()))
            })
        };
        for (slot, c) in self.clusters.iter() {
            if c.n() != rebuilt[slot].n() {
                return Err(format!("slot {slot} count mismatch"));
            }
            let ok = if data.bits().is_some() {
                c.ones() == rebuilt[slot].ones()
            } else {
                moments_close(c.sum(), rebuilt[slot].sum())
                    && moments_close(c.sumsq(), rebuilt[slot].sumsq())
            };
            if !ok {
                return Err(format!("slot {slot} stats mismatch"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;
    use crate::sampler::kernel::{CollapsedGibbs, TransitionKernel};

    fn make_shard(seed: u64) -> (crate::data::Dataset, Shard, Model) {
        let ds = SyntheticConfig {
            n: 200,
            d: 16,
            clusters: 4,
            beta: 0.1,
            seed,
        }
        .generate_with_test_fraction(0.0);
        let model = Model::bernoulli(16, 0.5);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let st = Shard::init_from_prior(&ds.train, rows, 1.0, Pcg64::seed_from(seed));
        (ds, st, model)
    }

    #[test]
    fn init_and_sweeps_preserve_invariants() {
        let (ds, mut st, model) = make_shard(1);
        st.check_invariants(&ds.train).unwrap();
        for _ in 0..3 {
            CollapsedGibbs.sweep(&mut st, (&ds.train).into(), &model);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 200);
    }

    #[test]
    fn drain_insert_roundtrip() {
        let (ds, mut st, _model) = make_shard(2);
        let nc = st.num_clusters();
        let nr = st.num_rows();
        let drained = st.drain_clusters();
        assert_eq!(drained.len(), nc);
        assert_eq!(st.num_rows(), 0);
        for (stats, rows) in drained {
            st.insert_cluster(stats, rows);
        }
        assert_eq!(st.num_clusters(), nc);
        assert_eq!(st.num_rows(), nr);
        st.check_invariants(&ds.train).unwrap();
    }

    #[test]
    fn export_assignments_unique_ids() {
        let (ds, st, _model) = make_shard(3);
        let mut z = vec![u32::MAX; ds.train.rows()];
        let next = st.export_assignments(&mut z, 5);
        assert_eq!(next as usize, 5 + st.num_clusters());
        assert!(z.iter().all(|&id| id >= 5 && id < next));
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, mut a, model) = make_shard(4);
        let (_, mut b, _) = make_shard(4);
        a.set_theta(0.7);
        b.set_theta(0.7);
        for _ in 0..2 {
            CollapsedGibbs.sweep(&mut a, (&ds.train).into(), &model);
            CollapsedGibbs.sweep(&mut b, (&ds.train).into(), &model);
        }
        let mut za = vec![0u32; ds.train.rows()];
        let mut zb = vec![0u32; ds.train.rows()];
        a.export_assignments(&mut za, 0);
        b.export_assignments(&mut zb, 0);
        assert_eq!(za, zb);
    }

    #[test]
    fn single_cluster_init_counts() {
        let ds = SyntheticConfig {
            n: 50,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 5,
        }
        .generate_with_test_fraction(0.0);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let st = Shard::init_single_cluster(&ds.train, rows, 1.0, Pcg64::seed_from(5));
        assert_eq!(st.num_clusters(), 1);
        st.check_invariants(&ds.train).unwrap();
        let (_, c) = st.active_clusters().next().unwrap();
        assert_eq!(c.n() as usize, ds.train.rows());
    }

    #[test]
    fn snapshot_restore_replays_sweeps_bit_exactly() {
        // the retry-from-snapshot guarantee: snapshot, sweep the live
        // shard, then restore and sweep the SAME number of times — both
        // lineages must land in the identical chain state (assignments
        // and subsequent RNG draws alike)
        let (ds, mut st, model) = make_shard(7);
        st.set_theta(0.9);
        let snap = st.snapshot();
        for _ in 0..3 {
            CollapsedGibbs.sweep(&mut st, (&ds.train).into(), &model);
        }
        let mut replay = snap.restore();
        for _ in 0..3 {
            CollapsedGibbs.sweep(&mut replay, (&ds.train).into(), &model);
        }
        let mut za = vec![0u32; ds.train.rows()];
        let mut zb = vec![0u32; ds.train.rows()];
        st.export_assignments(&mut za, 0);
        replay.export_assignments(&mut zb, 0);
        assert_eq!(za, zb);
        // the private streams stay aligned past the replay
        assert_eq!(st.rng.next_u64(), replay.rng.next_u64());
        replay.check_invariants(&ds.train).unwrap();
    }

    #[test]
    fn snapshot_restore_is_identity_without_sweeps() {
        let (ds, st, _model) = make_shard(8);
        let restored = st.snapshot().restore();
        assert_eq!(restored.rows, st.rows);
        assert_eq!(restored.assign, st.assign);
        assert_eq!(restored.theta(), st.theta());
        assert_eq!(restored.num_clusters(), st.num_clusters());
        assert_eq!(restored.bonus_sweeps(), st.bonus_sweeps());
        restored.check_invariants(&ds.train).unwrap();
    }

    #[test]
    fn from_parts_rejects_corrupt_input() {
        let ds = SyntheticConfig {
            n: 20,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        assert!(Shard::from_parts(&ds.train, vec![0, 1], vec![0], Pcg64::seed_from(1)).is_err());
        assert!(Shard::from_parts(&ds.train, vec![999], vec![0], Pcg64::seed_from(1)).is_err());
        let ok = Shard::from_parts(&ds.train, vec![0, 1], vec![0, 0], Pcg64::seed_from(1)).unwrap();
        ok.check_invariants(&ds.train).unwrap();
        assert_eq!(ok.num_clusters(), 1);
    }
}
