//! Griddy-Gibbs kernel (Ritter & Tanner 1992) — the paper's update for
//! the per-dimension base-measure hyperparameters `β_d` (§6): evaluate the
//! conditional log-density on a fixed grid, exp-normalize, sample a grid
//! cell, then jitter uniformly within the cell.

use super::pcg::Pcg64;
use crate::special::exp_normalize;

/// A reusable griddy-Gibbs sampler over a fixed log-spaced or linear grid.
#[derive(Debug, Clone)]
pub struct GriddyGibbs {
    grid: Vec<f64>,
    /// scratch buffer for log-densities (reused across calls)
    logp: Vec<f64>,
}

impl GriddyGibbs {
    /// Linear grid of `n` points on [lo, hi].
    pub fn linear(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2 && hi > lo);
        let grid = (0..n)
            .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
            .collect();
        GriddyGibbs {
            grid,
            logp: vec![0.0; n],
        }
    }

    /// Log-spaced grid of `n` points on [lo, hi] (both > 0) — the natural
    /// choice for scale-like hyperparameters such as β_d.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2 && hi > lo && lo > 0.0);
        let (ll, lh) = (lo.ln(), hi.ln());
        let grid = (0..n)
            .map(|i| (ll + (lh - ll) * i as f64 / (n - 1) as f64).exp())
            .collect();
        GriddyGibbs {
            grid,
            logp: vec![0.0; n],
        }
    }

    /// The grid points the sampler evaluates over.
    pub fn grid(&self) -> &[f64] {
        &self.grid
    }

    /// Draw one sample: evaluate `logf` at every grid point, normalize,
    /// pick a cell, jitter uniformly to the midpoint of the neighbouring
    /// cells. Invariant for the grid-discretized density (the paper's
    /// kernel; exactness at the grid resolution).
    pub fn sample(&mut self, rng: &mut Pcg64, logf: impl Fn(f64) -> f64) -> f64 {
        for (i, &g) in self.grid.iter().enumerate() {
            self.logp[i] = logf(g);
        }
        exp_normalize(&mut self.logp);
        let total: f64 = self.logp.iter().sum();
        let mut u = rng.next_f64() * total;
        let mut idx = self.logp.len() - 1;
        for (i, &p) in self.logp.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                idx = i;
                break;
            }
        }
        // jitter within the cell bounds (half-way to neighbours)
        let lo = if idx == 0 {
            self.grid[0]
        } else {
            0.5 * (self.grid[idx - 1] + self.grid[idx])
        };
        let hi = if idx + 1 == self.grid.len() {
            self.grid[idx]
        } else {
            0.5 * (self.grid[idx] + self.grid[idx + 1])
        };
        lo + rng.next_f64() * (hi - lo)
    }

    /// Posterior mean on the grid (deterministic summary, used in tests).
    pub fn grid_mean(&mut self, logf: impl Fn(f64) -> f64) -> f64 {
        for (i, &g) in self.grid.iter().enumerate() {
            self.logp[i] = logf(g);
        }
        exp_normalize(&mut self.logp);
        self.grid
            .iter()
            .zip(&self.logp)
            .map(|(&g, &p)| g * p)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    #[test]
    fn grids_are_monotone_and_bounded() {
        let g = GriddyGibbs::linear(0.0, 1.0, 11);
        assert_eq!(g.grid().len(), 11);
        assert!((g.grid()[5] - 0.5).abs() < 1e-12);
        let lg = GriddyGibbs::log_spaced(0.01, 100.0, 9);
        assert!((lg.grid()[4] - 1.0).abs() < 1e-9); // geometric midpoint
        assert!(lg.grid().windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn samples_concentrate_on_target_mode() {
        // target ∝ exp(-(x-2)^2 / 0.02): sharp peak at 2
        let mut g = GriddyGibbs::linear(0.0, 4.0, 201);
        let mut rng = Pcg64::seed_from(1);
        let xs: Vec<f64> = (0..5_000)
            .map(|_| g.sample(&mut rng, |x| -(x - 2.0) * (x - 2.0) / 0.02))
            .collect();
        assert!((mean(&xs) - 2.0).abs() < 0.02, "mean {}", mean(&xs));
    }

    #[test]
    fn grid_mean_matches_analytic() {
        // Beta(2,2) on [0,1]: mean 0.5
        let mut g = GriddyGibbs::linear(1e-6, 1.0 - 1e-6, 501);
        let m = g.grid_mean(|x| x.ln() + (1.0 - x).ln());
        assert!((m - 0.5).abs() < 1e-3, "mean {m}");
    }

    #[test]
    fn log_spaced_sampling_recovers_scale() {
        // target: lognormal centred at ln 1.0 with sd 0.25
        let mut g = GriddyGibbs::log_spaced(0.01, 100.0, 301);
        let mut rng = Pcg64::seed_from(2);
        let xs: Vec<f64> = (0..5_000)
            .map(|_| {
                g.sample(&mut rng, |x| {
                    let l = x.ln();
                    -l * l / (2.0 * 0.25 * 0.25) - l // includes Jacobian-free density on x
                })
            })
            .collect();
        let lmean = mean(&xs.iter().map(|x| x.ln()).collect::<Vec<_>>());
        assert!(lmean.abs() < 0.35, "log-mean {lmean}");
    }
}
