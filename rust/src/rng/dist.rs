//! Distribution samplers over [`Pcg64`]: Normal (Marsaglia polar), Gamma
//! (Marsaglia–Tsang with the α<1 boost), Beta, Dirichlet, Bernoulli, and
//! categorical sampling from (log-)weights — the building blocks of every
//! transition operator in the paper.

use super::pcg::Pcg64;
use crate::special::logsumexp;

/// Standard normal via Marsaglia's polar method.
pub fn normal(rng: &mut Pcg64) -> f64 {
    loop {
        let u = 2.0 * rng.next_f64() - 1.0;
        let v = 2.0 * rng.next_f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// Gamma(shape α, scale 1) via Marsaglia & Tsang (2000); α < 1 handled by
/// the standard U^{1/α} boost.
pub fn gamma(rng: &mut Pcg64, alpha: f64) -> f64 {
    assert!(alpha > 0.0, "gamma shape must be positive, got {alpha}");
    if alpha < 1.0 {
        // G(α) = G(α+1) · U^{1/α}
        let u = rng.next_f64_open();
        return gamma(rng, alpha + 1.0) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = normal(rng);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v = v * v * v;
        let u = rng.next_f64_open();
        let x2 = x * x;
        if u < 1.0 - 0.0331 * x2 * x2 {
            return d * v;
        }
        if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Beta(a, b) as Ga/(Ga+Gb).
pub fn beta(rng: &mut Pcg64, a: f64, b: f64) -> f64 {
    let x = gamma(rng, a);
    let y = gamma(rng, b);
    x / (x + y)
}

/// Dirichlet(αs) via normalized Gammas. Returns a probability vector.
pub fn dirichlet(rng: &mut Pcg64, alphas: &[f64]) -> Vec<f64> {
    assert!(!alphas.is_empty());
    let mut g: Vec<f64> = alphas.iter().map(|&a| gamma(rng, a)).collect();
    let s: f64 = g.iter().sum();
    if s <= 0.0 {
        // all-tiny shapes can underflow; fall back to a one-hot at the
        // largest shape (the distribution's own degenerate limit)
        let k = crate::util::argmax(alphas);
        g.iter_mut().for_each(|x| *x = 0.0);
        g[k] = 1.0;
        return g;
    }
    g.iter_mut().for_each(|x| *x /= s);
    g
}

/// Bernoulli(p) draw.
pub fn bernoulli(rng: &mut Pcg64, p: f64) -> bool {
    rng.next_f64() < p
}

/// Categorical draw from *unnormalized probabilities* (linear scale).
pub fn categorical(rng: &mut Pcg64, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    assert!(
        total > 0.0 && total.is_finite(),
        "categorical needs positive finite total, got {total}"
    );
    let mut u = rng.next_f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1 // floating-point tail
}

/// Categorical draw from *log*-weights, destroying the buffer: max-shift,
/// exp in place, then one linear sampling pass — half the `exp` calls of
/// [`categorical_log`]. The Gibbs hot loop owns its scratch buffer, so
/// the destruction is free (perf: see EXPERIMENTS.md §Perf).
pub fn categorical_log_inplace(rng: &mut Pcg64, logw: &mut [f64]) -> usize {
    let m = logw.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    assert!(m.is_finite(), "categorical_log_inplace: all weights are -inf");
    let mut total = 0.0;
    for x in logw.iter_mut() {
        *x = (*x - m).exp();
        total += *x;
    }
    let mut u = rng.next_f64() * total;
    for (i, &w) in logw.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    logw
        .iter()
        .rposition(|&w| w > 0.0)
        .expect("categorical_log_inplace: empty support")
}

/// Categorical draw from *log*-weights (any common offset). Uses a single
/// max-shift + linear pass; robust to −∞ entries (zero probability).
pub fn categorical_log(rng: &mut Pcg64, logw: &[f64]) -> usize {
    let z = logsumexp(logw);
    assert!(z.is_finite(), "categorical_log: all weights are -inf");
    let mut u = rng.next_f64();
    for (i, &lw) in logw.iter().enumerate() {
        u -= (lw - z).exp();
        if u <= 0.0 {
            return i;
        }
    }
    // floating-point tail: return the last non-(-inf) index
    logw.iter()
        .rposition(|&lw| lw > f64::NEG_INFINITY)
        .expect("categorical_log: all weights are -inf")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, variance};

    fn draws(f: impl Fn(&mut Pcg64) -> f64, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seed_from(seed);
        (0..n).map(|_| f(&mut rng)).collect()
    }

    #[test]
    fn normal_moments() {
        let xs = draws(normal, 100_000, 1);
        assert!(mean(&xs).abs() < 0.02);
        assert!((variance(&xs) - 1.0).abs() < 0.03);
    }

    #[test]
    fn gamma_moments_across_shapes() {
        for &a in &[0.3, 0.9, 1.0, 2.5, 10.0, 100.0] {
            let xs = draws(|r| gamma(r, a), 60_000, 2);
            // E = a, Var = a (scale 1)
            assert!(
                (mean(&xs) - a).abs() < 0.05 * a.max(1.0),
                "gamma({a}) mean {}",
                mean(&xs)
            );
            assert!(
                (variance(&xs) - a).abs() < 0.12 * a.max(1.0),
                "gamma({a}) var {}",
                variance(&xs)
            );
            assert!(xs.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn beta_moments() {
        let (a, b) = (2.0, 5.0);
        let xs = draws(|r| beta(r, a, b), 60_000, 3);
        let want_mean = a / (a + b);
        let want_var = a * b / ((a + b) * (a + b) * (a + b + 1.0));
        assert!((mean(&xs) - want_mean).abs() < 0.01);
        assert!((variance(&xs) - want_var).abs() < 0.005);
    }

    #[test]
    fn dirichlet_sums_to_one_with_correct_means() {
        let alphas = [1.0, 2.0, 7.0];
        let mut rng = Pcg64::seed_from(4);
        let n = 30_000;
        let mut acc = [0.0; 3];
        for _ in 0..n {
            let p = dirichlet(&mut rng, &alphas);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            for i in 0..3 {
                acc[i] += p[i];
            }
        }
        let a0: f64 = alphas.iter().sum();
        for i in 0..3 {
            assert!((acc[i] / n as f64 - alphas[i] / a0).abs() < 0.01);
        }
    }

    #[test]
    fn categorical_frequencies() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut rng = Pcg64::seed_from(5);
        let mut counts = [0u64; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[categorical(&mut rng, &w)] += 1;
        }
        for i in 0..4 {
            let p = counts[i] as f64 / n as f64;
            assert!((p - w[i] / 10.0).abs() < 0.01, "bucket {i}: {p}");
        }
    }

    #[test]
    fn categorical_log_matches_linear_and_handles_offsets() {
        let w = [0.1f64, 0.6, 0.3];
        let logw: Vec<f64> = w.iter().map(|x| x.ln() - 1234.0).collect();
        let mut rng = Pcg64::seed_from(6);
        let mut counts = [0u64; 3];
        let n = 200_000;
        for _ in 0..n {
            counts[categorical_log(&mut rng, &logw)] += 1;
        }
        for i in 0..3 {
            let p = counts[i] as f64 / n as f64;
            assert!((p - w[i]).abs() < 0.01, "bucket {i}: {p}");
        }
    }

    #[test]
    fn categorical_log_skips_neg_inf() {
        let logw = [f64::NEG_INFINITY, 0.0, f64::NEG_INFINITY];
        let mut rng = Pcg64::seed_from(7);
        for _ in 0..100 {
            assert_eq!(categorical_log(&mut rng, &logw), 1);
        }
    }
}
