//! PCG-XSL-RR 128/64: O'Neill's PCG64 — the workhorse generator.
//!
//! 128-bit LCG state with an xor-shift-low + random-rotate output
//! permutation. Fast, tiny state, excellent statistical quality, and —
//! critical for MCMC reproducibility — trivially seedable and `split`able
//! so every worker/supercluster gets an independent deterministic stream.

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG64 generator (PCG-XSL-RR 128/64 variant).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd stream selector
}

impl Pcg64 {
    /// Seed with explicit state/stream (any values — both get mixed).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        // a few warmup steps decorrelate close seeds
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Convenience single-value seeding (stream 0xda3e39cb94b95bdb).
    pub fn seed_from(seed: u64) -> Self {
        Pcg64::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent generator for worker `id` — used to hand
    /// each supercluster its own stream with deterministic global seeding.
    pub fn split(&mut self, id: u64) -> Pcg64 {
        let s = self.next_u64() ^ (id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        Pcg64::new(s, id.wrapping_add(0x853c_49e6_748f_ea9b))
    }

    /// Next 64 uniform bits (the PCG-XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1) — never exactly 0 (safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        loop {
            let x = self.next_f64();
            if x > 0.0 {
                return x;
            }
        }
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_dependent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        let mut c = Pcg64::new(42, 2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seed_from(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut rng = Pcg64::seed_from(3);
        let mut counts = [0u64; 5];
        let n = 250_000;
        for _ in 0..n {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "bucket p = {p}");
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg64::seed_from(11);
        let mut w0 = root.split(0);
        let mut w1 = root.split(1);
        let a: Vec<u64> = (0..8).map(|_| w0.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| w1.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
