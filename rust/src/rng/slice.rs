//! Univariate slice sampler (Neal 2003) with step-out and shrinkage —
//! the paper's suggested kernel for the centralized concentration update
//! (Eq. 6): "This can be done with slice sampling or adaptive rejection
//! sampling."

use super::pcg::Pcg64;

/// One slice-sampling transition for a log-density `logf`, starting at
/// `x0`, with initial bracket width `w` and a step-out cap of `max_steps`
/// doublings, optionally bounded to `(lo, hi)`.
///
/// Returns the new point; leaves `logf`'s distribution invariant.
pub fn slice_sample(
    rng: &mut Pcg64,
    logf: impl Fn(f64) -> f64,
    x0: f64,
    w: f64,
    max_steps: u32,
    bounds: (f64, f64),
) -> f64 {
    let (lo_b, hi_b) = bounds;
    debug_assert!(x0 > lo_b && x0 < hi_b, "x0 {x0} outside bounds");
    let ly0 = logf(x0);
    assert!(
        ly0.is_finite(),
        "slice_sample: log-density not finite at start ({x0} -> {ly0})"
    );
    // vertical level: ln u + ln f(x0)
    let ly = ly0 + rng.next_f64_open().ln();

    // step out
    let mut l = x0 - w * rng.next_f64();
    let mut r = l + w;
    let mut steps = max_steps;
    while steps > 0 && l > lo_b && logf(l.max(lo_b + f64::MIN_POSITIVE)) > ly {
        l -= w;
        steps -= 1;
    }
    let mut steps = max_steps;
    while steps > 0 && r < hi_b && logf(r.min(hi_b)) > ly {
        r += w;
        steps -= 1;
    }
    l = l.max(lo_b);
    r = r.min(hi_b);

    // shrinkage
    loop {
        let x1 = l + rng.next_f64() * (r - l);
        if logf(x1) > ly {
            return x1;
        }
        if x1 < x0 {
            l = x1;
        } else {
            r = x1;
        }
        if (r - l) < 1e-300 {
            return x0; // pathological shrink: stay put (still invariant)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, variance};

    #[test]
    fn normal_target_moments() {
        // target N(3, 2^2)
        let logf = |x: f64| -0.5 * ((x - 3.0) / 2.0).powi(2);
        let mut rng = Pcg64::seed_from(1);
        let mut x = 0.5;
        let mut xs = Vec::with_capacity(40_000);
        for i in 0..50_000 {
            x = slice_sample(&mut rng, logf, x, 1.0, 64, (f64::NEG_INFINITY, f64::INFINITY));
            if i >= 10_000 {
                xs.push(x);
            }
        }
        assert!((mean(&xs) - 3.0).abs() < 0.1, "mean {}", mean(&xs));
        assert!((variance(&xs) - 4.0).abs() < 0.4, "var {}", variance(&xs));
    }

    #[test]
    fn gamma_target_respects_positive_bound() {
        // target Gamma(3, scale 1): logf = 2 ln x - x
        let logf = |x: f64| if x > 0.0 { 2.0 * x.ln() - x } else { f64::NEG_INFINITY };
        let mut rng = Pcg64::seed_from(2);
        let mut x = 1.0;
        let mut xs = Vec::new();
        for i in 0..60_000 {
            x = slice_sample(&mut rng, logf, x, 1.0, 64, (0.0, f64::INFINITY));
            assert!(x > 0.0);
            if i >= 10_000 {
                xs.push(x);
            }
        }
        assert!((mean(&xs) - 3.0).abs() < 0.15, "mean {}", mean(&xs));
        assert!((variance(&xs) - 3.0).abs() < 0.5, "var {}", variance(&xs));
    }
}
