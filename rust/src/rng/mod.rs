//! Random-number substrate, built from scratch (no `rand` in the offline
//! crate universe): a PCG64 generator plus every sampler the paper's MCMC
//! needs — Gamma/Beta/Dirichlet draws, log-space categorical sampling,
//! univariate slice sampling (for the concentration update, Eq. 6), and a
//! griddy-Gibbs kernel (for the `β_d` hyperparameter update, §6).

pub mod dist;
pub mod griddy;
pub mod pcg;
pub mod slice;

pub use dist::*;
pub use griddy::GriddyGibbs;
pub use pcg::Pcg64;
pub use slice::slice_sample;
