//! Runtime bridge: executes the AOT-compiled JAX/Pallas scoring graphs
//! from the Rust hot path via the PJRT C API (`xla` crate — currently
//! stubbed, see [`pjrt`]; every caller is served by [`FallbackScorer`]).
//!
//! `make artifacts` lowers the Layer-2 entry points to HLO **text**
//! (`artifacts/*.hlo.txt` + `manifest.txt`); [`PjrtScorer`] loads and
//! compiles them once (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile`) and then serves batched scoring with
//! padding/chunking onto the fixed compiled shapes. Padding contracts
//! (verified by the Python L1/L2 tests and the cross-check integration
//! test):
//!
//! * pad dims `d → d_v`: `W1 = W0 = 0` (log 1 — exact no-op);
//! * pad clusters `j → j_v`: `logpi = -1e30` (masked by logsumexp);
//! * pad rows `b → b_v`: zero rows, outputs ignored.
//!
//! [`FallbackScorer`] is the pure-Rust implementation of the identical
//! contract — used when artifacts are absent and as the cross-check
//! oracle in integration tests.
//!
//! Besides trace-time evaluation ([`Scorer::predictive_density`] /
//! [`Scorer::loglik_matrix`]), the trait carries the sweep-side entry
//! points [`Scorer::score_rows_against_clusters`] (row batches) and
//! [`Scorer::score_ones_against_clusters`] (one pre-decoded datum — the
//! kernel hot loop): the sweep packs each shard's cached predictive
//! tables into the `[D, J]` layout and scores a datum's whole candidate
//! set in one batched call, so a PJRT artifact that implements the
//! entry points accelerates the map step itself with zero kernel
//! changes. The pure-Rust evaluation runs through the SIMD-blocked
//! [`accumulate_ones_block`] (bit-identical to the naive loop — see
//! DESIGN.md §8). [`ScorerKind`] is the backend selector both CLI entry
//! points expose as `--scorer`.

pub mod pjrt;

use crate::data::BinMat;
use crate::special::logsumexp;

pub use pjrt::PjrtScorer;

/// Columns per cache tile of the bit-sparse block accumulator: 128 f64
/// columns = 1 KiB per accumulator segment, small enough that a tile of
/// scores stays L1-resident while the set-bit `diff` rows stream
/// through it.
const BLOCK_TILE: usize = 128;

/// Accumulate `block[s] += Σ_{d in ones} diff[d * j + s]` over the
/// first `j` entries of `block` — the bit-sparse inner loop of the
/// sweep-side scoring block.
///
/// The loop is restructured for the autovectorizer: columns are
/// processed in L1-resident tiles of [`BLOCK_TILE`], set bits are
/// consumed in pairs (one accumulator load/store serves two additions),
/// and the per-tile loop is unrolled into four independent f64 lanes.
/// Every column's additions stay in strict ascending-set-bit order —
/// `(block + d1) + d2`, never `block + (d1 + d2)` — so the result is
/// **bit-identical** to the naive one-bit-at-a-time loop, and therefore
/// to the scalar per-cluster reference path that adds the same cached
/// terms in the same order.
///
/// `ones` must hold ascending dim indices with `d * j + j <= diff.len()`
/// for every entry (callers clamp padded dims first).
pub fn accumulate_ones_block(block: &mut [f64], ones: &[u32], diff: &[f64], j: usize) {
    let block = &mut block[..j];
    let mut t0 = 0usize;
    while t0 < j {
        let t1 = (t0 + BLOCK_TILE).min(j);
        let tile = &mut block[t0..t1];
        let w = tile.len();
        let mut k = 0usize;
        while k + 1 < ones.len() {
            let r1 = &diff[ones[k] as usize * j + t0..][..w];
            let r2 = &diff[ones[k + 1] as usize * j + t0..][..w];
            let mut i = 0usize;
            while i + 4 <= w {
                tile[i] = (tile[i] + r1[i]) + r2[i];
                tile[i + 1] = (tile[i + 1] + r1[i + 1]) + r2[i + 1];
                tile[i + 2] = (tile[i + 2] + r1[i + 2]) + r2[i + 2];
                tile[i + 3] = (tile[i + 3] + r1[i + 3]) + r2[i + 3];
                i += 4;
            }
            while i < w {
                tile[i] = (tile[i] + r1[i]) + r2[i];
                i += 1;
            }
            k += 2;
        }
        if k < ones.len() {
            let r1 = &diff[ones[k] as usize * j + t0..][..w];
            for (b, &x) in tile.iter_mut().zip(r1) {
                *b += x;
            }
        }
        t0 = t1;
    }
}

/// Batched mixture scoring: everything the samplers need from the
/// compiled artifacts.
///
/// Weight layout: `w1[d * j_total + j] = ln p̂(x_d = 1 | cluster j)`,
/// row-major `[D, J]`; `logpi[j]` = log mixture weight.
///
/// Implementations must be `Send`: the kernel sweep path owns one scorer
/// per [`crate::sampler::Shard`], and shards migrate across the
/// coordinator's map-step worker threads.
///
/// ```
/// use clustercluster::data::BinMat;
/// use clustercluster::runtime::{FallbackScorer, Scorer};
///
/// // one datum x = [1, 0], one cluster with p̂(x_d = 1) = 0.5 per dim
/// let mut x = BinMat::zeros(1, 2);
/// x.set(0, 0, true);
/// let half = 0.5f32.ln();
/// let (w1, w0) = (vec![half; 2], vec![half; 2]);
/// let mut scorer = FallbackScorer::new();
/// let dens = scorer.predictive_density(&x, &w1, &w0, &[0.0], 2, 1);
/// assert!((dens[0] - 2.0 * half).abs() < 1e-6);
/// ```
pub trait Scorer: Send {
    /// Per-row log predictive density `ln Σ_j exp(S[r,j] + logpi[j])`.
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// The full `[rows, J]` log-likelihood matrix (row-major).
    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// Sweep-side batched scoring: the log-likelihood block of the given
    /// data `rows` against `j` packed cluster columns. `out` is CLEARED
    /// and refilled row-major `[rows.len(), j]` — implementations must
    /// not append (callers reuse one buffer across data and index the
    /// first `j` entries per row).
    ///
    /// The weights arrive pre-reduced to the bit-sparse form of the
    /// `[D, J]` contract (`bias = colsum(W0)`, `diff = W1 − W0`, both
    /// f64 so the block is bit-identical to the scalar per-cluster
    /// path), and the block is evaluated by the same identity
    /// [`Self::loglik_matrix`] uses:
    /// `S[r, s] = bias[s] + Σ_{dd < d: x_{r,dd}=1} diff[dd*j + s]`.
    ///
    /// Padding contract (property-tested in
    /// `rust/tests/scorer_equivalence.rs`): padded dims carry
    /// `diff = 0`/`bias += 0` (exact no-op), padded/dead columns are
    /// simply never read by the caller, padded rows never perturb real
    /// rows (each row's block is independent).
    ///
    /// The default implementation is the pure-Rust evaluation every
    /// scorer starts from (SIMD-blocked through
    /// [`accumulate_ones_block`], bit-identical to the naive loop); a
    /// PJRT-backed scorer overrides it with artifact execution without
    /// any kernel change.
    #[allow(clippy::too_many_arguments)] // mirrors the artifact ABI
    fn score_rows_against_clusters(
        &mut self,
        data: &BinMat,
        rows: &[usize],
        bias: &[f64],
        diff: &[f64],
        d: usize,
        j: usize,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(bias.len(), j);
        assert_eq!(diff.len(), d * j);
        out.clear();
        out.reserve(rows.len() * j);
        let mut ones: Vec<u32> = Vec::new();
        for &r in rows {
            ones.clear();
            data.for_each_one(r, |dd| {
                if dd < d {
                    ones.push(dd as u32);
                }
            });
            let start = out.len();
            out.extend_from_slice(bias);
            accumulate_ones_block(&mut out[start..], &ones, diff, j);
        }
    }

    /// Per-datum variant of [`Self::score_rows_against_clusters`] for
    /// the kernel hot loop: the datum arrives pre-decoded to its
    /// ascending set-bit index list (the kernels decode each row's bits
    /// exactly once per datum and reuse the list for every dispatch),
    /// so no `BinMat` walk and no per-call allocation happens here.
    /// Set bits at `d` or beyond (padded dims) are ignored. `out` is
    /// cleared and refilled with exactly `j` entries.
    ///
    /// The default implementation is the same SIMD-blocked pure-Rust
    /// evaluation as the rows entry point; a PJRT backend that
    /// overrides the rows entry point should override this one too, or
    /// the sweep path will keep using the pure-Rust block.
    #[allow(clippy::too_many_arguments)] // mirrors the artifact ABI
    fn score_ones_against_clusters(
        &mut self,
        ones: &[u32],
        bias: &[f64],
        diff: &[f64],
        d: usize,
        j: usize,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(bias.len(), j);
        assert_eq!(diff.len(), d * j);
        let cut = ones.partition_point(|&o| (o as usize) < d);
        out.clear();
        out.extend_from_slice(bias);
        accumulate_ones_block(out, &ones[..cut], diff, j);
    }

    /// Real-data variant of [`Self::score_ones_against_clusters`] for
    /// the collapsed-Gaussian sweep path: score one dense row against
    /// `j` packed Student-t columns. `diff` is the `[2D, J]` two-plane
    /// layout (rows `0..D` the posterior locations `m_n`, rows `D..2D`
    /// the inverse scales `κ_n/(2b_n(κ_n+1))`), and each column
    /// evaluates `bias[s] − aux[s] · Σ_d ln1p((x_d − m_d)² · inv_d)`
    /// with the per-dimension terms added in ascending-`d` order — the
    /// exact fp order of the scalar per-cluster path, so batched and
    /// scalar chains stay bit-identical just like the bit-sparse path.
    /// `out` is cleared and refilled with exactly `j` entries.
    #[allow(clippy::too_many_arguments)] // mirrors the artifact ABI
    fn score_real_against_clusters(
        &mut self,
        row: &[f64],
        bias: &[f64],
        aux: &[f64],
        diff: &[f64],
        j: usize,
        out: &mut Vec<f64>,
    ) {
        let d = row.len();
        assert_eq!(bias.len(), j);
        assert_eq!(aux.len(), j);
        assert_eq!(diff.len(), 2 * d * j);
        out.clear();
        out.resize(j, 0.0);
        for (dd, &x) in row.iter().enumerate() {
            let mn = &diff[dd * j..(dd + 1) * j];
            let inv = &diff[(d + dd) * j..(d + dd + 1) * j];
            for jj in 0..j {
                let t = x - mn[jj];
                out[jj] += (t * t * inv[jj]).ln_1p();
            }
        }
        for jj in 0..j {
            out[jj] = bias[jj] - aux[jj] * out[jj];
        }
    }

    /// Implementation name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Scorer backend selector — what `--scorer auto|fallback|pjrt` parses
/// into on both CLI entry points, and what the sweep-side
/// [`crate::sampler::ScoreMode::Batched`] carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScorerKind {
    /// PJRT artifacts when loadable, pure-Rust fallback otherwise.
    #[default]
    Auto,
    /// Always the pure-Rust [`FallbackScorer`].
    Fallback,
    /// PJRT artifacts, failing loudly when the backend is unavailable.
    Pjrt,
}

impl ScorerKind {
    /// Parse a `--scorer` value.
    pub fn parse(s: &str) -> Result<ScorerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(ScorerKind::Auto),
            "fallback" | "rust" => Ok(ScorerKind::Fallback),
            "pjrt" => Ok(ScorerKind::Pjrt),
            other => Err(format!(
                "unknown scorer {other:?} (expected \"auto\", \"fallback\" or \"pjrt\")"
            )),
        }
    }

    /// CLI name of this backend selection.
    pub fn name(self) -> &'static str {
        match self {
            ScorerKind::Auto => "auto",
            ScorerKind::Fallback => "fallback",
            ScorerKind::Pjrt => "pjrt",
        }
    }

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(
            std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
        )
    }

    /// Materialize the scorer this selector names. `Pjrt` errors when the
    /// backend is unavailable — the CLI entry points call this so an
    /// explicit `--scorer pjrt` fails up front, not mid-chain.
    pub fn try_build(self) -> Result<Box<dyn Scorer>, String> {
        match self {
            ScorerKind::Fallback => Ok(Box::new(FallbackScorer::new())),
            ScorerKind::Pjrt => PjrtScorer::load(&Self::artifacts_dir())
                .map(|s| Box::new(s) as Box<dyn Scorer>)
                .map_err(|e| e.to_string()),
            ScorerKind::Auto => Ok(PjrtScorer::load(&Self::artifacts_dir())
                .map(|s| Box::new(s) as Box<dyn Scorer>)
                .unwrap_or_else(|_| Box::new(FallbackScorer::new()))),
        }
    }

    /// Materialize with best-effort degradation: an unavailable backend
    /// warns and serves the fallback. This is the library-side path (a
    /// running chain must not die because artifacts moved); strict
    /// callers use [`Self::try_build`].
    pub fn build_or_fallback(self) -> Box<dyn Scorer> {
        self.try_build().unwrap_or_else(|e| {
            eprintln!("[runtime] scorer {:?}: {e}; using pure-Rust fallback", self.name());
            Box::new(FallbackScorer::new())
        })
    }
}

/// Pure-Rust scorer: same contract as the artifacts, no PJRT. Uses the
/// bit-sparse identity `S = colsum(W0) + Σ_{d: x_d=1} (W1-W0)[d,·]`.
#[derive(Debug, Default)]
pub struct FallbackScorer;

impl FallbackScorer {
    /// The stateless pure-Rust scorer.
    pub fn new() -> Self {
        FallbackScorer
    }

    fn scores_into(
        test: &BinMat,
        r: usize,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
        acc: &mut [f64],
    ) {
        debug_assert_eq!(acc.len(), j);
        // bias: column sums of w0 — cheap relative to row loop, but we
        // recompute per call batch, not per row (see loglik_matrix)
        for jj in 0..j {
            acc[jj] = 0.0;
        }
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                acc[jj] += row[jj] as f64;
            }
        }
        test.for_each_one(r, |dd| {
            if dd < d {
                let r1 = &w1[dd * j..(dd + 1) * j];
                let r0 = &w0[dd * j..(dd + 1) * j];
                for jj in 0..j {
                    acc[jj] += (r1[jj] - r0[jj]) as f64;
                }
            }
        });
    }
}

impl Scorer for FallbackScorer {
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        assert_eq!(logpi.len(), j);
        let n = test.rows();
        // precompute bias once
        let mut bias = vec![0.0f64; j];
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                bias[jj] += row[jj] as f64;
            }
        }
        let mut out = Vec::with_capacity(n);
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            acc.copy_from_slice(&bias);
            test.for_each_one(r, |dd| {
                if dd < d {
                    let r1 = &w1[dd * j..(dd + 1) * j];
                    let r0 = &w0[dd * j..(dd + 1) * j];
                    for jj in 0..j {
                        acc[jj] += (r1[jj] - r0[jj]) as f64;
                    }
                }
            });
            for jj in 0..j {
                acc[jj] += logpi[jj] as f64;
            }
            out.push(logsumexp(&acc) as f32);
        }
        out
    }

    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        let n = test.rows();
        let mut out = vec![0.0f32; n * j];
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            Self::scores_into(test, r, w1, w0, d, j, &mut acc);
            for jj in 0..j {
                out[r * j + jj] = acc[jj] as f32;
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "fallback"
    }
}

/// Best-available scorer: PJRT artifacts if present (CC_ARTIFACTS env or
/// ./artifacts), pure-Rust fallback otherwise. Same resolution policy as
/// `--scorer auto` ([`ScorerKind::Auto`]), plus a stderr note when the
/// backend degrades.
pub fn auto_scorer() -> Box<dyn Scorer> {
    match ScorerKind::Pjrt.try_build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[runtime] artifacts unavailable ({e}); using pure-Rust fallback scorer");
            Box::new(FallbackScorer::new())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_problem(
        n: usize,
        d: usize,
        j: usize,
        seed: u64,
    ) -> (BinMat, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.5 {
                    m.set(r, c, true);
                }
            }
        }
        let mut w1 = vec![0.0f32; d * j];
        let mut w0 = vec![0.0f32; d * j];
        for i in 0..d * j {
            let p = 0.05 + 0.9 * rng.next_f64();
            w1[i] = (p as f32).ln();
            w0[i] = (1.0 - p as f32).ln();
        }
        let mut logpi = vec![0.0f32; j];
        let z = (j as f32).ln();
        for x in logpi.iter_mut() {
            *x = -z;
        }
        (m, w1, w0, logpi)
    }

    /// Brute-force oracle using the dense per-element definition.
    fn oracle_matrix(m: &BinMat, w1: &[f32], w0: &[f32], d: usize, j: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; m.rows() * j];
        for r in 0..m.rows() {
            for jj in 0..j {
                let mut s = 0.0f64;
                for dd in 0..d {
                    s += if m.get(r, dd) {
                        w1[dd * j + jj] as f64
                    } else {
                        w0[dd * j + jj] as f64
                    };
                }
                out[r * j + jj] = s;
            }
        }
        out
    }

    #[test]
    fn fallback_matches_bruteforce_matrix() {
        let (m, w1, w0, _) = rand_problem(7, 33, 5, 1);
        let mut s = FallbackScorer::new();
        let got = s.loglik_matrix(&m, &w1, &w0, 33, 5);
        let want = oracle_matrix(&m, &w1, &w0, 33, 5);
        for i in 0..got.len() {
            assert!(
                (got[i] as f64 - want[i]).abs() < 1e-4,
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn fallback_density_matches_matrix_logsumexp() {
        let (m, w1, w0, logpi) = rand_problem(6, 20, 4, 2);
        let mut s = FallbackScorer::new();
        let mat = s.loglik_matrix(&m, &w1, &w0, 20, 4);
        let dens = s.predictive_density(&m, &w1, &w0, &logpi, 20, 4);
        for r in 0..6 {
            let terms: Vec<f64> = (0..4)
                .map(|jj| mat[r * 4 + jj] as f64 + logpi[jj] as f64)
                .collect();
            let want = logsumexp(&terms);
            assert!(
                (dens[r] as f64 - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                dens[r]
            );
        }
    }

    #[test]
    fn padded_clusters_do_not_change_density() {
        let (m, mut w1, mut w0, mut logpi) = rand_problem(5, 16, 3, 3);
        let mut s = FallbackScorer::new();
        let base = s.predictive_density(&m, &w1, &w0, &logpi, 16, 3);
        // pad to j=6 — column-major-in-d layout means rebuilding rows
        let (d, j, jp) = (16, 3, 6);
        let mut w1p = vec![0.0f32; d * jp];
        let mut w0p = vec![0.0f32; d * jp];
        for dd in 0..d {
            for jj in 0..j {
                w1p[dd * jp + jj] = w1[dd * j + jj];
                w0p[dd * jp + jj] = w0[dd * j + jj];
            }
        }
        let mut logpip = vec![-1.0e30f32; jp];
        logpip[..j].copy_from_slice(&logpi);
        let padded = s.predictive_density(&m, &w1p, &w0p, &logpip, d, jp);
        for r in 0..5 {
            assert!((padded[r] - base[r]).abs() < 1e-5, "row {r}");
        }
        let _ = (&mut w1, &mut w0, &mut logpi);
    }

    #[test]
    fn score_rows_against_clusters_matches_loglik_matrix() {
        let (m, w1, w0, _) = rand_problem(9, 27, 6, 4);
        let (d, j) = (27usize, 6usize);
        // reduce the f32 contract weights to the bit-sparse f64 form
        let mut bias = vec![0.0f64; j];
        let mut diff = vec![0.0f64; d * j];
        for dd in 0..d {
            for jj in 0..j {
                bias[jj] += w0[dd * j + jj] as f64;
                diff[dd * j + jj] = w1[dd * j + jj] as f64 - w0[dd * j + jj] as f64;
            }
        }
        let mut s = FallbackScorer::new();
        let want = s.loglik_matrix(&m, &w1, &w0, d, j);
        let rows: Vec<usize> = (0..m.rows()).collect();
        let mut got = Vec::new();
        s.score_rows_against_clusters(&m, &rows, &bias, &diff, d, j, &mut got);
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - want[i] as f64).abs() < 1e-3,
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    /// Reference accumulator: one bit at a time, one column at a time —
    /// the exact fp order the SIMD-blocked loop must reproduce.
    fn naive_accumulate(block: &mut [f64], ones: &[u32], diff: &[f64], j: usize) {
        for &o in ones {
            let row = &diff[o as usize * j..(o as usize + 1) * j];
            for (b, &x) in block[..j].iter_mut().zip(row) {
                *b += x;
            }
        }
    }

    #[test]
    fn blocked_accumulator_is_bit_identical_to_naive() {
        let mut rng = Pcg64::seed_from(9);
        // exercise odd/even bit counts, tile boundaries (j > 128), and
        // non-multiple-of-4 tails
        for &(d, j, nbits) in &[
            (1usize, 1usize, 1usize),
            (7, 3, 4),
            (40, 130, 7),
            (64, 300, 33),
            (16, 127, 0),
            (50, 129, 50),
        ] {
            let mut diff = vec![0.0f64; d * j];
            for x in diff.iter_mut() {
                *x = rng.next_f64() - 0.5;
            }
            let mut ones: Vec<u32> = (0..d as u32).collect();
            rng.shuffle(&mut ones);
            ones.truncate(nbits.min(d));
            ones.sort_unstable();
            let mut bias = vec![0.0f64; j];
            for x in bias.iter_mut() {
                *x = rng.next_f64();
            }
            let mut want = bias.clone();
            naive_accumulate(&mut want, &ones, &diff, j);
            let mut got = bias.clone();
            accumulate_ones_block(&mut got, &ones, &diff, j);
            for i in 0..j {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "(d={d}, j={j}, bits={nbits}) col {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn score_ones_matches_rows_entry_point_and_clips_padded_dims() {
        let (m, w1, w0, _) = rand_problem(5, 30, 9, 6);
        let (d, j) = (30usize, 9usize);
        let mut bias = vec![0.0f64; j];
        let mut diff = vec![0.0f64; d * j];
        for dd in 0..d {
            for jj in 0..j {
                bias[jj] += w0[dd * j + jj] as f64;
                diff[dd * j + jj] = w1[dd * j + jj] as f64 - w0[dd * j + jj] as f64;
            }
        }
        let mut s = FallbackScorer::new();
        let rows: Vec<usize> = (0..m.rows()).collect();
        let mut via_rows = Vec::new();
        s.score_rows_against_clusters(&m, &rows, &bias, &diff, d, j, &mut via_rows);
        for r in 0..m.rows() {
            let mut ones: Vec<u32> = Vec::new();
            m.for_each_one(r, |dd| ones.push(dd as u32));
            // trailing out-of-range bits must be ignored, matching the
            // rows entry point's dd < d clamp
            ones.push(d as u32);
            ones.push(d as u32 + 3);
            let mut out = Vec::new();
            s.score_ones_against_clusters(&ones, &bias, &diff, d, j, &mut out);
            assert_eq!(out.len(), j);
            for jj in 0..j {
                assert_eq!(
                    out[jj].to_bits(),
                    via_rows[r * j + jj].to_bits(),
                    "row {r} col {jj}"
                );
            }
        }
    }

    #[test]
    fn score_real_matches_scalar_order_bitwise() {
        let mut rng = Pcg64::seed_from(14);
        let (d, j) = (6usize, 5usize);
        let row: Vec<f64> = (0..d).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let mut diff = vec![0.0f64; 2 * d * j];
        for s in diff.iter_mut().take(d * j) {
            *s = rng.next_f64() - 0.5; // location plane
        }
        for s in diff.iter_mut().skip(d * j) {
            *s = rng.next_f64() + 0.1; // inverse-scale plane (> 0)
        }
        let bias: Vec<f64> = (0..j).map(|_| -3.0 * rng.next_f64()).collect();
        let aux: Vec<f64> = (0..j).map(|_| 1.0 + rng.next_f64()).collect();
        let mut s = FallbackScorer::new();
        let mut out = Vec::new();
        s.score_real_against_clusters(&row, &bias, &aux, &diff, j, &mut out);
        assert_eq!(out.len(), j);
        for jj in 0..j {
            // scalar reference: per-dim terms added in ascending-d order
            let mut acc = 0.0f64;
            for dd in 0..d {
                let t = row[dd] - diff[dd * j + jj];
                acc += (t * t * diff[(d + dd) * j + jj]).ln_1p();
            }
            let want = bias[jj] - aux[jj] * acc;
            assert_eq!(out[jj].to_bits(), want.to_bits(), "col {jj}");
        }
    }

    #[test]
    fn scorer_kind_parses_and_builds() {
        assert_eq!(ScorerKind::parse("auto").unwrap(), ScorerKind::Auto);
        assert_eq!(ScorerKind::parse("Fallback").unwrap(), ScorerKind::Fallback);
        assert_eq!(ScorerKind::parse("pjrt").unwrap(), ScorerKind::Pjrt);
        assert!(ScorerKind::parse("gpu").is_err());
        // offline universe: auto degrades to the fallback silently,
        // explicit pjrt errors, fallback always builds
        assert_eq!(ScorerKind::Auto.try_build().unwrap().name(), "fallback");
        assert_eq!(ScorerKind::Fallback.try_build().unwrap().name(), "fallback");
        assert!(ScorerKind::Pjrt.try_build().is_err());
        assert_eq!(ScorerKind::Pjrt.build_or_fallback().name(), "fallback");
    }
}
