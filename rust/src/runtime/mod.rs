//! Runtime bridge: executes the AOT-compiled JAX/Pallas scoring graphs
//! from the Rust hot path via the PJRT C API (`xla` crate — currently
//! stubbed, see [`pjrt`]; every caller is served by [`FallbackScorer`]).
//!
//! `make artifacts` lowers the Layer-2 entry points to HLO **text**
//! (`artifacts/*.hlo.txt` + `manifest.txt`); [`PjrtScorer`] loads and
//! compiles them once (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile`) and then serves batched scoring with
//! padding/chunking onto the fixed compiled shapes. Padding contracts
//! (verified by the Python L1/L2 tests and the cross-check integration
//! test):
//!
//! * pad dims `d → d_v`: `W1 = W0 = 0` (log 1 — exact no-op);
//! * pad clusters `j → j_v`: `logpi = -1e30` (masked by logsumexp);
//! * pad rows `b → b_v`: zero rows, outputs ignored.
//!
//! [`FallbackScorer`] is the pure-Rust implementation of the identical
//! contract — used when artifacts are absent and as the cross-check
//! oracle in integration tests.
//!
//! Besides trace-time evaluation ([`Scorer::predictive_density`] /
//! [`Scorer::loglik_matrix`]), the trait carries the sweep-side entry
//! point [`Scorer::score_rows_against_clusters`]: the kernel hot loop
//! packs each shard's cached predictive tables into the `[D, J]` layout
//! and scores a datum's whole candidate set in one batched call, so a
//! PJRT artifact that implements the entry point accelerates the map
//! step itself with zero kernel changes. [`ScorerKind`] is the backend
//! selector both CLI entry points expose as `--scorer`.

pub mod pjrt;

use crate::data::BinMat;
use crate::special::logsumexp;

pub use pjrt::PjrtScorer;

/// Batched mixture scoring: everything the samplers need from the
/// compiled artifacts.
///
/// Weight layout: `w1[d * j_total + j] = ln p̂(x_d = 1 | cluster j)`,
/// row-major `[D, J]`; `logpi[j]` = log mixture weight.
///
/// Implementations must be `Send`: the kernel sweep path owns one scorer
/// per [`crate::sampler::Shard`], and shards migrate across the
/// coordinator's map-step worker threads.
///
/// ```
/// use clustercluster::data::BinMat;
/// use clustercluster::runtime::{FallbackScorer, Scorer};
///
/// // one datum x = [1, 0], one cluster with p̂(x_d = 1) = 0.5 per dim
/// let mut x = BinMat::zeros(1, 2);
/// x.set(0, 0, true);
/// let half = 0.5f32.ln();
/// let (w1, w0) = (vec![half; 2], vec![half; 2]);
/// let mut scorer = FallbackScorer::new();
/// let dens = scorer.predictive_density(&x, &w1, &w0, &[0.0], 2, 1);
/// assert!((dens[0] - 2.0 * half).abs() < 1e-6);
/// ```
pub trait Scorer: Send {
    /// Per-row log predictive density `ln Σ_j exp(S[r,j] + logpi[j])`.
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// The full `[rows, J]` log-likelihood matrix (row-major).
    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// Sweep-side batched scoring: the log-likelihood block of the given
    /// data `rows` against `j` packed cluster columns. `out` is CLEARED
    /// and refilled row-major `[rows.len(), j]` — implementations must
    /// not append (callers reuse one buffer across data and index the
    /// first `j` entries per row).
    ///
    /// The weights arrive pre-reduced to the bit-sparse form of the
    /// `[D, J]` contract (`bias = colsum(W0)`, `diff = W1 − W0`, both
    /// f64 so the block is bit-identical to the scalar per-cluster
    /// path), and the block is evaluated by the same identity
    /// [`Self::loglik_matrix`] uses:
    /// `S[r, s] = bias[s] + Σ_{dd < d: x_{r,dd}=1} diff[dd*j + s]`.
    ///
    /// Padding contract (property-tested in
    /// `rust/tests/scorer_equivalence.rs`): padded dims carry
    /// `diff = 0`/`bias += 0` (exact no-op), padded/dead columns are
    /// simply never read by the caller, padded rows never perturb real
    /// rows (each row's block is independent).
    ///
    /// The default implementation is the pure-Rust evaluation every
    /// scorer starts from; a PJRT-backed scorer overrides it with
    /// artifact execution without any kernel change.
    #[allow(clippy::too_many_arguments)] // mirrors the artifact ABI
    fn score_rows_against_clusters(
        &mut self,
        data: &BinMat,
        rows: &[usize],
        bias: &[f64],
        diff: &[f64],
        d: usize,
        j: usize,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(bias.len(), j);
        assert_eq!(diff.len(), d * j);
        out.clear();
        out.reserve(rows.len() * j);
        for &r in rows {
            let start = out.len();
            out.extend_from_slice(bias);
            let block = &mut out[start..];
            data.for_each_one(r, |dd| {
                if dd < d {
                    let drow = &diff[dd * j..(dd + 1) * j];
                    for (b, &x) in block.iter_mut().zip(drow) {
                        *b += x;
                    }
                }
            });
        }
    }

    /// Implementation name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Scorer backend selector — what `--scorer auto|fallback|pjrt` parses
/// into on both CLI entry points, and what the sweep-side
/// [`crate::sampler::ScoreMode::Batched`] carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScorerKind {
    /// PJRT artifacts when loadable, pure-Rust fallback otherwise.
    #[default]
    Auto,
    /// Always the pure-Rust [`FallbackScorer`].
    Fallback,
    /// PJRT artifacts, failing loudly when the backend is unavailable.
    Pjrt,
}

impl ScorerKind {
    /// Parse a `--scorer` value.
    pub fn parse(s: &str) -> Result<ScorerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(ScorerKind::Auto),
            "fallback" | "rust" => Ok(ScorerKind::Fallback),
            "pjrt" => Ok(ScorerKind::Pjrt),
            other => Err(format!(
                "unknown scorer {other:?} (expected \"auto\", \"fallback\" or \"pjrt\")"
            )),
        }
    }

    /// CLI name of this backend selection.
    pub fn name(self) -> &'static str {
        match self {
            ScorerKind::Auto => "auto",
            ScorerKind::Fallback => "fallback",
            ScorerKind::Pjrt => "pjrt",
        }
    }

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(
            std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
        )
    }

    /// Materialize the scorer this selector names. `Pjrt` errors when the
    /// backend is unavailable — the CLI entry points call this so an
    /// explicit `--scorer pjrt` fails up front, not mid-chain.
    pub fn try_build(self) -> Result<Box<dyn Scorer>, String> {
        match self {
            ScorerKind::Fallback => Ok(Box::new(FallbackScorer::new())),
            ScorerKind::Pjrt => PjrtScorer::load(&Self::artifacts_dir())
                .map(|s| Box::new(s) as Box<dyn Scorer>)
                .map_err(|e| e.to_string()),
            ScorerKind::Auto => Ok(PjrtScorer::load(&Self::artifacts_dir())
                .map(|s| Box::new(s) as Box<dyn Scorer>)
                .unwrap_or_else(|_| Box::new(FallbackScorer::new()))),
        }
    }

    /// Materialize with best-effort degradation: an unavailable backend
    /// warns and serves the fallback. This is the library-side path (a
    /// running chain must not die because artifacts moved); strict
    /// callers use [`Self::try_build`].
    pub fn build_or_fallback(self) -> Box<dyn Scorer> {
        self.try_build().unwrap_or_else(|e| {
            eprintln!("[runtime] scorer {:?}: {e}; using pure-Rust fallback", self.name());
            Box::new(FallbackScorer::new())
        })
    }
}

/// Pure-Rust scorer: same contract as the artifacts, no PJRT. Uses the
/// bit-sparse identity `S = colsum(W0) + Σ_{d: x_d=1} (W1-W0)[d,·]`.
#[derive(Debug, Default)]
pub struct FallbackScorer;

impl FallbackScorer {
    /// The stateless pure-Rust scorer.
    pub fn new() -> Self {
        FallbackScorer
    }

    fn scores_into(
        test: &BinMat,
        r: usize,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
        acc: &mut [f64],
    ) {
        debug_assert_eq!(acc.len(), j);
        // bias: column sums of w0 — cheap relative to row loop, but we
        // recompute per call batch, not per row (see loglik_matrix)
        for jj in 0..j {
            acc[jj] = 0.0;
        }
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                acc[jj] += row[jj] as f64;
            }
        }
        test.for_each_one(r, |dd| {
            if dd < d {
                let r1 = &w1[dd * j..(dd + 1) * j];
                let r0 = &w0[dd * j..(dd + 1) * j];
                for jj in 0..j {
                    acc[jj] += (r1[jj] - r0[jj]) as f64;
                }
            }
        });
    }
}

impl Scorer for FallbackScorer {
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        assert_eq!(logpi.len(), j);
        let n = test.rows();
        // precompute bias once
        let mut bias = vec![0.0f64; j];
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                bias[jj] += row[jj] as f64;
            }
        }
        let mut out = Vec::with_capacity(n);
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            acc.copy_from_slice(&bias);
            test.for_each_one(r, |dd| {
                if dd < d {
                    let r1 = &w1[dd * j..(dd + 1) * j];
                    let r0 = &w0[dd * j..(dd + 1) * j];
                    for jj in 0..j {
                        acc[jj] += (r1[jj] - r0[jj]) as f64;
                    }
                }
            });
            for jj in 0..j {
                acc[jj] += logpi[jj] as f64;
            }
            out.push(logsumexp(&acc) as f32);
        }
        out
    }

    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        let n = test.rows();
        let mut out = vec![0.0f32; n * j];
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            Self::scores_into(test, r, w1, w0, d, j, &mut acc);
            for jj in 0..j {
                out[r * j + jj] = acc[jj] as f32;
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "fallback"
    }
}

/// Best-available scorer: PJRT artifacts if present (CC_ARTIFACTS env or
/// ./artifacts), pure-Rust fallback otherwise. Same resolution policy as
/// `--scorer auto` ([`ScorerKind::Auto`]), plus a stderr note when the
/// backend degrades.
pub fn auto_scorer() -> Box<dyn Scorer> {
    match ScorerKind::Pjrt.try_build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[runtime] artifacts unavailable ({e}); using pure-Rust fallback scorer");
            Box::new(FallbackScorer::new())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_problem(
        n: usize,
        d: usize,
        j: usize,
        seed: u64,
    ) -> (BinMat, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.5 {
                    m.set(r, c, true);
                }
            }
        }
        let mut w1 = vec![0.0f32; d * j];
        let mut w0 = vec![0.0f32; d * j];
        for i in 0..d * j {
            let p = 0.05 + 0.9 * rng.next_f64();
            w1[i] = (p as f32).ln();
            w0[i] = (1.0 - p as f32).ln();
        }
        let mut logpi = vec![0.0f32; j];
        let z = (j as f32).ln();
        for x in logpi.iter_mut() {
            *x = -z;
        }
        (m, w1, w0, logpi)
    }

    /// Brute-force oracle using the dense per-element definition.
    fn oracle_matrix(m: &BinMat, w1: &[f32], w0: &[f32], d: usize, j: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; m.rows() * j];
        for r in 0..m.rows() {
            for jj in 0..j {
                let mut s = 0.0f64;
                for dd in 0..d {
                    s += if m.get(r, dd) {
                        w1[dd * j + jj] as f64
                    } else {
                        w0[dd * j + jj] as f64
                    };
                }
                out[r * j + jj] = s;
            }
        }
        out
    }

    #[test]
    fn fallback_matches_bruteforce_matrix() {
        let (m, w1, w0, _) = rand_problem(7, 33, 5, 1);
        let mut s = FallbackScorer::new();
        let got = s.loglik_matrix(&m, &w1, &w0, 33, 5);
        let want = oracle_matrix(&m, &w1, &w0, 33, 5);
        for i in 0..got.len() {
            assert!(
                (got[i] as f64 - want[i]).abs() < 1e-4,
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn fallback_density_matches_matrix_logsumexp() {
        let (m, w1, w0, logpi) = rand_problem(6, 20, 4, 2);
        let mut s = FallbackScorer::new();
        let mat = s.loglik_matrix(&m, &w1, &w0, 20, 4);
        let dens = s.predictive_density(&m, &w1, &w0, &logpi, 20, 4);
        for r in 0..6 {
            let terms: Vec<f64> = (0..4)
                .map(|jj| mat[r * 4 + jj] as f64 + logpi[jj] as f64)
                .collect();
            let want = logsumexp(&terms);
            assert!(
                (dens[r] as f64 - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                dens[r]
            );
        }
    }

    #[test]
    fn padded_clusters_do_not_change_density() {
        let (m, mut w1, mut w0, mut logpi) = rand_problem(5, 16, 3, 3);
        let mut s = FallbackScorer::new();
        let base = s.predictive_density(&m, &w1, &w0, &logpi, 16, 3);
        // pad to j=6 — column-major-in-d layout means rebuilding rows
        let (d, j, jp) = (16, 3, 6);
        let mut w1p = vec![0.0f32; d * jp];
        let mut w0p = vec![0.0f32; d * jp];
        for dd in 0..d {
            for jj in 0..j {
                w1p[dd * jp + jj] = w1[dd * j + jj];
                w0p[dd * jp + jj] = w0[dd * j + jj];
            }
        }
        let mut logpip = vec![-1.0e30f32; jp];
        logpip[..j].copy_from_slice(&logpi);
        let padded = s.predictive_density(&m, &w1p, &w0p, &logpip, d, jp);
        for r in 0..5 {
            assert!((padded[r] - base[r]).abs() < 1e-5, "row {r}");
        }
        let _ = (&mut w1, &mut w0, &mut logpi);
    }

    #[test]
    fn score_rows_against_clusters_matches_loglik_matrix() {
        let (m, w1, w0, _) = rand_problem(9, 27, 6, 4);
        let (d, j) = (27usize, 6usize);
        // reduce the f32 contract weights to the bit-sparse f64 form
        let mut bias = vec![0.0f64; j];
        let mut diff = vec![0.0f64; d * j];
        for dd in 0..d {
            for jj in 0..j {
                bias[jj] += w0[dd * j + jj] as f64;
                diff[dd * j + jj] = w1[dd * j + jj] as f64 - w0[dd * j + jj] as f64;
            }
        }
        let mut s = FallbackScorer::new();
        let want = s.loglik_matrix(&m, &w1, &w0, d, j);
        let rows: Vec<usize> = (0..m.rows()).collect();
        let mut got = Vec::new();
        s.score_rows_against_clusters(&m, &rows, &bias, &diff, d, j, &mut got);
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - want[i] as f64).abs() < 1e-3,
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn scorer_kind_parses_and_builds() {
        assert_eq!(ScorerKind::parse("auto").unwrap(), ScorerKind::Auto);
        assert_eq!(ScorerKind::parse("Fallback").unwrap(), ScorerKind::Fallback);
        assert_eq!(ScorerKind::parse("pjrt").unwrap(), ScorerKind::Pjrt);
        assert!(ScorerKind::parse("gpu").is_err());
        // offline universe: auto degrades to the fallback silently,
        // explicit pjrt errors, fallback always builds
        assert_eq!(ScorerKind::Auto.try_build().unwrap().name(), "fallback");
        assert_eq!(ScorerKind::Fallback.try_build().unwrap().name(), "fallback");
        assert!(ScorerKind::Pjrt.try_build().is_err());
        assert_eq!(ScorerKind::Pjrt.build_or_fallback().name(), "fallback");
    }
}
