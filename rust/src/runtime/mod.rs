//! Runtime bridge: executes the AOT-compiled JAX/Pallas scoring graphs
//! from the Rust hot path via the PJRT C API (`xla` crate — currently
//! stubbed, see [`pjrt`]; every caller is served by [`FallbackScorer`]).
//!
//! `make artifacts` lowers the Layer-2 entry points to HLO **text**
//! (`artifacts/*.hlo.txt` + `manifest.txt`); [`PjrtScorer`] loads and
//! compiles them once (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile`) and then serves batched scoring with
//! padding/chunking onto the fixed compiled shapes. Padding contracts
//! (verified by the Python L1/L2 tests and the cross-check integration
//! test):
//!
//! * pad dims `d → d_v`: `W1 = W0 = 0` (log 1 — exact no-op);
//! * pad clusters `j → j_v`: `logpi = -1e30` (masked by logsumexp);
//! * pad rows `b → b_v`: zero rows, outputs ignored.
//!
//! [`FallbackScorer`] is the pure-Rust implementation of the identical
//! contract — used when artifacts are absent and as the cross-check
//! oracle in integration tests.

pub mod pjrt;

use crate::data::BinMat;
use crate::special::logsumexp;

pub use pjrt::PjrtScorer;

/// Batched mixture scoring: everything the samplers need from the
/// compiled artifacts.
///
/// Weight layout: `w1[d * j_total + j] = ln p̂(x_d = 1 | cluster j)`,
/// row-major `[D, J]`; `logpi[j]` = log mixture weight.
pub trait Scorer {
    /// Per-row log predictive density `ln Σ_j exp(S[r,j] + logpi[j])`.
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// The full `[rows, J]` log-likelihood matrix (row-major).
    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32>;

    /// Implementation name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Pure-Rust scorer: same contract as the artifacts, no PJRT. Uses the
/// bit-sparse identity `S = colsum(W0) + Σ_{d: x_d=1} (W1-W0)[d,·]`.
#[derive(Debug, Default)]
pub struct FallbackScorer;

impl FallbackScorer {
    pub fn new() -> Self {
        FallbackScorer
    }

    fn scores_into(
        test: &BinMat,
        r: usize,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
        acc: &mut [f64],
    ) {
        debug_assert_eq!(acc.len(), j);
        // bias: column sums of w0 — cheap relative to row loop, but we
        // recompute per call batch, not per row (see loglik_matrix)
        for jj in 0..j {
            acc[jj] = 0.0;
        }
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                acc[jj] += row[jj] as f64;
            }
        }
        test.for_each_one(r, |dd| {
            if dd < d {
                let r1 = &w1[dd * j..(dd + 1) * j];
                let r0 = &w0[dd * j..(dd + 1) * j];
                for jj in 0..j {
                    acc[jj] += (r1[jj] - r0[jj]) as f64;
                }
            }
        });
    }
}

impl Scorer for FallbackScorer {
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        assert_eq!(logpi.len(), j);
        let n = test.rows();
        // precompute bias once
        let mut bias = vec![0.0f64; j];
        for dd in 0..d {
            let row = &w0[dd * j..(dd + 1) * j];
            for jj in 0..j {
                bias[jj] += row[jj] as f64;
            }
        }
        let mut out = Vec::with_capacity(n);
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            acc.copy_from_slice(&bias);
            test.for_each_one(r, |dd| {
                if dd < d {
                    let r1 = &w1[dd * j..(dd + 1) * j];
                    let r0 = &w0[dd * j..(dd + 1) * j];
                    for jj in 0..j {
                        acc[jj] += (r1[jj] - r0[jj]) as f64;
                    }
                }
            });
            for jj in 0..j {
                acc[jj] += logpi[jj] as f64;
            }
            out.push(logsumexp(&acc) as f32);
        }
        out
    }

    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        let n = test.rows();
        let mut out = vec![0.0f32; n * j];
        let mut acc = vec![0.0f64; j];
        for r in 0..n {
            Self::scores_into(test, r, w1, w0, d, j, &mut acc);
            for jj in 0..j {
                out[r * j + jj] = acc[jj] as f32;
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "fallback"
    }
}

/// Best-available scorer: PJRT artifacts if present (CC_ARTIFACTS env or
/// ./artifacts), pure-Rust fallback otherwise.
pub fn auto_scorer() -> Box<dyn Scorer> {
    let dir = std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    match PjrtScorer::load(std::path::Path::new(&dir)) {
        Ok(s) => Box::new(s),
        Err(e) => {
            eprintln!("[runtime] artifacts unavailable ({e}); using pure-Rust fallback scorer");
            Box::new(FallbackScorer::new())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_problem(
        n: usize,
        d: usize,
        j: usize,
        seed: u64,
    ) -> (BinMat, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.5 {
                    m.set(r, c, true);
                }
            }
        }
        let mut w1 = vec![0.0f32; d * j];
        let mut w0 = vec![0.0f32; d * j];
        for i in 0..d * j {
            let p = 0.05 + 0.9 * rng.next_f64();
            w1[i] = (p as f32).ln();
            w0[i] = (1.0 - p as f32).ln();
        }
        let mut logpi = vec![0.0f32; j];
        let z = (j as f32).ln();
        for x in logpi.iter_mut() {
            *x = -z;
        }
        (m, w1, w0, logpi)
    }

    /// Brute-force oracle using the dense per-element definition.
    fn oracle_matrix(m: &BinMat, w1: &[f32], w0: &[f32], d: usize, j: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; m.rows() * j];
        for r in 0..m.rows() {
            for jj in 0..j {
                let mut s = 0.0f64;
                for dd in 0..d {
                    s += if m.get(r, dd) {
                        w1[dd * j + jj] as f64
                    } else {
                        w0[dd * j + jj] as f64
                    };
                }
                out[r * j + jj] = s;
            }
        }
        out
    }

    #[test]
    fn fallback_matches_bruteforce_matrix() {
        let (m, w1, w0, _) = rand_problem(7, 33, 5, 1);
        let mut s = FallbackScorer::new();
        let got = s.loglik_matrix(&m, &w1, &w0, 33, 5);
        let want = oracle_matrix(&m, &w1, &w0, 33, 5);
        for i in 0..got.len() {
            assert!(
                (got[i] as f64 - want[i]).abs() < 1e-4,
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn fallback_density_matches_matrix_logsumexp() {
        let (m, w1, w0, logpi) = rand_problem(6, 20, 4, 2);
        let mut s = FallbackScorer::new();
        let mat = s.loglik_matrix(&m, &w1, &w0, 20, 4);
        let dens = s.predictive_density(&m, &w1, &w0, &logpi, 20, 4);
        for r in 0..6 {
            let terms: Vec<f64> = (0..4)
                .map(|jj| mat[r * 4 + jj] as f64 + logpi[jj] as f64)
                .collect();
            let want = logsumexp(&terms);
            assert!(
                (dens[r] as f64 - want).abs() < 1e-4,
                "row {r}: {} vs {want}",
                dens[r]
            );
        }
    }

    #[test]
    fn padded_clusters_do_not_change_density() {
        let (m, mut w1, mut w0, mut logpi) = rand_problem(5, 16, 3, 3);
        let mut s = FallbackScorer::new();
        let base = s.predictive_density(&m, &w1, &w0, &logpi, 16, 3);
        // pad to j=6 — column-major-in-d layout means rebuilding rows
        let (d, j, jp) = (16, 3, 6);
        let mut w1p = vec![0.0f32; d * jp];
        let mut w0p = vec![0.0f32; d * jp];
        for dd in 0..d {
            for jj in 0..j {
                w1p[dd * jp + jj] = w1[dd * j + jj];
                w0p[dd * jp + jj] = w0[dd * j + jj];
            }
        }
        let mut logpip = vec![-1.0e30f32; jp];
        logpip[..j].copy_from_slice(&logpi);
        let padded = s.predictive_density(&m, &w1p, &w0p, &logpip, d, jp);
        for r in 0..5 {
            assert!((padded[r] - base[r]).abs() < 1e-5, "row {r}");
        }
        let _ = (&mut w1, &mut w0, &mut logpi);
    }
}
