//! PJRT-backed scorer — **offline stub**.
//!
//! The real implementation loads `artifacts/manifest.txt`, compiles every
//! HLO text module on the CPU PJRT client once (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`), and serves scoring by padding and
//! chunking workloads onto the fixed compiled shapes.
//!
//! The `xla` crate that provides the PJRT C-API bindings is not in this
//! build's offline dependency universe, so this module keeps the full
//! *frontend* — manifest parsing, artifact validation, and the error
//! contract the failure-injection suite pins down — and fails loading
//! with a clear "backend unavailable" error instead of compiling HLO.
//! [`super::FallbackScorer`] (the pure-Rust implementation of the
//! identical scoring contract, cross-checked against the Python L1/L2
//! oracle) serves every caller through [`super::auto_scorer`] in the
//! meantime. Restoring the backend is purely additive: implement
//! [`PjrtScorer::load`]'s final step against the manifest entries this
//! stub already validates.

use super::Scorer;
use crate::data::BinMat;
use std::fmt;
use std::path::Path;

/// Artifact-loading error (Display is what `auto_scorer` logs and the
/// failure-injection tests match on).
#[derive(Debug)]
pub struct PjrtError(String);

impl fmt::Display for PjrtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PjrtError {}

fn err(msg: impl Into<String>) -> PjrtError {
    PjrtError(msg.into())
}

/// One validated artifact variant from the manifest: `name entry b d j
/// file`, where (b, d, j) is the compiled (rows, dims, clusters) shape.
#[allow(dead_code)] // consumed by the xla-backed build; stub only validates
struct Variant {
    name: String,
    entry: String,
    b: usize,
    d: usize,
    j: usize,
    hlo_text: String,
}

/// Scorer backed by AOT-compiled PJRT executables (stubbed: loading
/// always fails after validation — see the module docs).
pub struct PjrtScorer {
    variants: Vec<Variant>,
    /// calls served (for bench introspection)
    pub executions: u64,
}

impl fmt::Debug for PjrtScorer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PjrtScorer")
            .field("variants", &self.variant_names())
            .field("executions", &self.executions)
            .finish()
    }
}

impl PjrtScorer {
    /// Load and validate every artifact listed in `<dir>/manifest.txt`.
    /// In this offline build the final compile step is unavailable, so a
    /// *valid* manifest still returns an error (backend unavailable) —
    /// after all validation errors have had their chance to surface.
    pub fn load(dir: &Path) -> Result<PjrtScorer, PjrtError> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| err(format!("reading {}: {e}", manifest.display())))?;
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                return Err(err(format!(
                    "manifest line {} malformed: {line:?}",
                    lineno + 1
                )));
            }
            let (name, entry) = (f[0].to_string(), f[1].to_string());
            let parse = |s: &str, what: &str| -> Result<usize, PjrtError> {
                s.parse()
                    .map_err(|_| err(format!("manifest line {}: bad {what} {s:?}", lineno + 1)))
            };
            let b = parse(f[2], "batch")?;
            let d = parse(f[3], "dims")?;
            let j = parse(f[4], "clusters")?;
            let path = dir.join(f[5]);
            let hlo_text = std::fs::read_to_string(&path)
                .map_err(|e| err(format!("reading {}: {e}", path.display())))?;
            if !hlo_text.trim_start().starts_with("HloModule") {
                return Err(err(format!("{} is not HLO text", path.display())));
            }
            variants.push(Variant {
                name,
                entry,
                b,
                d,
                j,
                hlo_text,
            });
        }
        if variants.is_empty() {
            return Err(err(format!(
                "manifest {} lists no variants",
                manifest.display()
            )));
        }
        // Everything checked out — but there is no PJRT client to compile
        // the modules with in this build.
        drop(variants);
        Err(err(
            "PJRT backend unavailable: the `xla` crate is not in the offline \
             dependency universe (pure-Rust FallbackScorer serves this contract)",
        ))
    }

    /// Names of the compiled artifact variants in the manifest.
    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|v| v.name.as_str()).collect()
    }
}

impl Scorer for PjrtScorer {
    fn predictive_density(
        &mut self,
        _test: &BinMat,
        _w1: &[f32],
        _w0: &[f32],
        _logpi: &[f32],
        _d: usize,
        _j: usize,
    ) -> Vec<f32> {
        unreachable!("PjrtScorer cannot be constructed without the xla backend")
    }

    fn loglik_matrix(
        &mut self,
        _test: &BinMat,
        _w1: &[f32],
        _w0: &[f32],
        _d: usize,
        _j: usize,
    ) -> Vec<f32> {
        unreachable!("PjrtScorer cannot be constructed without the xla backend")
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cc_pjrt_stub").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn valid_manifest_reports_backend_unavailable() {
        let d = tmpdir("valid");
        std::fs::write(d.join("m.hlo.txt"), "HloModule loglik\n").unwrap();
        std::fs::write(d.join("manifest.txt"), "loglik_64 loglik 64 256 128 m.hlo.txt\n")
            .unwrap();
        let e = PjrtScorer::load(&d).unwrap_err().to_string();
        assert!(e.contains("backend unavailable"), "{e}");
    }

    #[test]
    fn validation_errors_win_over_backend_error() {
        let d = tmpdir("badnum");
        std::fs::write(d.join("m.hlo.txt"), "HloModule x\n").unwrap();
        std::fs::write(d.join("manifest.txt"), "a loglik sixty 256 128 m.hlo.txt\n").unwrap();
        let e = PjrtScorer::load(&d).unwrap_err().to_string();
        assert!(e.contains("bad batch"), "{e}");
    }
}
