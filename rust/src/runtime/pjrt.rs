//! PJRT-backed scorer: loads `artifacts/manifest.txt`, compiles every HLO
//! text module on the CPU PJRT client once, and serves scoring by padding
//! and chunking workloads onto the fixed compiled shapes.
//!
//! Wiring per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects in proto form; the text parser reassigns ids).

use super::Scorer;
use crate::data::BinMat;
use crate::special::logsumexp;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One compiled artifact variant.
struct Variant {
    name: String,
    entry: String,
    b: usize,
    d: usize,
    j: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for PjrtScorer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtScorer")
            .field("variants", &self.variant_names())
            .field("executions", &self.executions)
            .finish()
    }
}

/// Scorer backed by AOT-compiled PJRT executables.
pub struct PjrtScorer {
    variants: Vec<Variant>,
    /// calls served (for bench introspection)
    pub executions: u64,
}

impl PjrtScorer {
    /// Load and compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<PjrtScorer> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let (name, entry) = (f[0].to_string(), f[1].to_string());
            let b: usize = f[2].parse()?;
            let d: usize = f[3].parse()?;
            let j: usize = f[4].parse()?;
            let path = dir.join(f[5]);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            variants.push(Variant {
                name,
                entry,
                b,
                d,
                j,
                exe,
            });
        }
        if variants.is_empty() {
            bail!("manifest {} lists no variants", manifest.display());
        }
        Ok(PjrtScorer {
            variants,
            executions: 0,
        })
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|v| v.name.as_str()).collect()
    }

    /// Pick the variant of `entry` with the smallest padded area that
    /// covers `d` dims; J is chunkable so any `j_v` works.
    fn pick(&self, entry: &str, d: usize) -> Result<usize> {
        let mut best: Option<(usize, usize)> = None; // (cost, idx)
        for (i, v) in self.variants.iter().enumerate() {
            if v.entry == entry && v.d >= d {
                let cost = v.b * v.d * v.j;
                if best.map(|(c, _)| cost < c).unwrap_or(true) {
                    best = Some((cost, i));
                }
            }
        }
        best.map(|(_, i)| i)
            .ok_or_else(|| anyhow!("no '{entry}' artifact covers d={d}"))
    }

    /// Build the padded [d_v, j_v] weight block for cluster columns
    /// [j0, j0+jn) from the logical [d, j] matrices.
    fn pad_weights(
        w: &[f32],
        d: usize,
        j: usize,
        d_v: usize,
        j_v: usize,
        j0: usize,
        jn: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), d_v * j_v);
        out.fill(0.0);
        for dd in 0..d {
            let src = &w[dd * j + j0..dd * j + j0 + jn];
            let dst = &mut out[dd * j_v..dd * j_v + jn];
            dst.copy_from_slice(src);
        }
    }

    /// Execute the loglik artifact on one (row-block, cluster-chunk).
    fn exec_loglik(
        &mut self,
        vi: usize,
        x: &[f32],
        w1: &[f32],
        w0: &[f32],
    ) -> Result<Vec<f32>> {
        let v = &self.variants[vi];
        let xl = xla::Literal::vec1(x).reshape(&[v.b as i64, v.d as i64])?;
        let w1l = xla::Literal::vec1(w1).reshape(&[v.d as i64, v.j as i64])?;
        let w0l = xla::Literal::vec1(w0).reshape(&[v.d as i64, v.j as i64])?;
        let result = v.exe.execute::<xla::Literal>(&[xl, w1l, w0l])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        self.executions += 1;
        Ok(out.to_vec::<f32>()?)
    }
}

impl Scorer for PjrtScorer {
    fn predictive_density(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        logpi: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        // density = logsumexp over J of (loglik + logpi); chunk J through
        // the loglik artifact and combine here (exact, any J)
        let mat = self.loglik_matrix(test, w1, w0, d, j);
        let n = test.rows();
        let mut out = Vec::with_capacity(n);
        let mut terms = vec![0.0f64; j];
        for r in 0..n {
            for jj in 0..j {
                terms[jj] = mat[r * j + jj] as f64 + logpi[jj] as f64;
            }
            out.push(logsumexp(&terms) as f32);
        }
        out
    }

    fn loglik_matrix(
        &mut self,
        test: &BinMat,
        w1: &[f32],
        w0: &[f32],
        d: usize,
        j: usize,
    ) -> Vec<f32> {
        assert_eq!(w1.len(), d * j);
        assert_eq!(w0.len(), d * j);
        let vi = self
            .pick("loglik", d)
            .expect("no loglik artifact for these dims");
        let (b_v, d_v, j_v) = {
            let v = &self.variants[vi];
            (v.b, v.d, v.j)
        };
        let n = test.rows();
        let mut out = vec![0.0f32; n * j];
        let mut xbuf = vec![0.0f32; b_v * d_v];
        let mut w1buf = vec![0.0f32; d_v * j_v];
        let mut w0buf = vec![0.0f32; d_v * j_v];

        let mut j0 = 0;
        while j0 < j {
            let jn = (j - j0).min(j_v);
            Self::pad_weights(w1, d, j, d_v, j_v, j0, jn, &mut w1buf);
            Self::pad_weights(w0, d, j, d_v, j_v, j0, jn, &mut w0buf);
            let mut r0 = 0;
            while r0 < n {
                let rn = (n - r0).min(b_v);
                test.unpack_block_f32(r0, b_v, d_v, &mut xbuf);
                let block = self
                    .exec_loglik(vi, &xbuf, &w1buf, &w0buf)
                    .expect("PJRT execution failed");
                for r in 0..rn {
                    let src = &block[r * j_v..r * j_v + jn];
                    let dst = &mut out[(r0 + r) * j + j0..(r0 + r) * j + j0 + jn];
                    dst.copy_from_slice(src);
                }
                r0 += rn;
            }
            j0 += jn;
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
