//! Property-test harness (proptest is not in the offline crate universe):
//! seeded random generation, many cases, and first-failure reporting with
//! the reproducing seed, plus the shared posterior-enumeration machinery
//! behind the 203-partition exactness gates. Used by the suites in
//! `rust/tests/` (`posterior_exactness.rs`, `mu_modes.rs`,
//! `scorer_equivalence.rs`, `property_invariants.rs`).

use crate::data::{BinMat, CatMat, DataRef, RealMat};
use crate::model::{ClusterStats, Model};
use crate::rng::Pcg64;
use crate::special::{lgamma, logsumexp};
use std::collections::HashMap;

/// Number of rows in the [`enumeration_fixture`] dataset.
pub const ENUM_N: usize = 6;
/// Dimensionality of the [`enumeration_fixture`] dataset.
pub const ENUM_D: usize = 4;

/// The fixed 6×4 mildly-structured binary dataset every enumeration
/// gate runs on — small enough that all Bell(6) = 203 partitions can be
/// scored exactly.
pub fn enumeration_fixture() -> BinMat {
    let dense: [u8; ENUM_N * ENUM_D] = [
        1, 1, 0, 0, //
        1, 1, 0, 1, //
        0, 0, 1, 1, //
        0, 1, 1, 1, //
        1, 0, 0, 0, //
        0, 0, 1, 0, //
    ];
    BinMat::from_dense(ENUM_N, ENUM_D, &dense)
}

/// Real-valued companion fixture (6×2, mildly separated) for the
/// Gaussian enumeration gate — same row count as
/// [`enumeration_fixture`], so the same 203 partitions.
pub fn enumeration_fixture_real() -> RealMat {
    let dense = vec![
        0.3, -0.2, //
        0.5, 0.1, //
        -1.2, 2.0, //
        -0.9, 1.7, //
        1.8, -1.5, //
        2.1, -1.1, //
    ];
    RealMat::from_dense(ENUM_N, 2, dense)
}

/// Categorical companion fixture (6 rows, 3 dims with mixed
/// cardinalities 3/2/4 — exercising the one-hot offsets) for the
/// Dirichlet–multinomial enumeration gate.
pub fn enumeration_fixture_cat() -> CatMat {
    let cards = [3u32, 2, 4];
    let codes = [
        0, 0, 1, //
        0, 1, 1, //
        2, 1, 3, //
        2, 0, 3, //
        1, 0, 0, //
        1, 1, 2, //
    ];
    CatMat::from_codes(ENUM_N, &cards, &codes)
}

/// Canonical restricted-growth string of an assignment vector (the
/// partition identity, independent of label values).
pub fn canonical_partition(z: &[u32]) -> Vec<u8> {
    let mut map: HashMap<u32, u8> = HashMap::new();
    let mut next = 0u8;
    z.iter()
        .map(|&zi| {
            *map.entry(zi).or_insert_with(|| {
                let v = next;
                next += 1;
                v
            })
        })
        .collect()
}

/// All set partitions of `{0..n-1}` as restricted-growth strings.
pub fn all_partitions(n: usize) -> Vec<Vec<u8>> {
    fn rec(i: usize, maxv: u8, cur: &mut Vec<u8>, out: &mut Vec<Vec<u8>>) {
        if i == cur.len() {
            out.push(cur.clone());
            return;
        }
        for v in 0..=maxv {
            cur[i] = v;
            rec(i + 1, maxv.max(v + 1), cur, out);
        }
    }
    let mut out = Vec::new();
    let mut cur = vec![0u8; n];
    rec(0, 0, &mut cur, &mut out);
    out
}

/// Exact unnormalized log posterior of one partition under any
/// [`Model`] likelihood:
/// `J ln α + Σ_j ln Γ(n_j) + Σ_j log-marginal(cluster_j)`.
pub fn partition_log_posterior<'a>(
    data: impl Into<DataRef<'a>>,
    model: &Model,
    alpha: f64,
    part: &[u8],
) -> f64 {
    let data = data.into();
    let j = (*part.iter().max().unwrap() + 1) as usize;
    let mut lp = j as f64 * alpha.ln();
    for cid in 0..j {
        let mut c = ClusterStats::empty(data.dims());
        let mut n = 0u64;
        for (r, &p) in part.iter().enumerate() {
            if p as usize == cid {
                c.add(data, r);
                n += 1;
            }
        }
        lp += lgamma(n as f64) + c.log_marginal(model);
    }
    lp
}

/// The exact normalized DPM posterior over ALL partitions of the
/// dataset's rows (only feasible for tiny data — the gates use the
/// 6-row fixtures, 203 partitions each).
pub fn enumerate_posterior<'a>(
    data: impl Into<DataRef<'a>>,
    model: &Model,
    alpha: f64,
) -> HashMap<Vec<u8>, f64> {
    let data = data.into();
    let parts = all_partitions(data.rows());
    let lps: Vec<f64> = parts
        .iter()
        .map(|p| partition_log_posterior(data, model, alpha, p))
        .collect();
    let z = logsumexp(&lps);
    parts
        .into_iter()
        .zip(lps)
        .map(|(p, lp)| (p, (lp - z).exp()))
        .collect()
}

/// Total-variation distance between the exact posterior and an
/// empirical partition histogram of `total` samples.
pub fn partition_tv_distance(
    truth: &HashMap<Vec<u8>, f64>,
    counts: &HashMap<Vec<u8>, u64>,
    total: u64,
) -> f64 {
    let mut tv = 0.0;
    for (p, &q) in truth {
        let emp = counts.get(p).copied().unwrap_or(0) as f64 / total as f64;
        tv += (q - emp).abs();
    }
    // partitions never visited but with positive truth are already
    // counted; visited-but-zero-truth impossible (all have support)
    tv / 2.0
}

/// Run `prop` on `cases` values drawn by `generate`. Panics on the first
/// failure with the case index, seed, and debug rendering of the input.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut rng = Pcg64::seed_from(seed);
        let value = generate(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  {msg}\n  input: {value:?}"
            );
        }
    }
}

/// Exact-f64 predictive-mixture oracle for a coordinator state:
/// mean over test rows of
/// `log [ Σ_j (n_j/(N+α)) p(x|j) + (α/(N+α)) p(x|∅) ]`,
/// computed straight from uncached cluster stats. Shared by the
/// scorer-equivalence and property suites so both gates assert the
/// *same* predictive contract against the Scorer trait path.
pub fn coordinator_predictive_oracle<'a>(
    coord: &crate::coordinator::Coordinator<'_>,
    test: impl Into<DataRef<'a>>,
) -> f64 {
    use crate::special::logsumexp;
    let test = test.into();
    let n: usize = coord.states().iter().map(|s| s.num_rows()).sum();
    let n_total = n as f64 + coord.alpha();
    let clusters = coord.global_clusters();
    let mut acc = 0.0f64;
    for r in 0..test.rows() {
        let mut terms: Vec<f64> = clusters
            .iter()
            .map(|c| (c.n() as f64 / n_total).ln() + c.score_uncached(&coord.model, test, r))
            .collect();
        terms.push((coord.alpha() / n_total).ln() + coord.model.log_pred_empty(test, r));
        acc += logsumexp(&terms);
    }
    acc / test.rows() as f64
}

/// Assert two floats agree to a tolerance, with a labelled error.
pub fn assert_close(label: &str, got: f64, want: f64, tol: f64) -> Result<(), String> {
    if (got - want).abs() <= tol * want.abs().max(1.0) {
        Ok(())
    } else {
        Err(format!("{label}: got {got}, want {want} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "count",
            25,
            1,
            |rng| rng.next_below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        check(
            "fails",
            10,
            2,
            |rng| rng.next_below(100),
            |&v| {
                if v < 90 {
                    Ok(())
                } else {
                    Err(format!("{v} too big"))
                }
            },
        );
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close("x", 1.0001, 1.0, 1e-3).is_ok());
        assert!(assert_close("x", 1.1, 1.0, 1e-3).is_err());
    }

    #[test]
    fn all_partitions_counts_are_bell_numbers() {
        for (n, bell) in [(1usize, 1usize), (2, 2), (3, 5), (4, 15), (5, 52), (6, 203)] {
            assert_eq!(all_partitions(n).len(), bell, "Bell({n})");
        }
    }

    #[test]
    fn canonical_partition_is_label_invariant() {
        assert_eq!(
            canonical_partition(&[7, 7, 2, 9]),
            canonical_partition(&[0, 0, 5, 1])
        );
        assert_ne!(
            canonical_partition(&[0, 1, 1]),
            canonical_partition(&[0, 0, 1])
        );
    }

    #[test]
    fn enumerated_posterior_normalizes() {
        let data = enumeration_fixture();
        let model = Model::bernoulli(ENUM_D, 0.6);
        let post = enumerate_posterior(&data, &model, 1.3);
        assert_eq!(post.len(), 203);
        let total: f64 = post.values().sum();
        assert!((total - 1.0).abs() < 1e-9, "Σp = {total}");
        assert!(post.values().all(|&p| p > 0.0));
    }

    #[test]
    fn enumerated_posterior_normalizes_for_all_likelihoods() {
        use crate::model::ModelSpec;
        let real = enumeration_fixture_real();
        let cat = enumeration_fixture_cat();
        let models = [
            (DataRef::from(&real), ModelSpec::DEFAULT_GAUSSIAN),
            (DataRef::from(&cat), ModelSpec::DEFAULT_CATEGORICAL),
        ];
        for (data, spec) in models {
            let model = spec.build(data, 0.5).unwrap();
            let post = enumerate_posterior(data, &model, 1.3);
            assert_eq!(post.len(), 203, "{}", model.name());
            let total: f64 = post.values().sum();
            assert!((total - 1.0).abs() < 1e-9, "{}: Σp = {total}", model.name());
            assert!(post.values().all(|&p| p > 0.0), "{}", model.name());
        }
    }
}
