//! Property-test harness (proptest is not in the offline crate universe):
//! seeded random generation, many cases, and first-failure reporting with
//! the reproducing seed. Used by the invariant suites in `rust/tests/`.

use crate::rng::Pcg64;

/// Run `prop` on `cases` values drawn by `generate`. Panics on the first
/// failure with the case index, seed, and debug rendering of the input.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut rng = Pcg64::seed_from(seed);
        let value = generate(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  {msg}\n  input: {value:?}"
            );
        }
    }
}

/// Exact-f64 predictive-mixture oracle for a coordinator state:
/// mean over test rows of
/// `log [ Σ_j (n_j/(N+α)) p(x|j) + (α/(N+α)) p(x|∅) ]`,
/// computed straight from uncached cluster stats. Shared by the
/// scorer-equivalence and property suites so both gates assert the
/// *same* predictive contract against the Scorer trait path.
pub fn coordinator_predictive_oracle(
    coord: &crate::coordinator::Coordinator<'_>,
    test: &crate::data::BinMat,
) -> f64 {
    use crate::special::logsumexp;
    let n: usize = coord.states().iter().map(|s| s.num_rows()).sum();
    let n_total = n as f64 + coord.alpha();
    let clusters = coord.global_clusters();
    let mut acc = 0.0f64;
    for r in 0..test.rows() {
        let mut terms: Vec<f64> = clusters
            .iter()
            .map(|c| (c.n() as f64 / n_total).ln() + c.score_uncached(&coord.model, test, r))
            .collect();
        terms.push((coord.alpha() / n_total).ln() + coord.model.empty_cluster_loglik());
        acc += logsumexp(&terms);
    }
    acc / test.rows() as f64
}

/// Assert two floats agree to a tolerance, with a labelled error.
pub fn assert_close(label: &str, got: f64, want: f64, tol: f64) -> Result<(), String> {
    if (got - want).abs() <= tol * want.abs().max(1.0) {
        Ok(())
    } else {
        Err(format!("{label}: got {got}, want {want} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "count",
            25,
            1,
            |rng| rng.next_below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        check(
            "fails",
            10,
            2,
            |rng| rng.next_below(100),
            |&v| {
                if v < 90 {
                    Ok(())
                } else {
                    Err(format!("{v} too big"))
                }
            },
        );
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close("x", 1.0001, 1.0, 1e-3).is_ok());
        assert!(assert_close("x", 1.1, 1.0, 1e-3).is_err());
    }
}
