//! Walker (2007) slice sampling as an alternative per-supercluster
//! transition kernel — the paper's §4 point is that *any* standard DPM
//! technique ("such as Neal (2000), Walker (2007), or Papaspiliopoulos
//! and Roberts (2008)") applies within a supercluster without
//! modification, because each supercluster is a conditionally
//! independent `DP(αμ_k, H)`.
//!
//! One sweep (slice-efficient variant, coin weights kept collapsed):
//!
//! 1. impute explicit weights from the **posterior DP** (Ferguson): the
//!    occupied-atom masses plus the continuous remainder are jointly
//!    `(w_1..w_J, w_rest) ~ Dirichlet(n_1..n_J, θ)` with `θ = αμ_k`,
//!    realized by stick-breaking `v_j ~ Beta(n_j, θ + Σ_{l>j} n_l)`
//!    (note: NOT the blocked-Gibbs `Beta(1+n_j, ·)`, which is only
//!    correct with persistent stick labels — the enumeration gate
//!    caught that variant at TV ≈ 0.18);
//! 2. per datum, a slice `u_i ~ U(0, π_{z_i})`;
//! 3. break the remainder with empty sticks `v ~ Beta(1, θ)` until the
//!    leftover mass is below `min_i u_i` (finite truncation, exact);
//! 4. Gibbs each `z_i` over the *eligible* set `{j : π_j > u_i}` with
//!    collapsed predictive weights `p(x_i | x_{-i} in j)` (likelihood
//!    only — π enters through eligibility, not the weights).
//!
//! The sticks/slices are discarded after the sweep (auxiliary variables).
//! Exactness is certified by the same posterior-enumeration gate as the
//! collapsed-Gibbs kernel (`rust/tests/posterior_exactness.rs`).

use super::supercluster_state::SuperclusterState;
use crate::data::BinMat;
use crate::model::BetaBernoulli;
use crate::rng::{beta as beta_draw, categorical_log_inplace};

/// Which local transition operator the map step runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalKernel {
    /// Neal (2000) Algorithm 3 collapsed Gibbs (default).
    CollapsedGibbs,
    /// Walker (2007) slice sampling (slice-efficient, collapsed coins).
    WalkerSlice,
}

/// One stick of the truncated representation: its weight and, once
/// materialized, the cluster slot it points at (`None` = still empty).
#[derive(Debug, Clone, Copy)]
struct Stick {
    pi: f64,
    slot: Option<usize>,
}

impl SuperclusterState {
    /// One Walker slice-sampling sweep with concentration `local_alpha`.
    pub fn walker_sweep(&mut self, data: &BinMat, model: &BetaBernoulli, local_alpha: f64) {
        let theta = local_alpha.max(1e-12);
        if self.num_rows() == 0 {
            return;
        }
        let mut rng = self.take_rng();

        // ---- 1. sticks for occupied clusters in APPEARANCE order ----
        // Given the partition of an exchangeable DP sample, the posterior
        // of the stick weights in order-of-appearance labeling is
        // v_j ~ Beta(1 + n_j, θ + Σ_{l>j} n_l) independently (Pitman's
        // size-biased representation). Using an arbitrary fixed order
        // here is NOT a draw from p(labels | z) and biases the chain —
        // caught by the posterior-enumeration gate.
        let slots: Vec<usize> = self.slots_by_appearance();
        let counts: Vec<u64> = slots.iter().map(|&s| self.cluster_n(s)).collect();
        let mut tail: Vec<u64> = vec![0; counts.len()];
        let mut acc = 0u64;
        for i in (0..counts.len()).rev() {
            tail[i] = acc;
            acc += counts[i];
        }
        // Posterior-DP representation (Ferguson): the occupied-atom
        // masses plus the continuous remainder are jointly
        // (w_1..w_J, w_rest) ~ Dirichlet(n_1..n_J, θ), realized by
        // stick-breaking with v_j ~ Beta(n_j, θ + Σ_{l>j} n_l) — note NO
        // "+1" (that form belongs to blocked Gibbs with persistent stick
        // labels; using it here biases the chain — caught by the
        // posterior-enumeration gate).
        let mut sticks: Vec<Stick> = Vec::with_capacity(slots.len() + 8);
        let mut remaining = 1.0f64;
        for i in 0..slots.len() {
            let v = beta_draw(&mut rng, counts[i] as f64, theta + tail[i] as f64);
            sticks.push(Stick {
                pi: remaining * v,
                slot: Some(slots[i]),
            });
            remaining *= 1.0 - v;
        }

        // ---- 2. slice per datum: u_i ~ U(0, π_{z_i}) ----
        let n = self.num_rows();
        let mut slot_to_stick = vec![usize::MAX; self.num_slots()];
        for (idx, st) in sticks.iter().enumerate() {
            slot_to_stick[st.slot.unwrap()] = idx;
        }
        let mut u = vec![0.0f64; n];
        let mut u_min = f64::INFINITY;
        for i in 0..n {
            let zi = self.assign_of(i) as usize;
            let pz = sticks[slot_to_stick[zi]].pi.max(1e-300);
            u[i] = rng.next_f64_open() * pz;
            if u[i] < u_min {
                u_min = u[i];
            }
        }

        // ---- 3. extend with empty sticks v ~ Beta(1, θ) until the
        //         leftover mass cannot contain any slice ----
        let mut guard = 0;
        while remaining > u_min && guard < 10_000 {
            let v = beta_draw(&mut rng, 1.0, theta);
            sticks.push(Stick {
                pi: remaining * v,
                slot: None,
            });
            remaining *= 1.0 - v;
            guard += 1;
        }

        // ---- 4. Gibbs each datum over its eligible sticks ----
        // weights: collapsed predictive (likelihood only — π enters via
        // eligibility). Emptied clusters keep their stick and score as
        // empty tables; picking an unmaterialized stick creates its
        // cluster, which later data in the same sweep can then join.
        let empty_loglik = model.empty_cluster_loglik();
        let mut cand: Vec<usize> = Vec::new();
        let mut logw: Vec<f64> = Vec::new();
        for i in 0..n {
            let r = self.row_of(i);
            let old_stick = slot_to_stick[self.assign_of(i) as usize];
            self.remove_row_keep_slot(i, data);

            cand.clear();
            logw.clear();
            for (idx, st) in sticks.iter().enumerate() {
                if st.pi > u[i] {
                    cand.push(idx);
                    logw.push(match st.slot {
                        Some(s) => self.score_slot(s, model, data, r),
                        None => empty_loglik,
                    });
                }
            }
            // float-tail guard: the datum's own stick is eligible by
            // construction, but keep a fallback anyway
            if cand.is_empty() {
                cand.push(old_stick);
                logw.push(0.0);
            }
            let pick = cand[categorical_log_inplace(&mut rng, &mut logw)];
            let slot = match sticks[pick].slot {
                Some(s) => {
                    self.add_row_to_slot(i, s, data);
                    s
                }
                None => {
                    let s = self.add_row_to_new_cluster(i, data, model.d);
                    sticks[pick].slot = Some(s);
                    if slot_to_stick.len() <= s {
                        slot_to_stick.resize(s + 1, usize::MAX);
                    }
                    slot_to_stick[s] = pick;
                    s
                }
            };
            let _ = slot;
        }
        self.compact_free_slots();
        self.put_rng(rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::rng::Pcg64;

    #[test]
    fn walker_sweep_preserves_invariants() {
        let ds = SyntheticConfig {
            n: 300,
            d: 16,
            clusters: 4,
            beta: 0.15,
            seed: 3,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(16, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = SuperclusterState::init_from_prior(
            &ds.train,
            rows,
            1.0,
            &model,
            Pcg64::seed_from(1),
        );
        for _ in 0..5 {
            st.walker_sweep(&ds.train, &model, 1.0);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 300);
    }

    #[test]
    fn walker_finds_structure() {
        let ds = SyntheticConfig {
            n: 400,
            d: 32,
            clusters: 4,
            beta: 0.05,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let mut model = BetaBernoulli::symmetric(32, 0.5);
        model.build_lut(ds.train.rows() + 1);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let mut st = SuperclusterState::init_from_prior(
            &ds.train,
            rows,
            4.0,
            &model,
            Pcg64::seed_from(5),
        );
        for _ in 0..30 {
            st.walker_sweep(&ds.train, &model, 4.0);
        }
        let j = st.num_clusters();
        assert!((2..=16).contains(&j), "Walker found {j} clusters, expected ~4");
    }

    #[test]
    fn walker_handles_empty_shard() {
        let ds = SyntheticConfig {
            n: 10,
            d: 8,
            clusters: 2,
            beta: 0.5,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let model = BetaBernoulli::symmetric(8, 0.5);
        let mut st = SuperclusterState::init_from_prior(
            &ds.train,
            Vec::new(),
            0.5,
            &model,
            Pcg64::seed_from(7),
        );
        st.walker_sweep(&ds.train, &model, 0.5);
        assert_eq!(st.num_rows(), 0);
    }
}
