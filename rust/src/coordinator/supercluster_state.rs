//! Per-worker state: one supercluster's shard of the latent variables —
//! its data rows, local cluster slots, and a private RNG stream (so the
//! chain is deterministic regardless of thread scheduling).
//!
//! The local transition operator is unmodified Neal-Alg.-3 collapsed
//! Gibbs with concentration `αμ_k` — exactly the paper's point: standard
//! DPM kernels apply per supercluster without alteration.

use crate::data::BinMat;
use crate::model::{BetaBernoulli, ClusterStats};
use crate::rng::{categorical_log, categorical_log_inplace, Pcg64};

/// One supercluster (= one simulated compute node).
pub struct SuperclusterState {
    /// global row ids resident on this node
    rows: Vec<usize>,
    /// local cluster slot per row (parallel to `rows`)
    assign: Vec<u32>,
    /// slotted local clusters
    clusters: Vec<Option<ClusterStats>>,
    free_slots: Vec<usize>,
    rng: Pcg64,
    // scratch buffers (reused across sweeps; never on the alloc hot path)
    scratch_ids: Vec<u32>,
    scratch_logw: Vec<f64>,
    scratch_ones: Vec<u32>,
}

impl SuperclusterState {
    /// Initialize this shard by a draw from the local CRP(αμ_k) prior
    /// (the paper's §5 initialization).
    pub fn init_from_prior(
        data: &BinMat,
        rows: Vec<usize>,
        local_alpha: f64,
        model: &BetaBernoulli,
        mut rng: Pcg64,
    ) -> Self {
        let n = rows.len();
        let mut st = SuperclusterState {
            rows,
            assign: vec![0; n],
            clusters: Vec::new(),
            free_slots: Vec::new(),
            rng,
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
        };
        rng = st.rng.clone(); // appease borrowck: use the internal stream
        for i in 0..n {
            let r = st.rows[i];
            st.scratch_ids.clear();
            st.scratch_logw.clear();
            for (slot, c) in st.clusters.iter().enumerate() {
                if let Some(c) = c {
                    st.scratch_ids.push(slot as u32);
                    st.scratch_logw.push((c.n() as f64).ln());
                }
            }
            st.scratch_ids.push(u32::MAX);
            st.scratch_logw.push(local_alpha.max(1e-300).ln());
            let pick = categorical_log(&mut rng, &st.scratch_logw);
            let slot = st.place(pick, data, r, model.d);
            st.assign[i] = slot;
        }
        st.rng = rng;
        st
    }

    fn place(&mut self, pick: usize, data: &BinMat, r: usize, d: usize) -> u32 {
        let slot = if self.scratch_ids[pick] == u32::MAX {
            match self.free_slots.pop() {
                Some(s) => {
                    self.clusters[s] = Some(ClusterStats::empty(d));
                    s
                }
                None => {
                    self.clusters.push(Some(ClusterStats::empty(d)));
                    self.clusters.len() - 1
                }
            }
        } else {
            self.scratch_ids[pick] as usize
        };
        self.clusters[slot].as_mut().unwrap().add(data, r);
        slot as u32
    }

    /// One collapsed Gibbs sweep over this shard with concentration
    /// `local_alpha = α μ_k`.
    pub fn gibbs_sweep(&mut self, data: &BinMat, model: &BetaBernoulli, local_alpha: f64) {
        let mut rng = self.rng.clone();
        for i in 0..self.rows.len() {
            let r = self.rows[i];
            let old = self.assign[i] as usize;
            {
                let c = self.clusters[old].as_mut().unwrap();
                c.remove(data, r);
                if c.is_empty() {
                    self.clusters[old] = None;
                    self.free_slots.push(old);
                }
            }
            self.scratch_ids.clear();
            self.scratch_logw.clear();
            // decode the datum's set bits ONCE, score every local
            // cluster from the same index list (perf: §Perf)
            self.scratch_ones.clear();
            let ones = &mut self.scratch_ones;
            data.for_each_one(r, |d| ones.push(d as u32));
            for (slot, c) in self.clusters.iter_mut().enumerate() {
                if let Some(c) = c {
                    self.scratch_ids.push(slot as u32);
                    self.scratch_logw
                        .push(c.log_n() + c.score_ones(model, &self.scratch_ones));
                }
            }
            self.scratch_ids.push(u32::MAX);
            self.scratch_logw
                .push(local_alpha.max(1e-300).ln() + model.empty_cluster_loglik());
            let pick = categorical_log_inplace(&mut rng, &mut self.scratch_logw);
            self.assign[i] = self.place(pick, data, r, model.d);
        }
        self.rng = rng;
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.iter().filter(|c| c.is_some()).count()
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    pub fn clusters(&self) -> impl Iterator<Item = &ClusterStats> {
        self.clusters.iter().flatten()
    }

    /// Push (n_j, c_jd) for every local cluster into `out` (reduce-step
    /// sufficient statistics for dimension `d`).
    pub fn collect_dim_stats(&self, d: usize, out: &mut Vec<(u64, u32)>) {
        for c in self.clusters.iter().flatten() {
            out.push((c.n(), c.ones()[d]));
        }
    }

    pub fn invalidate_caches(&mut self) {
        for c in self.clusters.iter_mut().flatten() {
            c.invalidate_cache();
        }
    }

    /// Remove and return every cluster as (stats, member-row-ids); leaves
    /// this shard empty. Used by the shuffle step.
    pub fn drain_clusters(&mut self, _data: &BinMat) -> Vec<(ClusterStats, Vec<usize>)> {
        let nslots = self.clusters.len();
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); nslots];
        for (i, &slot) in self.assign.iter().enumerate() {
            members[slot as usize].push(self.rows[i]);
        }
        let mut out = Vec::new();
        for (slot, c) in self.clusters.drain(..).enumerate() {
            if let Some(c) = c {
                out.push((c, std::mem::take(&mut members[slot])));
            }
        }
        self.rows.clear();
        self.assign.clear();
        self.free_slots.clear();
        out
    }

    /// Insert a cluster (stats + member rows) into this shard.
    pub fn insert_cluster(&mut self, stats: ClusterStats, member_rows: Vec<usize>) {
        debug_assert_eq!(stats.n() as usize, member_rows.len());
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.clusters[s] = Some(stats);
                s
            }
            None => {
                self.clusters.push(Some(stats));
                self.clusters.len() - 1
            }
        };
        for r in member_rows {
            self.rows.push(r);
            self.assign.push(slot as u32);
        }
    }

    /// Write this shard's assignments into the global z vector with
    /// globally-unique ids starting at `next_id`; returns the next free id.
    pub fn export_assignments(&self, z: &mut [u32], mut next_id: u32) -> u32 {
        let mut slot_to_id: Vec<Option<u32>> = vec![None; self.clusters.len()];
        for (i, &slot) in self.assign.iter().enumerate() {
            let id = *slot_to_id[slot as usize].get_or_insert_with(|| {
                let id = next_id;
                next_id += 1;
                id
            });
            z[self.rows[i]] = id;
        }
        next_id
    }

    /// Append `ln(n_j/(N+α)) + ln p(x_r | cluster)` for every local
    /// cluster (mutable for the score cache).
    pub fn score_against_all(
        &mut self,
        model: &BetaBernoulli,
        test: &BinMat,
        r: usize,
        n_total: f64,
        out: &mut Vec<f64>,
    ) {
        for c in self.clusters.iter_mut().flatten() {
            out.push((c.n() as f64 / n_total).ln() + c.score(model, test, r));
        }
    }

    /// Local cluster-slot assignment per resident row (checkpointing).
    pub fn assignments_local(&self) -> &[u32] {
        &self.assign
    }

    /// Rebuild a shard from persisted (rows, assign) — cluster stats are
    /// recomputed from the data (checkpoint resume).
    pub fn from_parts(
        data: &BinMat,
        rows: Vec<usize>,
        assign: Vec<u32>,
        rng: Pcg64,
    ) -> Result<Self, String> {
        if rows.len() != assign.len() {
            return Err("rows/assign length mismatch".into());
        }
        let nslots = assign.iter().map(|&a| a as usize + 1).max().unwrap_or(0);
        let mut clusters: Vec<Option<ClusterStats>> = (0..nslots).map(|_| None).collect();
        for (i, &slot) in assign.iter().enumerate() {
            let c = clusters[slot as usize]
                .get_or_insert_with(|| ClusterStats::empty(data.dims()));
            if rows[i] >= data.rows() {
                return Err(format!("row id {} out of range", rows[i]));
            }
            c.add(data, rows[i]);
        }
        let free_slots: Vec<usize> = clusters
            .iter()
            .enumerate()
            .filter_map(|(s, c)| c.is_none().then_some(s))
            .collect();
        Ok(SuperclusterState {
            rows,
            assign,
            clusters,
            free_slots,
            rng,
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            scratch_ones: Vec::new(),
        })
    }

    // ---- accessors for the Walker slice kernel (walker.rs) ----

    /// Move the private RNG stream out (returned via [`Self::put_rng`]).
    pub(crate) fn take_rng(&mut self) -> Pcg64 {
        self.rng.clone()
    }

    pub(crate) fn put_rng(&mut self, rng: Pcg64) {
        self.rng = rng;
    }

    /// Occupied cluster slots in order of first appearance along the
    /// shard's datum sequence (the labeling under which Pitman's
    /// size-biased stick posterior applies — see walker.rs).
    pub(crate) fn slots_by_appearance(&self) -> Vec<usize> {
        let mut seen = vec![false; self.clusters.len()];
        let mut out = Vec::new();
        for &slot in &self.assign {
            let s = slot as usize;
            if !seen[s] {
                seen[s] = true;
                out.push(s);
            }
        }
        out
    }

    /// Occupied cluster slots in persistent slot order.
    pub(crate) fn occupied_slots(&self) -> Vec<usize> {
        self.clusters
            .iter()
            .enumerate()
            .filter_map(|(s, c)| c.as_ref().map(|_| s))
            .collect()
    }

    pub(crate) fn num_slots(&self) -> usize {
        self.clusters.len()
    }

    pub(crate) fn cluster_n(&self, slot: usize) -> u64 {
        self.clusters[slot].as_ref().map(|c| c.n()).unwrap_or(0)
    }

    pub(crate) fn assign_of(&self, i: usize) -> u32 {
        self.assign[i]
    }

    pub(crate) fn row_of(&self, i: usize) -> usize {
        self.rows[i]
    }

    /// Remove datum index `i` from its cluster WITHOUT freeing the slot
    /// if it empties (Walker keeps emptied tables selectable through
    /// their stick until the end of the sweep).
    pub(crate) fn remove_row_keep_slot(&mut self, i: usize, data: &BinMat) {
        let slot = self.assign[i] as usize;
        self.clusters[slot]
            .as_mut()
            .expect("remove from dead slot")
            .remove(data, self.rows[i]);
    }

    pub(crate) fn add_row_to_slot(&mut self, i: usize, slot: usize, data: &BinMat) {
        self.clusters[slot]
            .as_mut()
            .expect("add to dead slot")
            .add(data, self.rows[i]);
        self.assign[i] = slot as u32;
    }

    /// Materialize a fresh cluster containing datum `i`; returns the slot.
    pub(crate) fn add_row_to_new_cluster(&mut self, i: usize, data: &BinMat, d: usize) -> usize {
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.clusters[s] = Some(ClusterStats::empty(d));
                s
            }
            None => {
                self.clusters.push(Some(ClusterStats::empty(d)));
                self.clusters.len() - 1
            }
        };
        self.clusters[slot].as_mut().unwrap().add(data, self.rows[i]);
        self.assign[i] = slot as u32;
        slot
    }

    /// Collapsed predictive log-likelihood of row `r` under `slot`
    /// (empty clusters score as fresh tables).
    pub(crate) fn score_slot(
        &mut self,
        slot: usize,
        model: &BetaBernoulli,
        data: &BinMat,
        r: usize,
    ) -> f64 {
        self.clusters[slot]
            .as_mut()
            .expect("score dead slot")
            .score(model, data, r)
    }

    /// Free every empty-but-alive slot (end of a Walker sweep).
    pub(crate) fn compact_free_slots(&mut self) {
        for s in 0..self.clusters.len() {
            let empty = matches!(&self.clusters[s], Some(c) if c.is_empty());
            if empty {
                self.clusters[s] = None;
                self.free_slots.push(s);
            }
        }
    }

    /// Integrity check: stats match the member rows exactly.
    pub fn check_invariants(&self, data: &BinMat) -> Result<(), String> {
        if self.rows.len() != self.assign.len() {
            return Err("rows/assign length mismatch".into());
        }
        let mut rebuilt: Vec<ClusterStats> = self
            .clusters
            .iter()
            .map(|_| ClusterStats::empty(data.dims()))
            .collect();
        for (i, &slot) in self.assign.iter().enumerate() {
            let slot = slot as usize;
            if slot >= self.clusters.len() || self.clusters[slot].is_none() {
                return Err(format!("row idx {i} assigned to dead slot {slot}"));
            }
            rebuilt[slot].add(data, self.rows[i]);
        }
        for (slot, c) in self.clusters.iter().enumerate() {
            if let Some(c) = c {
                if c.is_empty() {
                    return Err(format!("slot {slot} empty but not freed"));
                }
                if c.n() != rebuilt[slot].n() || c.ones() != rebuilt[slot].ones() {
                    return Err(format!("slot {slot} stats mismatch"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn make_state(seed: u64) -> (crate::data::Dataset, SuperclusterState, BetaBernoulli) {
        let ds = SyntheticConfig {
            n: 200,
            d: 16,
            clusters: 4,
            beta: 0.1,
            seed,
        }
        .generate_with_test_fraction(0.0);
        let model = BetaBernoulli::symmetric(16, 0.5);
        let rows: Vec<usize> = (0..ds.train.rows()).collect();
        let st = SuperclusterState::init_from_prior(
            &ds.train,
            rows,
            1.0,
            &model,
            Pcg64::seed_from(seed),
        );
        (ds, st, model)
    }

    #[test]
    fn init_and_sweeps_preserve_invariants() {
        let (ds, mut st, model) = make_state(1);
        st.check_invariants(&ds.train).unwrap();
        for _ in 0..3 {
            st.gibbs_sweep(&ds.train, &model, 1.0);
            st.check_invariants(&ds.train).unwrap();
        }
        assert!(st.num_clusters() >= 1);
        assert_eq!(st.num_rows(), 200);
    }

    #[test]
    fn drain_insert_roundtrip() {
        let (ds, mut st, _model) = make_state(2);
        let nc = st.num_clusters();
        let nr = st.num_rows();
        let drained = st.drain_clusters(&ds.train);
        assert_eq!(drained.len(), nc);
        assert_eq!(st.num_rows(), 0);
        for (stats, rows) in drained {
            st.insert_cluster(stats, rows);
        }
        assert_eq!(st.num_clusters(), nc);
        assert_eq!(st.num_rows(), nr);
        st.check_invariants(&ds.train).unwrap();
    }

    #[test]
    fn export_assignments_unique_ids() {
        let (ds, st, _model) = make_state(3);
        let mut z = vec![u32::MAX; ds.train.rows()];
        let next = st.export_assignments(&mut z, 5);
        assert_eq!(next as usize, 5 + st.num_clusters());
        assert!(z.iter().all(|&id| id >= 5 && id < next));
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, mut a, model) = make_state(4);
        let (_, mut b, _) = make_state(4);
        for _ in 0..2 {
            a.gibbs_sweep(&ds.train, &model, 0.7);
            b.gibbs_sweep(&ds.train, &model, 0.7);
        }
        let mut za = vec![0u32; ds.train.rows()];
        let mut zb = vec![0u32; ds.train.rows()];
        a.export_assignments(&mut za, 0);
        b.export_assignments(&mut zb, 0);
        assert_eq!(za, zb);
    }
}
