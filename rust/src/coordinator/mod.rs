//! Layer 3 — the paper's system contribution: the parallel MCMC
//! coordinator for Dirichlet-process mixtures (§4–5, Fig. 3).
//!
//! Every global round is one map-reduce cycle:
//!
//! * **map** — each supercluster (= compute node, one [`Shard`]) runs
//!   `R` local sweeps of its assigned [`TransitionKernel`] (kernels may
//!   differ across shards — [`KernelAssignment`]) over its own data
//!   with concentration `αμ_k`, using standard DPM operators
//!   *without modification* (Neal Alg. 3, Walker slice, or the Jain–Neal
//!   split–merge composites — see [`crate::sampler`] and the selection
//!   guide in DESIGN.md §7); data may instantiate new clusters locally
//!   but cannot cross nodes. Global split–merge moves run *inside* each
//!   shard against its conditional `DP(αμ_k, H)`, so even
//!   cluster-creating/dissolving moves parallelize.
//! * **reduce** — centralized, lightweight: sample `α` from Eq. 6 given
//!   `Σ_k J_k` (each worker ships one integer), the base-measure
//!   hyperparameters `β_d` by griddy Gibbs from pooled sufficient
//!   statistics, and — under a non-uniform [`MuMode`] — the supercluster
//!   weights μ themselves (Gibbs from `Dir(ξ/K + J_k)`, or the adaptive
//!   load-balancing MH retarget; DESIGN.md §6).
//! * **shuffle** — move whole clusters (stats + member rows) between
//!   superclusters by Gibbs on `s_j`, then broadcast the new state.
//!
//! The representation keeps the *true* DPM posterior invariant — the DP
//! "learns how to parallelize itself".
//!
//! Rounds run bulk-synchronously by default; `--overlap on`
//! ([`CoordinatorConfig::overlap`]) switches to the barrier-free
//! schedule — a genuinely concurrent host pipeline: shard completions
//! are consumed as they land (staging shuffle state and granting
//! work-stealing bonus sweeps while slow shards still sweep), the
//! shuffle and the α/β/μ reduce then run from the staged snapshot on
//! the coordinator thread, and the round reports **measured** concurrent
//! wall-clock alongside the `max(map, carry)` modeled figure
//! (DESIGN.md § Barrier-free rounds).
//!
//! ```
//! use clustercluster::coordinator::{Coordinator, CoordinatorConfig, MuMode};
//! use clustercluster::data::synthetic::SyntheticConfig;
//! use clustercluster::mapreduce::CommModel;
//! use clustercluster::rng::Pcg64;
//!
//! let ds = SyntheticConfig { n: 120, d: 8, clusters: 2, beta: 0.3, seed: 3 }
//!     .generate_with_test_fraction(0.0);
//! let cfg = CoordinatorConfig {
//!     workers: 2,
//!     mu_mode: MuMode::SizeProportional, // granularity tracks occupancy
//!     comm: CommModel::free(),
//!     ..Default::default()
//! };
//! let mut rng = Pcg64::seed_from(1);
//! let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
//! for _ in 0..3 { coord.step(&mut rng); }
//! assert!((coord.mu().iter().sum::<f64>() - 1.0).abs() < 1e-9);
//! coord.check_invariants().unwrap();
//! ```
//!
//! [`TransitionKernel`]: crate::sampler::TransitionKernel

pub mod checkpoint;

use crate::data::DataRef;
use crate::mapreduce::{
    finish_round, finish_round_overlapped, CommModel, DelayHook, FaultHook, MapReduce,
    OverlappedTiming, RoundStats, SupervisedDirective, SupervisedOutcome,
};
use crate::model::alpha::{sample_alpha, GammaPrior};
use crate::model::hyper::{BetaGridConfig, BetaUpdater};
use crate::model::{Model, ModelSpec};
use crate::rng::Pcg64;
use crate::special::logsumexp;
use crate::runtime::Scorer;
use crate::sampler::{KernelKind, ScoreMode, Shard, ShardSnapshot, TableSet, TableSetBuilder};
use crate::supercluster::{
    adaptive_mu_step, sample_mu_given_occupancy, sample_shuffle, ShuffleKernel,
};
use crate::util::timer::PhaseTimer;
use std::time::{Duration, Instant};

pub use checkpoint::{Checkpoint, CheckpointDir};
pub use crate::sampler::KernelAssignment;
// Back-compat names: the per-worker state is a plain sampler Shard, and
// the kernel selector is the sampler-level KernelKind.
pub use crate::sampler::KernelKind as LocalKernel;
pub use crate::sampler::Shard as SuperclusterState;

/// How the supercluster base weights μ are set — the *granularity of
/// parallelization* (paper §4: μ apportions the DP's mass, and thereby
/// the data, across the K compute nodes, while the partition posterior
/// is invariant to μ).
///
/// Every mode leaves the true DPM posterior exact (the μ updates are
/// Gibbs/Metropolis–Hastings steps on the extended state — see
/// DESIGN.md §6 and `rust/tests/mu_modes.rs`); they differ only in load
/// balance and mixing:
///
/// * [`MuMode::Uniform`] — μ fixed at 1/K (the paper's choice); zero
///   overhead, but load follows wherever the clusters drift.
/// * [`MuMode::SizeProportional`] — μ resampled each round from its
///   conditional `Dir(ξ/K + J_k)` given current supercluster cluster
///   counts; mass tracks where structure lives, which concentrates
///   shuffle moves on populated shards.
/// * [`MuMode::Adaptive`] — μ retargeted each round by an MH step whose
///   proposal shrinks superclusters exceeding the per-shard data-share
///   ceiling `target_occupancy / K`; steers toward equalized per-shard
///   work while remaining exact.
///
/// ```
/// use clustercluster::coordinator::MuMode;
///
/// assert_eq!(MuMode::parse("uniform").unwrap(), MuMode::Uniform);
/// assert_eq!(MuMode::parse("size-prop").unwrap(), MuMode::SizeProportional);
/// assert_eq!(
///     MuMode::parse("adaptive:1.5").unwrap(),
///     MuMode::Adaptive { target_occupancy: 1.5 },
/// );
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum MuMode {
    /// μ_k = 1/K (the paper's choice, and the default).
    #[default]
    Uniform,
    /// Gibbs-resample μ from `Dir(ξ/K + J_k)` given supercluster
    /// occupancies each global round.
    SizeProportional,
    /// Metropolis–Hastings retarget of μ toward equalized per-shard
    /// work between macro-sweeps.
    Adaptive {
        /// Allowed per-shard data share as a multiple of the uniform
        /// share 1/K; `1.0` steers toward strict equalization, larger
        /// values tolerate proportionally more imbalance.
        target_occupancy: f64,
    },
}

impl MuMode {
    /// Parse a `--mu-mode` value: `uniform`, `size-proportional` (alias
    /// `size-prop`, `size`, `proportional`), or `adaptive[:TARGET]`
    /// (TARGET = occupancy ceiling multiple, default 1.0).
    pub fn parse(s: &str) -> Result<MuMode, String> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "uniform" => Ok(MuMode::Uniform),
            "size-proportional" | "size-prop" | "size" | "proportional" => {
                Ok(MuMode::SizeProportional)
            }
            "adaptive" => Ok(MuMode::Adaptive {
                target_occupancy: 1.0,
            }),
            _ => match lower.strip_prefix("adaptive:") {
                Some(t) => {
                    let target: f64 = t
                        .parse()
                        .map_err(|_| format!("bad adaptive target {t:?}"))?;
                    if target > 0.0 && target.is_finite() {
                        Ok(MuMode::Adaptive {
                            target_occupancy: target,
                        })
                    } else {
                        Err(format!("adaptive target must be positive, got {t:?}"))
                    }
                }
                None => Err(format!(
                    "unknown μ mode {s:?} (expected \"uniform\", \"size-proportional\", \
                     or \"adaptive[:target]\")"
                )),
            },
        }
    }

    /// Human-readable name for run banners and logs.
    pub fn describe(&self) -> String {
        match self {
            MuMode::Uniform => "uniform".to_string(),
            MuMode::SizeProportional => "size-proportional".to_string(),
            MuMode::Adaptive { target_occupancy } => {
                format!("adaptive(target={target_occupancy})")
            }
        }
    }
}

/// Per-supercluster observability record for the most recent global
/// round — what makes the non-uniform [`MuMode`]s inspectable (exported
/// as a CSV series by `--shard-trace`, via
/// [`crate::metrics::ShardTrace`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRoundStat {
    /// supercluster index k
    pub shard: usize,
    /// μ_k after this round's granularity update (drives the next map
    /// step's local concentration αμ_k)
    pub mu: f64,
    /// data rows resident on the shard after the round
    pub rows: u64,
    /// live clusters on the shard after the round
    pub clusters: u64,
    /// measured map-step compute seconds for the shard this round
    pub map_seconds: f64,
    /// measured sweep throughput for the shard this round
    /// (pre-shuffle resident rows × sweeps run (base + bonus) /
    /// map_seconds — the rows the map step actually processed; 0 when
    /// unmeasurable) — the per-shard observable behind the hot-path
    /// bench numbers
    pub rows_per_s: f64,
    /// residual idle seconds this round. Under `--overlap on` this is
    /// **measured** wall-clock: the gap between the instant this shard's
    /// final completion (base + any bonus grants) drained and the
    /// instant the round's map window closed — real waiting on the real
    /// timeline. Under bulk it is reconstructed from durations (map
    /// critical path − this shard's map time), since a bulk round has no
    /// per-completion timestamps.
    pub idle_s: f64,
    /// what the shard's wait would have been with NO bonus sweeps — the
    /// bulk-synchronous barrier tax, recorded in both modes so
    /// `--overlap on|off` traces are comparable. Under `--overlap on`
    /// it is **measured**: window close − the instant the shard's *base*
    /// sweeps completed (so `barrier_wait_s − idle_s` is the wait the
    /// bonus grants actually absorbed). Under bulk it equals `idle_s`.
    pub barrier_wait_s: f64,
    /// work-stealing bonus sweeps granted to this shard this round
    /// (always 0 with `--overlap off`)
    pub bonus_sweeps: u64,
    /// supervised retries this shard consumed this round (always 0
    /// with `--supervise off`)
    pub retries: u32,
    /// watchdog timeouts that fired on this shard's attempts this round
    pub watchdog_fires: u32,
    /// whether this shard ran this round degraded (quarantined: sweep
    /// skipped, assignments frozen, stats still reduced)
    pub quarantined: bool,
    /// the transition kernel this shard runs
    pub kernel: KernelKind,
}

/// Fault-tolerance policy for supervised coordinator rounds
/// (`--supervise on`; DESIGN.md §12). Disabled by default: rounds then
/// run the legacy paths bit-exactly, where a shard panic aborts the
/// round after the drain.
///
/// With `enabled`, a shard attempt that panics, hits an injected I/O
/// error, or trips the map-window watchdog is **rebuilt from its
/// pre-round [`ShardSnapshot`] and retried** with bounded exponential
/// backoff (`backoff_base · 2^(r−1)`, capped at `backoff_cap`). A
/// retried attempt replays the identical sweep from the identical
/// state and private RNG stream, so a transient fault leaves the chain
/// **bit-identical** to a fault-free run. After `max_retries` the shard
/// is **quarantined**: for `cooldown_rounds` subsequent rounds it runs
/// degraded — rows keep their assignments, the sweep is skipped (zero
/// sweeps = composing fewer posterior-invariant kernels, so the chain
/// stays exact), its statistics still fold into the α/β reduces, and
/// its clusters still participate in the shuffle (frozen rows can
/// migrate to healthy shards, preserving ergodicity) — then it is
/// automatically reintegrated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SuperviseConfig {
    /// master switch; `false` ⇒ bit-exact legacy behavior
    pub enabled: bool,
    /// failed attempts retried per shard per round before quarantine
    pub max_retries: u32,
    /// backoff before retry r: `backoff_base · 2^(r−1)`, capped below
    pub backoff_base: Duration,
    /// ceiling on the exponential backoff
    pub backoff_cap: Duration,
    /// watchdog deadline on the map window (`--round-timeout`): when no
    /// completion lands within it, every unfinished shard's attempt is
    /// treated as stalled and takes the same recovery path as a panic.
    /// `None` disables the watchdog. Inline execution
    /// (`parallelism == 1`) cannot be preempted, so the watchdog only
    /// fires on pooled rounds.
    pub round_timeout: Option<Duration>,
    /// degraded rounds a quarantined shard sits out before reintegration
    pub cooldown_rounds: u64,
}

impl Default for SuperviseConfig {
    fn default() -> Self {
        SuperviseConfig {
            enabled: false,
            max_retries: 2,
            backoff_base: Duration::from_millis(25),
            backoff_cap: Duration::from_secs(1),
            round_timeout: None,
            cooldown_rounds: 3,
        }
    }
}

/// Recovery verdict of [`RoundSupervisor::on_failure`].
enum RecoveryAction {
    /// rebuild from the pre-round snapshot, replay the full base
    /// sweeps after this backoff
    Retry(Duration),
    /// retries exhausted: quarantine the shard and run one zero-sweep
    /// attempt so its (unswept) state still stages into the round
    Degrade,
    /// even the zero-sweep attempt failed: give up on the map task —
    /// the post-window fixup restores the snapshot on the coordinator
    Abandon,
}

/// Per-round supervision bookkeeping shared by the bulk and overlapped
/// supervised map windows: retry budgets, watchdog counts, and the
/// three quarantine stages (entered-quarantined, newly degraded,
/// abandoned).
struct RoundSupervisor {
    cfg: SuperviseConfig,
    /// shard was already quarantined when the round started (runs a
    /// zero-sweep attempt; failures are not retried)
    quarantined_entry: Vec<bool>,
    retries: Vec<u32>,
    watchdog_fires: Vec<u32>,
    /// exhausted its retries THIS round (zero-sweep attempt issued)
    degraded: Vec<bool>,
    abandoned: Vec<bool>,
}

impl RoundSupervisor {
    fn new(cfg: SuperviseConfig, quarantined_entry: Vec<bool>) -> Self {
        let k = quarantined_entry.len();
        RoundSupervisor {
            cfg,
            quarantined_entry,
            retries: vec![0; k],
            watchdog_fires: vec![0; k],
            degraded: vec![false; k],
            abandoned: vec![false; k],
        }
    }

    /// Decide what to do about a failed/stalled attempt of shard `kk`.
    fn on_failure(&mut self, kk: usize, timed_out: bool) -> RecoveryAction {
        if timed_out {
            self.watchdog_fires[kk] += 1;
        }
        if self.quarantined_entry[kk] || self.degraded[kk] {
            // the zero-sweep attempt failed too: nothing left to retry
            self.abandoned[kk] = true;
            return RecoveryAction::Abandon;
        }
        if self.retries[kk] < self.cfg.max_retries {
            self.retries[kk] += 1;
            let shift = (self.retries[kk] - 1).min(20);
            let backoff = self
                .cfg
                .backoff_base
                .saturating_mul(1u32 << shift)
                .min(self.cfg.backoff_cap);
            RecoveryAction::Retry(backoff)
        } else {
            self.degraded[kk] = true;
            RecoveryAction::Degrade
        }
    }

    /// Whether shard `kk` may still receive work-stealing bonus grants
    /// this round (quarantined/degraded shards never sweep).
    fn bonus_allowed(&self, kk: usize) -> bool {
        !self.quarantined_entry[kk] && !self.degraded[kk]
    }

    /// Whether shard `kk` ran this round degraded in any form.
    fn quarantined_this_round(&self, kk: usize) -> bool {
        self.quarantined_entry[kk] || self.degraded[kk] || self.abandoned[kk]
    }
}

/// Coordinator configuration.
///
/// ```
/// use clustercluster::coordinator::{CoordinatorConfig, KernelAssignment, MuMode};
/// use clustercluster::sampler::KernelKind;
///
/// // 8 workers, adaptive granularity, Gibbs/Walker alternating by shard
/// let cfg = CoordinatorConfig {
///     workers: 8,
///     mu_mode: MuMode::Adaptive { target_occupancy: 1.0 },
///     kernel_assignment: KernelAssignment::RoundRobin(vec![
///         KernelKind::CollapsedGibbs,
///         KernelKind::WalkerSlice,
///     ]),
///     ..Default::default()
/// };
/// assert_eq!(cfg.kernel_assignment.resolve(cfg.workers).unwrap().len(), 8);
/// ```
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// number of superclusters K (= simulated compute nodes)
    pub workers: usize,
    /// local kernel sweeps per global round (Fig. 2a's ratio)
    pub local_sweeps: usize,
    /// initial concentration α (the §5 calibration value)
    pub init_alpha: f64,
    /// Gamma prior driving the Eq. 6 α update
    pub alpha_prior: GammaPrior,
    /// initial symmetric β for all dims
    pub init_beta: f64,
    /// grid for the griddy-Gibbs β_d update
    pub beta_grid: BetaGridConfig,
    /// update α each round (reduce step)
    pub update_alpha: bool,
    /// β_d updates are O(D · grid · J): on by default at reduce cadence
    pub update_beta: bool,
    /// enable the cluster shuffle step (ablation: without it the islands
    /// never exchange structure and the chain is NOT a DPM sampler)
    pub shuffle: bool,
    /// which shuffle conditional updates `s_j` (Exact vs the paper's
    /// printed Eq. 7 — see [`crate::supercluster`])
    pub shuffle_kernel: ShuffleKernel,
    /// supercluster granularity: how μ is set/updated between rounds
    /// (`--mu-mode`; every mode is exactness-preserving)
    pub mu_mode: MuMode,
    /// per-supercluster transition operators (paper §4: any standard DPM
    /// kernel applies unmodified per supercluster, and different shards
    /// may run different kernels —
    /// `--local-kernel gibbs,split_merge:walker,…`)
    pub kernel_assignment: KernelAssignment,
    /// candidate-cluster scoring dispatch inside the map-step sweeps
    /// (`--scorer auto|fallback|pjrt`; one scorer instance per shard)
    pub scoring: ScoreMode,
    /// communication cost model for the modeled distributed wall-clock
    pub comm: CommModel,
    /// host threads for the map step (0 = one per available core)
    pub parallelism: usize,
    /// barrier-free rounds (`--overlap on`): stage shuffle moves into a
    /// swap buffer, run the global hyper updates on the post-shuffle
    /// reduced statistics, grant lightly-loaded shards bonus sweeps,
    /// and model the round wall-clock as `max(map, carry_prev)` instead
    /// of the serialized sum (DESIGN.md § Barrier-free rounds). Off by
    /// default: the bulk-synchronous schedule stays the pinned
    /// reference (K=1 bit-equivalence, enumeration gates)
    pub overlap: bool,
    /// cap on work-stealing bonus sweeps per shard per round under
    /// `overlap` (0 disables stealing; ignored with overlap off). The
    /// grant is a deterministic function of pre-round resident row
    /// counts, so the kernel composition stays reproducible and valid
    pub max_bonus_sweeps: usize,
    /// component likelihood (`--model`); must match the data kind
    /// handed to [`Coordinator::new`] (see [`ModelSpec::build`])
    pub model: ModelSpec,
    /// fault-tolerance policy for supervised rounds (`--supervise`,
    /// `--round-timeout`, `--max-retries`, …; DESIGN.md §12). Off by
    /// default ⇒ the legacy abort-on-panic paths run bit-exactly
    pub supervise: SuperviseConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            local_sweeps: 1,
            init_alpha: 1.0,
            alpha_prior: GammaPrior::default(),
            init_beta: 0.5,
            beta_grid: BetaGridConfig::default(),
            update_alpha: true,
            update_beta: false,
            shuffle: true,
            shuffle_kernel: ShuffleKernel::Exact,
            mu_mode: MuMode::Uniform,
            kernel_assignment: KernelAssignment::default(),
            scoring: ScoreMode::default(),
            comm: CommModel::default(),
            parallelism: 1,
            overlap: false,
            max_bonus_sweeps: 2,
            model: ModelSpec::Bernoulli,
            supervise: SuperviseConfig::default(),
        }
    }
}

/// Plan this round's work-stealing bonus sweeps from pre-round resident
/// row counts: shard k gets `min(max_bonus_sweeps, ⌊(rows_max − rows_k)
/// / rows_k⌋)` extra local sweeps — roughly as many as fit inside the
/// time the heaviest shard needs for its base sweep, assuming per-row
/// cost. Row counts are **sweep-invariant** (map sweeps never move data
/// across shards), so the grant is a deterministic function of a
/// statistic the local kernels cannot change: running `base + b_k`
/// sweeps of an invariant kernel is itself an invariant kernel on every
/// slice of the state space, which is what keeps the overlapped
/// composition exact (DESIGN.md § Barrier-free rounds). Empty shards
/// and the heaviest shard get 0; at K=1 or under balanced loads every
/// grant is 0, so `--overlap on` degrades gracefully to the base
/// schedule.
pub fn plan_bonus_sweeps(row_counts: &[u64], max_bonus_sweeps: usize) -> Vec<usize> {
    let rows_max = row_counts.iter().copied().max().unwrap_or(0);
    row_counts
        .iter()
        .map(|&r| {
            if r == 0 || r >= rows_max {
                0
            } else {
                (((rows_max - r) / r) as usize).min(max_bonus_sweeps)
            }
        })
        .collect()
}

/// One staged shuffle move: a drained cluster's sufficient statistics,
/// its member rows, and the supercluster it was (re)assigned to — the
/// swap-buffer entry [`Coordinator`] stages decisions into before
/// applying them.
type StagedMove = (crate::model::ClusterStats, Vec<usize>, usize);

/// One shuffle decision of the most recent round, in canonical drain
/// order (shard index, then cluster slot within the shard). Exposed via
/// [`Coordinator::last_shuffle_moves`] so tests can assert the staged-
/// move drain order is a function of the chain state alone — never of
/// the completion order the concurrent scheduler happened to produce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShuffleMove {
    /// supercluster the cluster was drained from
    pub from: usize,
    /// sampled destination supercluster (may equal `from`)
    pub to: usize,
    /// member rows the cluster carries
    pub rows: usize,
}

/// The distributed sampler state: K supercluster shards + global hypers.
pub struct Coordinator<'a> {
    data: DataRef<'a>,
    /// collapsed component likelihood (Beta–Bernoulli by default — see
    /// [`CoordinatorConfig::model`]; shared read-only by shards)
    pub model: Model,
    /// current concentration α
    pub alpha: f64,
    mu: Vec<f64>,
    cfg: CoordinatorConfig,
    /// one transition kernel selector per shard, resolved from
    /// [`CoordinatorConfig::kernel_assignment`] at construction
    shard_kernels: Vec<KernelKind>,
    states: Vec<Shard>,
    beta_updater: BetaUpdater,
    mr: MapReduce,
    /// per-phase wall-clock accounting (map/reduce/shuffle)
    pub timer: PhaseTimer,
    /// cumulative modeled distributed wall-clock (s)
    pub modeled_time_s: f64,
    /// cumulative measured host wall-clock (s)
    pub measured_time_s: f64,
    /// completed global rounds
    pub rounds: u64,
    /// per-shard observability records for the most recent round
    last_shard_stats: Vec<ShardRoundStat>,
    /// bytes the most recent round's shuffle step moved (0 when the
    /// shuffle is disabled or K = 1)
    last_shuffle_bytes: u64,
    /// the most recent round's shuffle decisions in canonical drain
    /// order (empty when the shuffle is disabled or K = 1)
    last_shuffle_moves: Vec<ShuffleMove>,
    /// adaptive-μ MH proposals attempted (Adaptive mode only)
    mu_proposals: u64,
    /// adaptive-μ MH proposals accepted (Adaptive mode only)
    mu_accepts: u64,
    /// the previous overlapped round's hidden tail (its shuffle
    /// transfer time + global-update compute), which the NEXT round
    /// pays only to the extent it exceeds the map critical path
    /// (`--overlap on` modeling; always 0 in bulk mode)
    prev_carry_s: f64,
    /// per-shard quarantine horizon: `Some(r)` means the shard runs
    /// degraded (zero sweeps) in every round whose index is `< r`, then
    /// reintegrates automatically (supervised rounds only)
    quarantined_until: Vec<Option<u64>>,
    /// most recent round's per-shard supervision counters (empty unless
    /// the round ran supervised)
    sup_retries: Vec<u32>,
    sup_watchdog: Vec<u32>,
    sup_quarantined: Vec<bool>,
    /// lifetime quarantine entries (first one is logged, the rest are
    /// counted silently — the `note_stick_overflow` pattern)
    quarantine_events: u64,
    // persistent reduce/eval scratch (reused every round — the reduce
    // step and trace-time evaluation allocate nothing at steady state)
    beta_scratch: Vec<f64>,
    pl_w1: Vec<f32>,
    pl_w0: Vec<f32>,
    pl_logpi: Vec<f32>,
}

impl std::fmt::Debug for Coordinator<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("workers", &self.cfg.workers)
            .field("rounds", &self.rounds)
            .field("alpha", &self.alpha)
            .field("clusters", &self.num_clusters())
            .finish_non_exhaustive()
    }
}

impl<'a> Coordinator<'a> {
    /// Initialize per the paper (§5): data assigned to superclusters
    /// uniformly at random, clustering initialized by a draw from the
    /// local Chinese restaurant prior. With K=1 the (trivial) random
    /// data placement is skipped, so the master stream is consumed
    /// exactly as by [`crate::serial::SerialGibbs::init_from_prior`] —
    /// the coordinate that makes K=1 equivalence chain-exact.
    ///
    /// # Panics
    ///
    /// Panics on an invalid configuration: `workers == 0`,
    /// `local_sweeps == 0`, a [`KernelAssignment`] that does not
    /// resolve to `workers` kernels (e.g. a `PerShard` list of the
    /// wrong length), or a [`CoordinatorConfig::model`] that does not
    /// match the data kind. Validate with
    /// [`KernelAssignment::resolve`] / [`ModelSpec::build`] first for a
    /// recoverable error — [`Coordinator::resume`] does exactly that
    /// and returns `Err` instead.
    pub fn new(
        data: impl Into<DataRef<'a>>,
        cfg: CoordinatorConfig,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(cfg.workers >= 1 && cfg.local_sweeps >= 1);
        let data = data.into();
        let k = cfg.workers;
        // every mode starts uniform: SizeProportional/Adaptive evolve μ
        // from there via their (exactness-preserving) per-round updates
        let mu = vec![1.0 / k as f64; k];
        let shard_kernels = cfg
            .kernel_assignment
            .resolve(k)
            .unwrap_or_else(|e| panic!("kernel assignment invalid: {e}"));
        let mut model = cfg
            .model
            .build(data, cfg.init_beta)
            .unwrap_or_else(|e| panic!("Coordinator: {e}"));
        // symmetric-beta fast-rebuild LUT for the kernel hot loop (perf;
        // no-op for the non-Bernoulli likelihoods)
        model.build_lut(data.rows() + 1);

        // uniform random data → supercluster assignment
        let mut rows_per: Vec<Vec<usize>> = vec![Vec::new(); k];
        if k == 1 {
            rows_per[0] = (0..data.rows()).collect();
        } else {
            for r in 0..data.rows() {
                rows_per[rng.next_below(k as u64) as usize].push(r);
            }
        }
        let states: Vec<Shard> = rows_per
            .into_iter()
            .enumerate()
            .map(|(kk, rows)| {
                let worker_rng = rng.split(kk as u64);
                let mut st =
                    Shard::init_from_prior(data, rows, cfg.init_alpha * mu[kk], worker_rng);
                st.set_score_mode(cfg.scoring);
                st
            })
            .collect();

        // never keep more pool threads than there are map tasks per round
        let parallelism = if cfg.parallelism == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.parallelism
        }
        .min(cfg.workers);

        let beta_updater = BetaUpdater::new(cfg.beta_grid);
        Coordinator {
            data,
            model,
            alpha: cfg.init_alpha,
            mu,
            shard_kernels,
            cfg,
            states,
            beta_updater,
            mr: MapReduce::new(parallelism),
            timer: PhaseTimer::new(),
            modeled_time_s: 0.0,
            measured_time_s: 0.0,
            rounds: 0,
            last_shard_stats: Vec::new(),
            last_shuffle_bytes: 0,
            last_shuffle_moves: Vec::new(),
            mu_proposals: 0,
            mu_accepts: 0,
            prev_carry_s: 0.0,
            quarantined_until: vec![None; k],
            sup_retries: Vec::new(),
            sup_watchdog: Vec::new(),
            sup_quarantined: Vec::new(),
            quarantine_events: 0,
            beta_scratch: Vec::new(),
            pl_w1: Vec::new(),
            pl_w0: Vec::new(),
            pl_logpi: Vec::new(),
        }
    }

    /// One global round, under the configured schedule
    /// ([`CoordinatorConfig::overlap`]). Returns the round's stats.
    ///
    /// * **bulk-synchronous** (default): map (R local sweeps per node,
    ///   each shard on its assigned kernel) → reduce (α, β, μ
    ///   granularity update) → shuffle (cluster moves + broadcast) —
    ///   the pinned reference schedule.
    /// * **overlapped**: bonus-sweep planning → map (base + bonus
    ///   sweeps) → shuffle staged against the α, μ the sweeps ran under
    ///   → reduce on the post-shuffle statistics, with this round's
    ///   shuffle transfer and global updates modeled as hidden behind
    ///   the next round's map (DESIGN.md § Barrier-free rounds).
    pub fn step(&mut self, rng: &mut Pcg64) -> RoundStats {
        if self.cfg.overlap {
            self.step_overlapped(rng)
        } else {
            self.step_bulk(rng)
        }
    }

    /// Round-entry supervision bookkeeping, run at the top of BOTH step
    /// paths (cheap no-op work with `--supervise off`): stamp this
    /// round's index into the fault-injection layer, reset the
    /// per-round counters, reintegrate shards whose quarantine
    /// cool-down expired, and return the per-shard entered-quarantined
    /// flags for this round.
    fn begin_round_supervision(&mut self) -> Vec<bool> {
        self.mr.set_fault_round(self.rounds);
        self.sup_retries.clear();
        self.sup_watchdog.clear();
        self.sup_quarantined.clear();
        let round = self.rounds;
        self.quarantined_until
            .iter_mut()
            .map(|q| match *q {
                Some(until) if round < until => true,
                Some(_) => {
                    // cool-down expired: automatic reintegration
                    *q = None;
                    false
                }
                None => false,
            })
            .collect()
    }

    /// Stamp the most recent supervised window's aggregate counters
    /// into a round's [`RoundStats`] (all three stay 0 with
    /// `--supervise off`, where the per-round vectors are empty).
    fn stamp_supervision_counters(&self, rs: &mut RoundStats) {
        rs.retries = self.sup_retries.iter().map(|&r| r as u64).sum();
        rs.watchdog_fires = self.sup_watchdog.iter().map(|&w| w as u64).sum();
        rs.quarantined_shards = self.sup_quarantined.iter().filter(|&&q| q).count() as u64;
    }

    /// Fold a supervised map window's verdicts back into the
    /// coordinator: publish the per-round counters (read by
    /// [`Self::shard_stats`] / the round's [`RoundStats`]), and arm or
    /// extend the quarantine horizon of every shard that exhausted its
    /// retries (or failed even its degraded zero-sweep attempt) this
    /// round. Only the first quarantine event ever is logged; the rest
    /// are counted silently ([`Self::quarantine_events`] — the
    /// stick-overflow pattern, because the fault-matrix tests drive
    /// tens of thousands of degraded rounds).
    fn finish_round_supervision(&mut self, sup: &RoundSupervisor) {
        let k = sup.retries.len();
        self.sup_retries = sup.retries.clone();
        self.sup_watchdog = sup.watchdog_fires.clone();
        self.sup_quarantined = (0..k).map(|kk| sup.quarantined_this_round(kk)).collect();
        for kk in 0..k {
            if sup.degraded[kk] || sup.abandoned[kk] {
                let until = self.rounds + 1 + self.cfg.supervise.cooldown_rounds;
                let q = &mut self.quarantined_until[kk];
                *q = Some(q.map_or(until, |u| u.max(until)));
                if self.quarantine_events == 0 {
                    eprintln!(
                        "supervise: shard {kk} quarantined at round {} \
                         (cool-down {} rounds; further events counted silently)",
                        self.rounds, self.cfg.supervise.cooldown_rounds
                    );
                }
                self.quarantine_events += 1;
            }
        }
    }

    /// The bulk-synchronous round: every stage waits for the previous
    /// one. Kept sample-for-sample equivalent to the pre-overlap
    /// coordinator (same RNG consumption, same cluster-insertion order),
    /// so K=1 serial bit-equivalence and the seeded suites pin it.
    fn step_bulk(&mut self, rng: &mut Pcg64) -> RoundStats {
        let round_t0 = Instant::now();
        let quarantined_entry = self.begin_round_supervision();
        let data = self.data;
        let model = &self.model;
        let alpha = self.alpha;
        let mu = &self.mu;
        let sweeps = self.cfg.local_sweeps;
        let kernels = &self.shard_kernels;

        // ---- map: local kernel sweeps, one task per supercluster ----
        let states = std::mem::take(&mut self.states);
        let map_t0 = Instant::now();
        let (mut states, map_durs) = if self.cfg.supervise.enabled {
            // supervised window: every shard is snapshotted before the
            // round so a failed attempt can be rebuilt and replayed
            // bit-exactly (the snapshot restores the identical private
            // RNG stream — see ShardSnapshot). A quarantined shard runs
            // a zero-sweep attempt: its rows keep their assignments,
            // but its J_k / β statistics and clusters still flow into
            // the reduce and shuffle below exactly like a healthy
            // shard's, so the round stays a composition of
            // posterior-invariant kernels.
            let scoring = self.cfg.scoring;
            let snaps: Vec<ShardSnapshot> = states.iter().map(|s| s.snapshot()).collect();
            let mut sup =
                RoundSupervisor::new(self.cfg.supervise, quarantined_entry.clone());
            let restore = |kk: usize, sw: usize| {
                let mut st = snaps[kk].restore();
                st.set_score_mode(scoring);
                (st, sw)
            };
            let tasks: Vec<(Shard, usize)> = states
                .into_iter()
                .enumerate()
                .map(|(kk, st)| {
                    let sw = if quarantined_entry[kk] { 0 } else { sweeps };
                    (st, sw)
                })
                .collect();
            let (slots, durs) = self.mr.map_supervised(
                tasks,
                |kk, (mut st, sw): (Shard, usize)| {
                    st.set_theta(alpha * mu[kk]);
                    st.run_sweeps(kernels[kk].kernel(), data, model, sw);
                    st
                },
                |_, st| st, // bulk rounds grant no follow-ups
                self.cfg.supervise.round_timeout,
                |ev| match ev.outcome {
                    SupervisedOutcome::Done(_) => SupervisedDirective::Retire,
                    _ => {
                        let timed_out = matches!(ev.outcome, SupervisedOutcome::TimedOut);
                        match sup.on_failure(ev.index, timed_out) {
                            RecoveryAction::Retry(b) => {
                                SupervisedDirective::Respawn(restore(ev.index, sweeps), b)
                            }
                            RecoveryAction::Degrade => {
                                SupervisedDirective::Respawn(restore(ev.index, 0), Duration::ZERO)
                            }
                            RecoveryAction::Abandon => SupervisedDirective::Abandon,
                        }
                    }
                },
            );
            // abandoned slots: the shard's attempt (even the degraded
            // zero-sweep one) never completed — restore the pre-round
            // snapshot so the round proceeds with its unswept state
            let states: Vec<Shard> = slots
                .into_iter()
                .enumerate()
                .map(|(kk, slot)| slot.unwrap_or_else(|| restore(kk, 0).0))
                .collect();
            self.finish_round_supervision(&sup);
            (states, durs)
        } else {
            self.mr.map(states, |kk, mut st: Shard| {
                st.set_theta(alpha * mu[kk]);
                let kernel = kernels[kk].kernel();
                for _ in 0..sweeps {
                    kernel.sweep(&mut st, data, model);
                }
                st
            })
        };
        self.timer.add("map", map_t0.elapsed());
        // row counts as swept (BEFORE the shuffle moves clusters): the
        // per-shard throughput metric must divide by what the map step
        // actually processed
        let rows_swept: Vec<u64> = states.iter().map(|s| s.num_rows() as u64).collect();

        // ---- reduce: centralized hyper updates ----
        let reduce_t0 = Instant::now();
        let mut bytes = self.reduce_hypers(&mut states, rng);
        let reduce_dur = reduce_t0.elapsed();
        self.timer.add("reduce", reduce_dur);

        // ---- shuffle: Gibbs on s_j, move whole clusters ----
        let shuffle_t0 = Instant::now();
        self.last_shuffle_bytes = if self.cfg.shuffle && self.cfg.workers > 1 {
            self.shuffle(&mut states, rng)
        } else {
            self.last_shuffle_moves.clear();
            0
        };
        bytes += self.last_shuffle_bytes;
        self.timer.add("shuffle", shuffle_t0.elapsed());

        self.states = states;
        self.rounds += 1;
        self.record_shard_stats(&map_durs, &rows_swept);

        let mut rs = finish_round(
            &self.cfg.comm,
            map_durs,
            reduce_dur + shuffle_t0.elapsed(),
            bytes,
            round_t0.elapsed(),
        );
        self.stamp_supervision_counters(&mut rs);
        self.modeled_time_s += rs.modeled_wall_s;
        self.measured_time_s += rs.measured_wall_s;
        rs
    }

    /// The overlapped round (DESIGN.md § Barrier-free rounds), executed
    /// as a genuinely **concurrent host pipeline**. The stage order is
    /// itself a valid composition of invariant kernels:
    ///
    /// 1. **plan** — bonus sweeps from pre-round resident row counts
    ///    ([`plan_bonus_sweeps`]; deterministic in a sweep-invariant
    ///    statistic, so granting them preserves exactness);
    /// 2. **map window** — shards run their base sweeps on the pool;
    ///    completions stream back to the coordinator thread as they
    ///    land. A shard still owed bonus sweeps is resubmitted as a
    ///    fresh pool job per grant ([`Shard::run_sweeps`] is
    ///    re-enterable), so grants execute while slow shards are still
    ///    sweeping. On a shard's *final* completion, the coordinator
    ///    stages its contribution in the gaps between drains — snapshot
    ///    J_k, snapshot the per-dim β statistics, drain its clusters
    ///    into a per-shard pending buffer — again overlapping the
    ///    stragglers' sweeps;
    /// 3. **shuffle** — once the window closes, the pending buffers are
    ///    flattened in **shard-index order** (never completion order)
    ///    and the `s_j` destinations are Gibbs-sampled from the master
    ///    stream against the α, μ the sweeps ran under, then applied;
    /// 4. **reduce** — α from the snapshot `Σ_k J_k`, β from the staged
    ///    per-shard statistics folded in shard-index order (a fixed fp
    ///    reduction order), μ from post-shuffle occupancies — the only
    ///    ordering under which the global updates may overlap shard
    ///    work, because they read nothing a still-running sweep could
    ///    write (a μ update racing in-flight shuffle decisions is one of
    ///    the forbidden interleavings).
    ///
    /// **Determinism.** The master RNG is consumed only on the
    /// coordinator thread, after the window, in a canonical order
    /// (shuffle draws → α → β → μ); shards consume only their private
    /// streams. Staging mutates per-shard slots keyed by shard index.
    /// The final chain state is therefore a pure function of the seed —
    /// independent of thread scheduling, completion order, or injected
    /// delays — which `tests/concurrent_rounds.rs` pins by permuting
    /// completion orders. At K=1 nothing is drained or snapshotted out
    /// of order, so the chain stays bit-identical to serial.
    ///
    /// On the modeled timeline, this round's shuffle transfer and
    /// global-update compute ride behind the NEXT round's map
    /// (`prev_carry_s`), so the modeled wall is
    /// `latency + stats_upload + max(map, carry_prev)` instead of the
    /// serialized sum. On the **measured** timeline the returned
    /// [`RoundStats`] reports the real concurrent wall
    /// (`measured_overlapped_s`) next to the reconstructed serialized
    /// cost (`measured_serialized_s`) — the real host overlap speedup.
    fn step_overlapped(&mut self, rng: &mut Pcg64) -> RoundStats {
        let round_t0 = Instant::now();
        let quarantined_entry = self.begin_round_supervision();
        let data = self.data;
        let model = &self.model;
        let alpha = self.alpha;
        let mu = &self.mu;
        let sweeps = self.cfg.local_sweeps;
        let kernels = &self.shard_kernels;
        let k = self.cfg.workers;

        // ---- plan: work-stealing grants from pre-round row counts ----
        let rows_swept: Vec<u64> = self.states.iter().map(|s| s.num_rows() as u64).collect();
        let bonus_plan = plan_bonus_sweeps(&rows_swept, self.cfg.max_bonus_sweeps);
        let bonus = &bonus_plan;

        let do_shuffle = self.cfg.shuffle && k > 1;
        let collect_beta = self.cfg.update_beta && matches!(self.model, Model::Bernoulli(_));
        let beta_dims = if collect_beta { self.model.as_bernoulli().d } else { 0 };

        // per-shard staging slots, filled as completions land (keyed by
        // shard index, so fill order cannot leak into chain state)
        let mut pending: Vec<Vec<(crate::model::ClusterStats, Vec<usize>)>> =
            vec![Vec::new(); k];
        let mut j_snap: Vec<u64> = vec![0; k];
        let mut beta_snap: Vec<Vec<Vec<(u64, u32)>>> = vec![Vec::new(); k];
        // measured per-shard completion timestamps (seconds since the
        // window opened) — the real idle/barrier-wait observables
        let mut base_done_at: Vec<f64> = vec![0.0; k];
        let mut final_done_at: Vec<f64> = vec![0.0; k];
        let mut stage_busy = Duration::ZERO;

        // ---- map window: streamed completions + in-window staging ----
        let states = std::mem::take(&mut self.states);
        let map_t0 = Instant::now();
        let (mut states, map_durs) = if self.cfg.supervise.enabled {
            // supervised window: same staged-completion protocol, but a
            // failed/stalled attempt is rebuilt from its pre-round
            // snapshot and retried (replaying the identical private RNG
            // stream, so transient faults leave the chain bit-exact).
            // Quarantined shards run a zero-sweep attempt; their final
            // completion stages normally, so the shuffle and reduce
            // below see every shard regardless of health.
            let scoring = self.cfg.scoring;
            let snaps: Vec<ShardSnapshot> = states.iter().map(|s| s.snapshot()).collect();
            let mut sup =
                RoundSupervisor::new(self.cfg.supervise, quarantined_entry.clone());
            let restore = |kk: usize, sw: usize| {
                let mut st = snaps[kk].restore();
                st.set_score_mode(scoring);
                (st, sw)
            };
            let tasks: Vec<(Shard, usize)> = states
                .into_iter()
                .enumerate()
                .map(|(kk, st)| {
                    let sw = if quarantined_entry[kk] { 0 } else { sweeps };
                    (st, sw)
                })
                .collect();
            let (slots, durs) = self.mr.map_supervised(
                tasks,
                |kk, (mut st, sw): (Shard, usize)| {
                    st.set_theta(alpha * mu[kk]);
                    st.run_sweeps(kernels[kk].kernel(), data, model, sw);
                    st
                },
                |kk, mut st: Shard| {
                    st.run_sweeps(kernels[kk].kernel(), data, model, 1);
                    st.note_bonus_sweeps(1);
                    st
                },
                self.cfg.supervise.round_timeout,
                |ev| {
                    let kk = ev.index;
                    let timed_out = matches!(ev.outcome, SupervisedOutcome::TimedOut);
                    match ev.outcome {
                        SupervisedOutcome::Done(st) => {
                            if ev.followups_done == 0 {
                                base_done_at[kk] = map_t0.elapsed().as_secs_f64();
                            }
                            if sup.bonus_allowed(kk) && ev.followups_done < bonus[kk] {
                                return SupervisedDirective::Follow;
                            }
                            // final completion: stage exactly as the
                            // unsupervised window does
                            final_done_at[kk] = map_t0.elapsed().as_secs_f64();
                            let stage_t0 = Instant::now();
                            j_snap[kk] = st.num_clusters() as u64;
                            if collect_beta {
                                let mut dims: Vec<Vec<(u64, u32)>> =
                                    Vec::with_capacity(beta_dims);
                                for d in 0..beta_dims {
                                    let mut out = Vec::new();
                                    st.collect_dim_stats(d, &mut out);
                                    dims.push(out);
                                }
                                beta_snap[kk] = dims;
                            }
                            if do_shuffle {
                                pending[kk] = st.drain_clusters();
                            }
                            stage_busy += stage_t0.elapsed();
                            SupervisedDirective::Retire
                        }
                        _ => match sup.on_failure(kk, timed_out) {
                            RecoveryAction::Retry(b) => {
                                SupervisedDirective::Respawn(restore(kk, sweeps), b)
                            }
                            RecoveryAction::Degrade => {
                                SupervisedDirective::Respawn(restore(kk, 0), Duration::ZERO)
                            }
                            RecoveryAction::Abandon => SupervisedDirective::Abandon,
                        },
                    }
                },
            );
            // abandoned slots never reached their final completion:
            // restore the pre-round snapshot on the coordinator thread
            // and replicate the staging that completion would have done
            let window_s = map_t0.elapsed().as_secs_f64();
            let states: Vec<Shard> = slots
                .into_iter()
                .enumerate()
                .map(|(kk, slot)| {
                    slot.unwrap_or_else(|| {
                        let mut st = restore(kk, 0).0;
                        base_done_at[kk] = window_s;
                        final_done_at[kk] = window_s;
                        j_snap[kk] = st.num_clusters() as u64;
                        if collect_beta {
                            let mut dims: Vec<Vec<(u64, u32)>> =
                                Vec::with_capacity(beta_dims);
                            for d in 0..beta_dims {
                                let mut out = Vec::new();
                                st.collect_dim_stats(d, &mut out);
                                dims.push(out);
                            }
                            beta_snap[kk] = dims;
                        }
                        if do_shuffle {
                            pending[kk] = st.drain_clusters();
                        }
                        st
                    })
                })
                .collect();
            self.finish_round_supervision(&sup);
            (states, durs)
        } else {
            self.mr.map_streaming(
                states,
                |kk, mut st: Shard| {
                    st.set_theta(alpha * mu[kk]);
                    st.run_sweeps(kernels[kk].kernel(), data, model, sweeps);
                    st
                },
                |kk, mut st: Shard| {
                    // one bonus grant = one extra sweep, resubmitted as its
                    // own pool job so the grant can be issued mid-round and
                    // run while stragglers are still on their base sweeps
                    st.run_sweeps(kernels[kk].kernel(), data, model, 1);
                    st.note_bonus_sweeps(1);
                    st
                },
                |ev| {
                    let kk = ev.index;
                    if ev.followups_done == 0 {
                        base_done_at[kk] = map_t0.elapsed().as_secs_f64();
                    }
                    if ev.followups_done < bonus[kk] {
                        return true; // grant another bonus sweep
                    }
                    // final completion for this shard: stage its round
                    // contribution NOW, on the coordinator thread, while
                    // other shards are still sweeping
                    final_done_at[kk] = map_t0.elapsed().as_secs_f64();
                    let stage_t0 = Instant::now();
                    j_snap[kk] = ev.result.num_clusters() as u64;
                    if collect_beta {
                        // β statistics must be snapshotted BEFORE the drain
                        // empties the cluster set
                        let mut dims: Vec<Vec<(u64, u32)>> = Vec::with_capacity(beta_dims);
                        for d in 0..beta_dims {
                            let mut out = Vec::new();
                            ev.result.collect_dim_stats(d, &mut out);
                            dims.push(out);
                        }
                        beta_snap[kk] = dims;
                    }
                    if do_shuffle {
                        // drain into the pending buffer only when a shuffle
                        // will actually run: drain + reinsert compacts
                        // cluster-slot numbering, which at K=1 (or shuffle
                        // off) would perturb the bit-pinned chain
                        pending[kk] = ev.result.drain_clusters();
                    }
                    stage_busy += stage_t0.elapsed();
                    false
                },
            )
        };
        let map_window = map_t0.elapsed();
        // phase attribution stays disjoint: staging ran inside the
        // window but is accounted to the shuffle phase below
        self.timer.add("map", map_window.saturating_sub(stage_busy));

        // ---- shuffle: canonical-order destinations from the stage ----
        let shuffle_t0 = Instant::now();
        self.last_shuffle_bytes = if do_shuffle {
            let mut all: Vec<StagedMove> = Vec::new();
            for (kk, moves) in pending.iter_mut().enumerate() {
                for (stats, rows) in moves.drain(..) {
                    all.push((stats, rows, kk));
                }
            }
            let (staged, b) = self.sample_shuffle_destinations(all, rng);
            Self::apply_moves(&mut states, staged);
            b
        } else {
            self.last_shuffle_moves.clear();
            0
        };
        let shuffle_dur = shuffle_t0.elapsed();
        self.timer.add("shuffle", shuffle_dur + stage_busy);

        // ---- reduce: hypers from the staged snapshot ----
        let reduce_t0 = Instant::now();
        let stats_bytes = self.reduce_hypers_overlapped(&mut states, &j_snap, &beta_snap, rng);
        let reduce_dur = reduce_t0.elapsed();
        self.timer.add("reduce", reduce_dur);
        let bytes = stats_bytes + self.last_shuffle_bytes;

        self.states = states;
        self.rounds += 1;
        self.record_shard_stats_measured(
            &map_durs,
            &bonus_plan,
            &rows_swept,
            &base_done_at,
            &final_done_at,
        );

        // the post-window host tail (the part a bulk schedule would
        // also serialize after its barrier, on top of the staging work
        // the window absorbed)
        let tail = shuffle_dur + reduce_dur;
        let mut rs = finish_round_overlapped(
            &self.cfg.comm,
            map_durs,
            stage_busy + tail,
            bytes,
            stats_bytes,
            self.prev_carry_s,
            OverlappedTiming {
                wall: round_t0.elapsed(),
                window: map_window,
            },
        );
        self.stamp_supervision_counters(&mut rs);
        // the tail this round hides behind the NEXT round's map: its
        // shuffle transfer plus its post-window compute (staging is
        // already inside the window, so it is not part of the carry)
        self.prev_carry_s = self.last_shuffle_bytes as f64
            / self.cfg.comm.bandwidth_bytes_per_s
            + tail.as_secs_f64();
        self.modeled_time_s += rs.modeled_wall_s;
        self.measured_time_s += rs.measured_wall_s;
        rs
    }

    /// Centralized hyper updates on the CURRENT `states`: α from Eq. 6
    /// given `Σ_k J_k`, β_d by griddy Gibbs from pooled sufficient
    /// statistics, and μ per the configured [`MuMode`]. Returns the
    /// modeled bytes of the reduced-statistics upload + broadcasts.
    /// Bulk rounds call this before the shuffle (μ conditions on
    /// pre-shuffle occupancies); overlapped rounds use
    /// [`Self::reduce_hypers_overlapped`], which reads the staged
    /// snapshot instead — each is a valid Gibbs conditional on the
    /// state at call time.
    fn reduce_hypers(&mut self, states: &mut [Shard], rng: &mut Pcg64) -> u64 {
        let mut bytes: u64 = 0;
        // each worker ships J_k (8 bytes) and, if β updates are on, its
        // cluster sufficient statistics (n + per-dim one-counts)
        let total_j: u64 = states.iter().map(|s| s.num_clusters() as u64).sum();
        bytes += 8 * states.len() as u64;
        if self.cfg.update_alpha {
            self.alpha = sample_alpha(
                rng,
                self.alpha,
                self.data.rows() as u64,
                total_j,
                &self.cfg.alpha_prior,
            );
        }
        // griddy-Gibbs β is a Beta–Bernoulli move: a silent no-op for
        // the fixed-hyper likelihoods (mirrors SerialGibbs::update_beta)
        if self.cfg.update_beta && matches!(self.model, Model::Bernoulli(_)) {
            let d_total = self.model.as_bernoulli().d;
            bytes += total_j * (8 + 4 * d_total as u64);
            let mut stats: Vec<(u64, u32)> = Vec::new();
            // persistent scratch instead of a per-round β clone
            self.beta_scratch.clear();
            self.beta_scratch.extend_from_slice(&self.model.as_bernoulli().beta);
            for d in 0..d_total {
                stats.clear();
                for st in states.iter() {
                    st.collect_dim_stats(d, &mut stats);
                }
                self.beta_scratch[d] = self.beta_updater.sample(rng, &stats);
            }
            // only touch the LUT / score caches when some β_d moved;
            // a still-symmetric refresh retargets the LUT in place
            let n_max = self.data.rows() + 1;
            if self.model.as_bernoulli_mut().update_betas(&self.beta_scratch, n_max) {
                for st in states.iter_mut() {
                    st.invalidate_caches();
                }
            }
            bytes += 8 * d_total as u64; // broadcast β
        }
        bytes += self.update_mu(states, rng);
        bytes
    }

    /// Centralized hyper updates for an **overlapped** round, reading
    /// the statistics STAGED at each shard's final completion instead of
    /// the live states: α from Eq. 6 given the snapshot `Σ_k J_k`
    /// (shuffle-invariant — moving clusters between shards cannot change
    /// the total), β_d by griddy Gibbs from the per-shard snapshot
    /// statistics folded in shard-index order (a fixed fp reduction
    /// order, so the draw is a function of chain state, never of
    /// completion order), and μ per [`MuMode`] from the live post-
    /// shuffle occupancies (exactly the conditional the bulk-overlap
    /// schedule used). Returns the modeled reduced-statistics bytes.
    fn reduce_hypers_overlapped(
        &mut self,
        states: &mut [Shard],
        j_snap: &[u64],
        beta_snap: &[Vec<Vec<(u64, u32)>>],
        rng: &mut Pcg64,
    ) -> u64 {
        let mut bytes: u64 = 0;
        let total_j: u64 = j_snap.iter().sum();
        bytes += 8 * states.len() as u64;
        if self.cfg.update_alpha {
            self.alpha = sample_alpha(
                rng,
                self.alpha,
                self.data.rows() as u64,
                total_j,
                &self.cfg.alpha_prior,
            );
        }
        if self.cfg.update_beta && matches!(self.model, Model::Bernoulli(_)) {
            let d_total = self.model.as_bernoulli().d;
            bytes += total_j * (8 + 4 * d_total as u64);
            let mut stats: Vec<(u64, u32)> = Vec::new();
            self.beta_scratch.clear();
            self.beta_scratch.extend_from_slice(&self.model.as_bernoulli().beta);
            for d in 0..d_total {
                stats.clear();
                for shard_stats in beta_snap {
                    stats.extend_from_slice(&shard_stats[d]);
                }
                self.beta_scratch[d] = self.beta_updater.sample(rng, &stats);
            }
            let n_max = self.data.rows() + 1;
            if self.model.as_bernoulli_mut().update_betas(&self.beta_scratch, n_max) {
                for st in states.iter_mut() {
                    st.invalidate_caches();
                }
            }
            bytes += 8 * d_total as u64; // broadcast β
        }
        bytes += self.update_mu(states, rng);
        bytes
    }

    /// μ granularity update (DESIGN.md §6), shared by both reduce
    /// flavors. Skipped at K=1, where μ is degenerate at [1]: this also
    /// keeps the master stream consumption identical to the serial
    /// chain, preserving chain-exact K=1 equivalence under every mode.
    /// Returns the modeled broadcast bytes.
    fn update_mu(&mut self, states: &[Shard], rng: &mut Pcg64) -> u64 {
        let mut bytes = 0u64;
        if self.cfg.workers > 1 {
            match self.cfg.mu_mode {
                MuMode::Uniform => {}
                MuMode::SizeProportional => {
                    let j_counts: Vec<u64> =
                        states.iter().map(|s| s.num_clusters() as u64).collect();
                    self.mu = sample_mu_given_occupancy(rng, &j_counts);
                    bytes += 8 * states.len() as u64; // broadcast μ
                }
                MuMode::Adaptive { target_occupancy } => {
                    let j_counts: Vec<u64> =
                        states.iter().map(|s| s.num_clusters() as u64).collect();
                    let row_counts: Vec<u64> =
                        states.iter().map(|s| s.num_rows() as u64).collect();
                    self.mu_proposals += 1;
                    if adaptive_mu_step(
                        rng,
                        &mut self.mu,
                        &row_counts,
                        &j_counts,
                        target_occupancy,
                    ) {
                        self.mu_accepts += 1;
                    }
                    bytes += 8 * states.len() as u64; // broadcast μ
                }
            }
        }
        bytes
    }

    /// Rebuild the per-shard observability series (μ_k, occupancy, map
    /// time, throughput, idle/barrier-wait) for a **bulk** round: no
    /// stealing ran, so bonus columns are 0 and `barrier_wait_s ==
    /// idle_s` (both reconstructed from durations — a bulk round has no
    /// per-completion timestamps).
    fn record_shard_stats(&mut self, map_durs: &[Duration], rows_swept: &[u64]) {
        let local_sweeps = self.cfg.local_sweeps;
        // the round's map critical path — the wait baseline every shard
        // is measured against
        let crit = map_durs
            .iter()
            .map(Duration::as_secs_f64)
            .fold(0.0, f64::max);
        self.last_shard_stats = self
            .states
            .iter()
            .enumerate()
            .map(|(kk, st)| {
                let map_seconds = map_durs.get(kk).map(|d| d.as_secs_f64()).unwrap_or(0.0);
                // throughput from the PRE-shuffle row count the map step
                // actually swept, not the post-shuffle occupancy
                let swept = rows_swept.get(kk).copied().unwrap_or(0);
                ShardRoundStat {
                    shard: kk,
                    mu: self.mu[kk],
                    rows: st.num_rows() as u64,
                    clusters: st.num_clusters() as u64,
                    map_seconds,
                    rows_per_s: if map_seconds > 0.0 {
                        swept as f64 * local_sweeps as f64 / map_seconds
                    } else {
                        0.0
                    },
                    idle_s: (crit - map_seconds).max(0.0),
                    barrier_wait_s: (crit - map_seconds).max(0.0),
                    bonus_sweeps: 0,
                    retries: self.sup_retries.get(kk).copied().unwrap_or(0),
                    watchdog_fires: self.sup_watchdog.get(kk).copied().unwrap_or(0),
                    quarantined: self.sup_quarantined.get(kk).copied().unwrap_or(false),
                    kernel: self.shard_kernels[kk],
                }
            })
            .collect();
    }

    /// Rebuild the per-shard observability series for an **overlapped**
    /// round from MEASURED completion timestamps: `idle_s` is the real
    /// wall between a shard's final completion draining and the map
    /// window closing; `barrier_wait_s` the real wall from its *base*
    /// completion — so their difference is the wait the bonus grants
    /// actually absorbed on the host timeline, not a reconstruction.
    fn record_shard_stats_measured(
        &mut self,
        map_durs: &[Duration],
        bonus_plan: &[usize],
        rows_swept: &[u64],
        base_done_at: &[f64],
        final_done_at: &[f64],
    ) {
        let local_sweeps = self.cfg.local_sweeps;
        // the window closes when the LAST completion drains — the
        // measured analogue of the modeled critical path
        let close = final_done_at.iter().copied().fold(0.0, f64::max);
        self.last_shard_stats = self
            .states
            .iter()
            .enumerate()
            .map(|(kk, st)| {
                let map_seconds = map_durs.get(kk).map(|d| d.as_secs_f64()).unwrap_or(0.0);
                let bonus_sweeps = bonus_plan.get(kk).copied().unwrap_or(0) as u64;
                let swept = rows_swept.get(kk).copied().unwrap_or(0);
                let sweeps_run = local_sweeps as u64 + bonus_sweeps;
                ShardRoundStat {
                    shard: kk,
                    mu: self.mu[kk],
                    rows: st.num_rows() as u64,
                    clusters: st.num_clusters() as u64,
                    map_seconds,
                    rows_per_s: if map_seconds > 0.0 {
                        swept as f64 * sweeps_run as f64 / map_seconds
                    } else {
                        0.0
                    },
                    idle_s: (close - final_done_at.get(kk).copied().unwrap_or(close))
                        .max(0.0),
                    barrier_wait_s: (close
                        - base_done_at.get(kk).copied().unwrap_or(close))
                    .max(0.0),
                    bonus_sweeps,
                    retries: self.sup_retries.get(kk).copied().unwrap_or(0),
                    watchdog_fires: self.sup_watchdog.get(kk).copied().unwrap_or(0),
                    quarantined: self.sup_quarantined.get(kk).copied().unwrap_or(false),
                    kernel: self.shard_kernels[kk],
                }
            })
            .collect();
    }

    /// Gibbs-resample every cluster's supercluster assignment and move
    /// the clusters, decide + apply back-to-back (the bulk-synchronous
    /// form). Returns the bytes the moves would transfer.
    fn shuffle(&mut self, states: &mut [Shard], rng: &mut Pcg64) -> u64 {
        let (staged, bytes) = self.shuffle_decide(states, rng);
        Self::apply_moves(states, staged);
        bytes
    }

    /// The decide half of the shuffle: drain every cluster, Gibbs-sample
    /// its new supercluster `s_j` under the current α, μ, and stage the
    /// (stats, rows, destination) moves into a swap buffer WITHOUT
    /// rebuilding the shards — the double-buffering that separates
    /// decisions from state mutation in an overlapped round. Sampling
    /// reads only the running J_k counts, never shard internals, so
    /// deferring the inserts is sample-for-sample identical to the old
    /// in-place form. Returns the staged moves (in drain order, which
    /// [`Self::apply_moves`] must preserve) and the modeled transfer
    /// bytes of the movers.
    fn shuffle_decide(
        &mut self,
        states: &mut [Shard],
        rng: &mut Pcg64,
    ) -> (Vec<StagedMove>, u64) {
        // extract all clusters: (stats, member rows, current supercluster)
        let mut all: Vec<StagedMove> = Vec::new();
        for (kk, st) in states.iter_mut().enumerate() {
            for (stats, rows) in st.drain_clusters() {
                all.push((stats, rows, kk));
            }
        }
        self.sample_shuffle_destinations(all, rng)
    }

    /// The sampling half of the shuffle decision, shared by the bulk
    /// path ([`Self::shuffle_decide`], which drains live) and the
    /// concurrent overlapped path (which drained per shard at each final
    /// completion and flattens the pending buffers in shard-index
    /// order). `all` must be in canonical drain order — shard index,
    /// then slot within the shard — which both callers guarantee; the
    /// master-stream draw sequence is then identical no matter how
    /// completions interleaved. Records every decision into
    /// [`Self::last_shuffle_moves`].
    fn sample_shuffle_destinations(
        &mut self,
        all: Vec<StagedMove>,
        rng: &mut Pcg64,
    ) -> (Vec<StagedMove>, u64) {
        let k = self.cfg.workers;
        // current per-supercluster cluster counts for the Eq.7 variant
        let mut j_counts: Vec<u64> = vec![0; k];
        for &(_, _, kk) in &all {
            j_counts[kk] += 1;
        }
        self.last_shuffle_moves.clear();
        let mut staged: Vec<StagedMove> = Vec::with_capacity(all.len());
        let mut bytes = 0u64;
        for (stats, rows, kk_old) in all {
            let mut j_minus = j_counts.clone();
            j_minus[kk_old] -= 1;
            let kk_new =
                sample_shuffle(rng, self.cfg.shuffle_kernel, self.alpha, &self.mu, &j_minus);
            j_counts[kk_old] -= 1;
            j_counts[kk_new] += 1;
            if kk_new != kk_old {
                // moving a cluster ships its parameters/stats and the
                // member row ids (the paper: "communicating a set of data
                // indices and one set of component parameters")
                bytes += 8 + 4 * self.model.stat_dims() as u64 + 8 * rows.len() as u64;
            }
            self.last_shuffle_moves.push(ShuffleMove {
                from: kk_old,
                to: kk_new,
                rows: rows.len(),
            });
            staged.push((stats, rows, kk_new));
        }
        (staged, bytes)
    }

    /// The apply half: reinsert every staged cluster at its destination,
    /// in the staged (drain) order — cluster-slot assignment is
    /// order-sensitive, and preserving it keeps bulk rounds bit-equal
    /// to the historical in-place shuffle.
    fn apply_moves(states: &mut [Shard], staged: Vec<StagedMove>) {
        for (stats, rows, kk_new) in staged {
            states[kk_new].insert_cluster(stats, rows);
        }
    }

    /// Total live clusters across all superclusters.
    pub fn num_clusters(&self) -> usize {
        self.states.iter().map(|s| s.num_clusters()).sum()
    }

    /// Export every live cluster's predictive table as an immutable
    /// [`TableSet`] — the round-boundary snapshot hook of the serving
    /// layer ([`crate::serve`]). Columns land in canonical order
    /// (shards in shard order, clusters within a shard in slot order),
    /// copied from the same per-cluster caches the sweep kernels score
    /// through, so the export is bit-identical across host schedules
    /// and consumes no RNG: calling this between rounds is invisible
    /// to the chain's draw sequence.
    pub fn export_table_set(&mut self) -> TableSet {
        let mut b = TableSetBuilder::new(self.model.table_rows());
        let model = &self.model;
        for st in self.states.iter_mut() {
            st.export_table_columns(model, &mut b);
        }
        b.finish()
    }

    /// Current concentration α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Current supercluster base weights μ (simplex of length K).
    pub fn mu(&self) -> &[f64] {
        &self.mu
    }

    /// The configured granularity mode.
    pub fn mu_mode(&self) -> MuMode {
        self.cfg.mu_mode
    }

    /// The kernel each shard runs (resolved from the config's
    /// [`KernelAssignment`] at construction).
    pub fn shard_kernels(&self) -> &[KernelKind] {
        &self.shard_kernels
    }

    /// Acceptance rate of the adaptive-μ MH retarget so far (`None`
    /// until the first proposal, i.e. for non-adaptive modes or K=1).
    pub fn mu_acceptance_rate(&self) -> Option<f64> {
        if self.mu_proposals == 0 {
            None
        } else {
            Some(self.mu_accepts as f64 / self.mu_proposals as f64)
        }
    }

    /// Per-shard observability records for the most recent round (empty
    /// before the first [`Self::step`]).
    pub fn shard_stats(&self) -> &[ShardRoundStat] {
        &self.last_shard_stats
    }

    /// Bytes the most recent round's shuffle step moved between
    /// superclusters (0 before the first round, when the shuffle is
    /// disabled, or at K = 1) — the `--shard-trace` shuffle-bytes line.
    pub fn last_shuffle_bytes(&self) -> u64 {
        self.last_shuffle_bytes
    }

    /// The most recent round's shuffle decisions, in canonical drain
    /// order (empty before the first round, with the shuffle disabled,
    /// or at K = 1). Because the drain order and the master-stream draw
    /// sequence are fixed by chain state, this sequence is identical for
    /// every host schedule — the observable `tests/concurrent_rounds.rs`
    /// pins against completion-order permutations.
    pub fn last_shuffle_moves(&self) -> &[ShuffleMove] {
        &self.last_shuffle_moves
    }

    /// Install (or clear) a per-shard start-delay hook on the map pool —
    /// the deterministic completion-order lever of the concurrency test
    /// layer ([`DelayHook`] delays base map tasks only; sleeps are
    /// excluded from measured durations and cannot perturb chain state).
    /// A panicking hook doubles as an injected mid-map shard failure.
    pub fn set_map_delay_hook(&mut self, hook: Option<DelayHook>) {
        self.mr.set_delay_hook(hook);
    }

    /// Install (or clear) a deterministic fault-injection hook on the
    /// map pool ([`crate::mapreduce::FaultHook`]): consulted once per
    /// base attempt with the (round, shard, attempt) site, it can
    /// delay, stall, panic, or fail the attempt. Under `--supervise on`
    /// the injected failures drive the retry/quarantine machinery; with
    /// supervision off a `Panic`/`Io` action aborts the round exactly
    /// like an organic shard panic (the legacy contract
    /// `tests/failure_injection.rs` pins).
    pub fn set_map_fault_hook(&mut self, hook: Option<FaultHook>) {
        self.mr.set_fault_hook(hook);
    }

    /// Per-shard quarantine flags of the most recent round (empty
    /// before the first supervised round): `true` while a shard is
    /// sitting out sweeps in degraded mode.
    pub fn quarantined_shards(&self) -> &[bool] {
        &self.sup_quarantined
    }

    /// Lifetime count of quarantine entries (shards that exhausted
    /// their retries, including re-arms of an already-quarantined
    /// shard whose degraded attempt failed again).
    pub fn quarantine_events(&self) -> u64 {
        self.quarantine_events
    }

    /// The per-supercluster shard states.
    pub fn states(&self) -> &[Shard] {
        &self.states
    }

    /// Replace the shard states (checkpoint resume); the configured
    /// scoring dispatch is re-applied to the incoming shards.
    pub(crate) fn replace_states(&mut self, mut states: Vec<Shard>) {
        for st in &mut states {
            st.set_score_mode(self.cfg.scoring);
        }
        self.states = states;
    }

    /// Global assignment vector (cluster ids unique across superclusters),
    /// aligned with the data row order — for ARI against ground truth.
    pub fn assignments(&self) -> Vec<u32> {
        let mut z = vec![0u32; self.data.rows()];
        let mut next_id = 0u32;
        for st in &self.states {
            next_id = st.export_assignments(&mut z, next_id);
        }
        z
    }

    /// All cluster stats with their sizes (global view after a round).
    pub fn global_clusters(&self) -> Vec<&crate::model::ClusterStats> {
        self.states.iter().flat_map(|s| s.clusters()).collect()
    }

    /// Mean test-set predictive log-likelihood per datum.
    ///
    /// Under the Beta–Bernoulli likelihood this goes through a
    /// [`Scorer`] (the PJRT artifact on the production path; the pure-
    /// Rust fallback in tests): the packed `[D, J]` weight matrices are
    /// exported per shard by [`crate::sampler::ClusterSet`] — the same
    /// layout the sweep-side batched path scores through — into
    /// persistent coordinator-owned buffers, so per-round evaluation
    /// re-allocates nothing (every `[D, J+1]` cell is rewritten each
    /// call; stale capacity is never read). The other likelihoods take
    /// the scalar f64 log-sum-exp path through
    /// [`Shard::score_against_all`] (the f32 weight-matrix export is
    /// Bernoulli-specific).
    pub fn predictive_loglik<'b>(
        &mut self,
        test: impl Into<DataRef<'b>>,
        scorer: &mut dyn Scorer,
    ) -> f64 {
        let test = test.into();
        let n_total = self.data.rows() as f64 + self.alpha;
        if !matches!(self.model, Model::Bernoulli(_)) {
            let mut acc = 0.0;
            let mut terms: Vec<f64> = Vec::new();
            for r in 0..test.rows() {
                terms.clear();
                for st in &mut self.states {
                    st.score_against_all(&self.model, test, r, n_total, &mut terms);
                }
                terms.push((self.alpha / n_total).ln() + self.model.log_pred_empty(test, r));
                acc += logsumexp(&terms);
            }
            return acc / test.rows() as f64;
        }
        let test = test.bits().expect("bernoulli model requires binary data");
        let j: usize = self.states.iter().map(|s| s.num_clusters()).sum();
        let d = self.model.as_bernoulli().d;
        // weight matrices [D, J+1]: J extant clusters + the fresh cluster
        let jj = j + 1;
        self.pl_w1.resize(d * jj, 0.0);
        self.pl_w0.resize(d * jj, 0.0);
        self.pl_logpi.resize(jj, 0.0);
        let mut col = 0usize;
        for st in &self.states {
            col = st.cluster_set().export_weight_columns(
                self.model.as_bernoulli(),
                n_total,
                &mut self.pl_w1,
                &mut self.pl_w0,
                &mut self.pl_logpi,
                jj,
                col,
            );
        }
        debug_assert_eq!(col, j);
        // fresh cluster: predictive coin 1/2 in every dim
        let half = 0.5f32.ln();
        for dd in 0..d {
            self.pl_w1[dd * jj + j] = half;
            self.pl_w0[dd * jj + j] = half;
        }
        self.pl_logpi[j] = ((self.alpha / n_total).ln()) as f32;

        let dens =
            scorer.predictive_density(test, &self.pl_w1, &self.pl_w0, &self.pl_logpi, d, jj);
        let total: f64 = dens.iter().map(|&x| x as f64).sum();
        total / test.rows() as f64
    }

    /// Joint log probability under the nested representation (Eq. 5 × the
    /// collapsed data marginals) — used by invariance tests.
    pub fn joint_log_prob(&self) -> f64 {
        use crate::special::lgamma;
        let n = self.data.rows() as f64;
        let total_j = self.num_clusters() as f64;
        let mut lp = lgamma(self.alpha) - lgamma(self.alpha + n) + total_j * self.alpha.ln();
        for (kk, st) in self.states.iter().enumerate() {
            lp += st.num_clusters() as f64 * self.mu[kk].ln();
            for c in st.clusters() {
                lp += lgamma(c.n() as f64);
                lp += c.log_marginal(&self.model);
            }
        }
        lp
    }

    /// Data-integrity check across all superclusters (tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.data.rows()];
        for (kk, st) in self.states.iter().enumerate() {
            st.check_invariants(self.data)
                .map_err(|e| format!("supercluster {kk}: {e}"))?;
            for &r in st.rows() {
                if seen[r] {
                    return Err(format!("row {r} owned by two superclusters"));
                }
                seen[r] = true;
            }
        }
        if let Some(r) = seen.iter().position(|&s| !s) {
            return Err(format!("row {r} owned by no supercluster"));
        }
        Ok(())
    }
}
