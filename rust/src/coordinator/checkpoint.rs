//! Checkpoint / resume for the coordinator: serialize the full latent
//! state (per-supercluster row ownership + assignments, α, β, round and
//! time counters) to a versioned, checksummed binary file, and rebuild a
//! running coordinator from it. Long VQ runs (the paper's Fig. 9 is a
//! 32-CPU-day job) need this to survive restarts.
//!
//! Cluster sufficient statistics are NOT stored — they are a pure
//! function of (data, assignments) and are rebuilt on load, which keeps
//! the file small and makes corruption structurally impossible to carry
//! into the stats.

use super::{Coordinator, CoordinatorConfig};
use crate::data::BinMat;
use crate::rng::Pcg64;
use crate::sampler::Shard;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CCCKPT1\n";

/// Plain-old-data snapshot of the coordinator's latent state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub alpha: f64,
    pub beta: Vec<f64>,
    pub rounds: u64,
    pub modeled_time_s: f64,
    pub measured_time_s: f64,
    /// per supercluster: (global row ids, local cluster slot per row)
    pub shards: Vec<(Vec<u64>, Vec<u32>)>,
}

impl Checkpoint {
    /// Capture from a live coordinator.
    pub fn capture(coord: &Coordinator<'_>) -> Checkpoint {
        Checkpoint {
            alpha: coord.alpha,
            beta: coord.model.beta.clone(),
            rounds: coord.rounds,
            modeled_time_s: coord.modeled_time_s,
            measured_time_s: coord.measured_time_s,
            shards: coord
                .states()
                .iter()
                .map(|st| {
                    (
                        st.rows().iter().map(|&r| r as u64).collect(),
                        st.assignments_local().to_vec(),
                    )
                })
                .collect(),
        }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        let mut sum: u64 = 0;
        let mut w64 = |f: &mut std::fs::File, x: u64, sum: &mut u64| -> std::io::Result<()> {
            *sum = sum.wrapping_add(x);
            f.write_all(&x.to_le_bytes())
        };
        f.write_all(MAGIC)?;
        w64(&mut f, self.alpha.to_bits(), &mut sum)?;
        w64(&mut f, self.beta.len() as u64, &mut sum)?;
        for &b in &self.beta {
            w64(&mut f, b.to_bits(), &mut sum)?;
        }
        w64(&mut f, self.rounds, &mut sum)?;
        w64(&mut f, self.modeled_time_s.to_bits(), &mut sum)?;
        w64(&mut f, self.measured_time_s.to_bits(), &mut sum)?;
        w64(&mut f, self.shards.len() as u64, &mut sum)?;
        for (rows, assign) in &self.shards {
            w64(&mut f, rows.len() as u64, &mut sum)?;
            for &r in rows {
                w64(&mut f, r, &mut sum)?;
            }
            for &a in assign {
                w64(&mut f, a as u64, &mut sum)?;
            }
        }
        f.write_all(&sum.to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Checkpoint> {
        let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(err("not a CCCKPT1 checkpoint"));
        }
        let mut sum: u64 = 0;
        let mut buf = [0u8; 8];
        let mut r64 = |f: &mut std::fs::File, sum: &mut u64| -> std::io::Result<u64> {
            f.read_exact(&mut buf)?;
            let x = u64::from_le_bytes(buf);
            *sum = sum.wrapping_add(x);
            Ok(x)
        };
        let alpha = f64::from_bits(r64(&mut f, &mut sum)?);
        let nbeta = r64(&mut f, &mut sum)? as usize;
        let mut beta = Vec::with_capacity(nbeta);
        for _ in 0..nbeta {
            beta.push(f64::from_bits(r64(&mut f, &mut sum)?));
        }
        let rounds = r64(&mut f, &mut sum)?;
        let modeled_time_s = f64::from_bits(r64(&mut f, &mut sum)?);
        let measured_time_s = f64::from_bits(r64(&mut f, &mut sum)?);
        let nshards = r64(&mut f, &mut sum)? as usize;
        let mut shards = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            let n = r64(&mut f, &mut sum)? as usize;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(r64(&mut f, &mut sum)?);
            }
            let mut assign = Vec::with_capacity(n);
            for _ in 0..n {
                assign.push(r64(&mut f, &mut sum)? as u32);
            }
            shards.push((rows, assign));
        }
        let mut tail = [0u8; 8];
        f.read_exact(&mut tail)?;
        if u64::from_le_bytes(tail) != sum {
            return Err(err("checkpoint checksum mismatch"));
        }
        Ok(Checkpoint {
            alpha,
            beta,
            rounds,
            modeled_time_s,
            measured_time_s,
            shards,
        })
    }
}

impl<'a> Coordinator<'a> {
    /// Persist the latent state.
    pub fn save_checkpoint(&self, path: &Path) -> std::io::Result<()> {
        Checkpoint::capture(self).save(path)
    }

    /// Rebuild a coordinator from a checkpoint against the SAME dataset
    /// (sufficient statistics are recomputed from assignments; every
    /// shard is integrity-checked before the chain may continue).
    pub fn resume(
        data: &'a BinMat,
        cfg: CoordinatorConfig,
        ckpt: &Checkpoint,
        rng: &mut Pcg64,
    ) -> Result<Coordinator<'a>, String> {
        if ckpt.shards.len() != cfg.workers {
            return Err(format!(
                "checkpoint has {} shards, config wants {} workers",
                ckpt.shards.len(),
                cfg.workers
            ));
        }
        if ckpt.beta.len() != data.dims() {
            return Err(format!(
                "checkpoint β has {} dims, data has {}",
                ckpt.beta.len(),
                data.dims()
            ));
        }
        let mut coord = Coordinator::new(data, cfg, rng);
        coord.alpha = ckpt.alpha;
        coord.model.beta = ckpt.beta.clone();
        // build_lut handles the asymmetric-β case itself (clears the LUT)
        coord.model.build_lut(data.rows() + 1);
        coord.rounds = ckpt.rounds;
        coord.modeled_time_s = ckpt.modeled_time_s;
        coord.measured_time_s = ckpt.measured_time_s;
        let states: Result<Vec<Shard>, String> = ckpt
            .shards
            .iter()
            .enumerate()
            .map(|(kk, (rows, assign))| {
                let rows: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
                let st = Shard::from_parts(
                    data,
                    rows,
                    assign.clone(),
                    rng.split(1000 + kk as u64),
                )?;
                st.check_invariants(data)
                    .map_err(|e| format!("shard {kk}: {e}"))?;
                Ok(st)
            })
            .collect();
        coord.replace_states(states?);
        coord.check_invariants()?;
        Ok(coord)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::mapreduce::CommModel;
    use crate::runtime::FallbackScorer;

    fn ckpt_dir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cc_ckpt_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_preserves_state_exactly() {
        let ds = SyntheticConfig {
            n: 500,
            d: 16,
            clusters: 4,
            beta: 0.2,
            seed: 1,
        }
        .generate();
        let cfg = CoordinatorConfig {
            workers: 3,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(2);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        for _ in 0..5 {
            coord.step(&mut rng);
        }
        let path = ckpt_dir().join("rt.ccckpt");
        coord.save_checkpoint(&path).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, Checkpoint::capture(&coord));

        let mut rng2 = Pcg64::seed_from(3);
        let mut resumed = Coordinator::resume(&ds.train, cfg, &ckpt, &mut rng2).unwrap();
        assert_eq!(resumed.num_clusters(), coord.num_clusters());
        assert_eq!(resumed.alpha(), coord.alpha());
        assert_eq!(resumed.rounds, coord.rounds);
        assert_eq!(resumed.assignments(), coord.assignments());
        // and the resumed chain runs + scores
        resumed.step(&mut rng2);
        let mut sc = FallbackScorer::new();
        let ll = resumed.predictive_loglik(&ds.test, &mut sc);
        assert!(ll.is_finite());
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let ds = SyntheticConfig {
            n: 100,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(5);
        let coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let path = ckpt_dir().join("corrupt.ccckpt");
        coord.save_checkpoint(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5a;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn mismatched_config_rejected() {
        let ds = SyntheticConfig {
            n: 100,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let ckpt = Checkpoint::capture(&coord);
        let cfg4 = CoordinatorConfig {
            workers: 4,
            ..cfg
        };
        assert!(Coordinator::resume(&ds.train, cfg4, &ckpt, &mut rng).is_err());
    }
}
