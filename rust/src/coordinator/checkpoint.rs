//! Checkpoint / resume for the coordinator: serialize the full latent
//! state (per-supercluster row ownership + assignments, α, the model
//! tag + sampled hyperparameters, the μ granularity state, per-shard
//! kernel assignment, round and time counters) to a versioned,
//! checksummed binary file, and rebuild a running coordinator from it.
//! Long VQ runs (the paper's Fig. 9 is a 32-CPU-day job) need this to
//! survive restarts.
//!
//! Cluster sufficient statistics are NOT stored — they are a pure
//! function of (data, assignments) and are rebuilt on load, which keeps
//! the file small and makes corruption structurally impossible to carry
//! into the stats. The μ vector IS stored (bit-exact): under
//! [`MuMode::SizeProportional`]/[`MuMode::Adaptive`] it is latent chain
//! state, and a resume that silently reinitialized it uniform would
//! *not* continue the same chain (`rust/tests/failure_injection.rs`
//! pins this).
//!
//! The current format is `CCCKPT3`, which records which component
//! likelihood the chain ran ([`crate::model::ModelSpec::tag`]) and its
//! hyperparameter vector ([`crate::model::ComponentModel::hyper_vec`]).
//! `CCCKPT2` files (written before the likelihood became selectable)
//! are still read — they always meant Beta–Bernoulli, and their β
//! vector IS the hyper vector — but saves always write v3. Resuming
//! under a different `--model` than the checkpoint was written with is
//! rejected, never silently reinterpreted.

use super::{Coordinator, CoordinatorConfig, MuMode};
use crate::data::DataRef;
use crate::rng::Pcg64;
use crate::sampler::{KernelKind, Shard};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"CCCKPT3\n";
const MAGIC_V2: &[u8; 8] = b"CCCKPT2\n";
const MAGIC_V1: &[u8; 8] = b"CCCKPT1\n";

fn mu_mode_to_tag(m: MuMode) -> (u64, f64) {
    match m {
        MuMode::Uniform => (0, 0.0),
        MuMode::SizeProportional => (1, 0.0),
        MuMode::Adaptive { target_occupancy } => (2, target_occupancy),
    }
}

fn mu_mode_from_tag(tag: u64, target: f64) -> Result<MuMode, String> {
    match tag {
        0 => Ok(MuMode::Uniform),
        1 => Ok(MuMode::SizeProportional),
        2 => Ok(MuMode::Adaptive {
            target_occupancy: target,
        }),
        other => Err(format!("unknown μ-mode tag {other}")),
    }
}

fn kernel_to_tag(k: KernelKind) -> u64 {
    match k {
        KernelKind::CollapsedGibbs => 0,
        KernelKind::WalkerSlice => 1,
        KernelKind::SplitMergeGibbs => 2,
        KernelKind::SplitMergeWalker => 3,
    }
}

/// `path` with `suffix` appended to its file name
/// (`runs/state.ccckpt` + `".prev"` → `runs/state.ccckpt.prev`).
pub(crate) fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

fn kernel_from_tag(tag: u64) -> Result<KernelKind, String> {
    match tag {
        0 => Ok(KernelKind::CollapsedGibbs),
        1 => Ok(KernelKind::WalkerSlice),
        2 => Ok(KernelKind::SplitMergeGibbs),
        3 => Ok(KernelKind::SplitMergeWalker),
        other => Err(format!("unknown kernel tag {other}")),
    }
}

/// Plain-old-data snapshot of the coordinator's latent state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// concentration α at capture time
    pub alpha: f64,
    /// which component likelihood the chain ran
    /// ([`crate::model::ModelSpec::tag`]; resume must match)
    pub model_tag: u64,
    /// the model's hyperparameter vector at capture time
    /// ([`crate::model::ComponentModel::hyper_vec`]): β_d for
    /// Beta–Bernoulli (sampled state, bit-exact), the fixed NIG /
    /// Dirichlet hypers otherwise (validated bit-equal on resume)
    pub hyper: Vec<f64>,
    /// completed global rounds
    pub rounds: u64,
    /// cumulative modeled distributed wall-clock (s)
    pub modeled_time_s: f64,
    /// cumulative measured host wall-clock (s)
    pub measured_time_s: f64,
    /// the granularity mode the run was using (resume must match)
    pub mu_mode: MuMode,
    /// the supercluster weights μ at capture time (bit-exact)
    pub mu: Vec<f64>,
    /// the resolved per-shard kernel assignment (resume must match)
    pub kernels: Vec<KernelKind>,
    /// per supercluster: (global row ids, local cluster slot per row)
    pub shards: Vec<(Vec<u64>, Vec<u32>)>,
}

impl Checkpoint {
    /// Capture from a live coordinator.
    pub fn capture(coord: &Coordinator<'_>) -> Checkpoint {
        Checkpoint {
            alpha: coord.alpha,
            model_tag: coord.cfg.model.tag(),
            hyper: coord.model.hyper_vec(),
            rounds: coord.rounds,
            modeled_time_s: coord.modeled_time_s,
            measured_time_s: coord.measured_time_s,
            mu_mode: coord.cfg.mu_mode,
            mu: coord.mu.clone(),
            kernels: coord.shard_kernels.clone(),
            shards: coord
                .states()
                .iter()
                .map(|st| {
                    (
                        st.rows().iter().map(|&r| r as u64).collect(),
                        st.assignments_local().to_vec(),
                    )
                })
                .collect(),
        }
    }

    /// Persist to `path` in the checksummed `CCCKPT3` binary format.
    ///
    /// The write is crash-safe: bytes land in `<path>.tmp` first, the
    /// temp file is fsynced, any existing `path` is renamed to
    /// `<path>.prev`, and the temp file is renamed over `path`. A crash
    /// at any point leaves an intact prior generation at `path` or
    /// `<path>.prev` — a torn file can never be the only copy.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let tmp = sibling(path, ".tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            self.write_to(&mut f)?;
            f.sync_all()?;
        }
        if path.exists() {
            std::fs::rename(path, sibling(path, ".prev"))?;
        }
        std::fs::rename(&tmp, path)?;
        // best-effort directory fsync so the renames themselves are
        // durable (not supported everywhere; failure is not an error)
        let dir = match path.parent() {
            Some(d) if !d.as_os_str().is_empty() => d,
            _ => Path::new("."),
        };
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    /// The sibling path [`Checkpoint::save`] keeps the prior generation
    /// at (and [`Checkpoint::load_with_fallback`] retries from).
    pub fn prev_path(path: &Path) -> PathBuf {
        sibling(path, ".prev")
    }

    fn write_to(&self, f: &mut std::fs::File) -> std::io::Result<()> {
        let mut sum: u64 = 0;
        let mut w64 = |f: &mut std::fs::File, x: u64, sum: &mut u64| -> std::io::Result<()> {
            *sum = sum.wrapping_add(x);
            f.write_all(&x.to_le_bytes())
        };
        f.write_all(MAGIC)?;
        w64(f, self.alpha.to_bits(), &mut sum)?;
        w64(f, self.model_tag, &mut sum)?;
        w64(f, self.hyper.len() as u64, &mut sum)?;
        for &b in &self.hyper {
            w64(f, b.to_bits(), &mut sum)?;
        }
        w64(f, self.rounds, &mut sum)?;
        w64(f, self.modeled_time_s.to_bits(), &mut sum)?;
        w64(f, self.measured_time_s.to_bits(), &mut sum)?;
        let (mode_tag, mode_target) = mu_mode_to_tag(self.mu_mode);
        w64(f, mode_tag, &mut sum)?;
        w64(f, mode_target.to_bits(), &mut sum)?;
        w64(f, self.shards.len() as u64, &mut sum)?;
        debug_assert_eq!(self.mu.len(), self.shards.len());
        debug_assert_eq!(self.kernels.len(), self.shards.len());
        for (kk, (rows, assign)) in self.shards.iter().enumerate() {
            w64(f, self.mu[kk].to_bits(), &mut sum)?;
            w64(f, kernel_to_tag(self.kernels[kk]), &mut sum)?;
            w64(f, rows.len() as u64, &mut sum)?;
            for &r in rows {
                w64(f, r, &mut sum)?;
            }
            for &a in assign {
                w64(f, a as u64, &mut sum)?;
            }
        }
        f.write_all(&sum.to_le_bytes())?;
        Ok(())
    }

    /// Load and verify a `CCCKPT3` checkpoint (magic, structure,
    /// checksum). `CCCKPT2` files are read too — a v2 file always meant
    /// Beta–Bernoulli (model tag 0), and its β vector is the hyper
    /// vector. Older `CCCKPT1` files (which carried no μ state) are
    /// rejected explicitly rather than silently resumed with uniform μ.
    pub fn load(path: &Path) -> std::io::Result<Checkpoint> {
        let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
        let mut f = std::fs::File::open(path)?;
        // A corrupt length word must never drive a huge allocation (an
        // OOM abort is not a catchable parse error): no count in a valid
        // file can exceed the number of u64 words the file itself holds.
        let max_words = f.metadata()?.len() / 8;
        let bounded = |n: u64, what: &str| -> std::io::Result<usize> {
            if n > max_words {
                Err(err(&format!(
                    "checkpoint {what} count {n} exceeds the file's own size"
                )))
            } else {
                Ok(n as usize)
            }
        };
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic == MAGIC_V1 {
            return Err(err(
                "CCCKPT1 checkpoint predates μ-state serialization; \
                 re-run from scratch (resuming it would silently reset μ)",
            ));
        }
        let v2 = &magic == MAGIC_V2;
        if !v2 && &magic != MAGIC {
            return Err(err("not a CCCKPT3 (or CCCKPT2) checkpoint"));
        }
        let mut sum: u64 = 0;
        let mut buf = [0u8; 8];
        let mut r64 = |f: &mut std::fs::File, sum: &mut u64| -> std::io::Result<u64> {
            f.read_exact(&mut buf)?;
            let x = u64::from_le_bytes(buf);
            *sum = sum.wrapping_add(x);
            Ok(x)
        };
        let alpha = f64::from_bits(r64(&mut f, &mut sum)?);
        // v3 inserts the model tag between α and the hyper vector; a v2
        // file has no tag (implicitly Beta–Bernoulli) and its next field
        // is the β length
        let model_tag = if v2 { 0 } else { r64(&mut f, &mut sum)? };
        let nhyper = bounded(r64(&mut f, &mut sum)?, "hyperparameter")?;
        let mut hyper = Vec::with_capacity(nhyper);
        for _ in 0..nhyper {
            hyper.push(f64::from_bits(r64(&mut f, &mut sum)?));
        }
        let rounds = r64(&mut f, &mut sum)?;
        let modeled_time_s = f64::from_bits(r64(&mut f, &mut sum)?);
        let measured_time_s = f64::from_bits(r64(&mut f, &mut sum)?);
        let mode_tag = r64(&mut f, &mut sum)?;
        let mode_target = f64::from_bits(r64(&mut f, &mut sum)?);
        let mu_mode = mu_mode_from_tag(mode_tag, mode_target)
            .map_err(|e| err(&e))?;
        let nshards = bounded(r64(&mut f, &mut sum)?, "shard")?;
        let mut mu = Vec::with_capacity(nshards);
        let mut kernels = Vec::with_capacity(nshards);
        let mut shards = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            mu.push(f64::from_bits(r64(&mut f, &mut sum)?));
            kernels.push(kernel_from_tag(r64(&mut f, &mut sum)?).map_err(|e| err(&e))?);
            let n = bounded(r64(&mut f, &mut sum)?, "row")?;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(r64(&mut f, &mut sum)?);
            }
            let mut assign = Vec::with_capacity(n);
            for _ in 0..n {
                assign.push(r64(&mut f, &mut sum)? as u32);
            }
            shards.push((rows, assign));
        }
        let mut tail = [0u8; 8];
        f.read_exact(&mut tail)?;
        if u64::from_le_bytes(tail) != sum {
            return Err(err("checkpoint checksum mismatch"));
        }
        Ok(Checkpoint {
            alpha,
            model_tag,
            hyper,
            rounds,
            modeled_time_s,
            measured_time_s,
            mu_mode,
            mu,
            kernels,
            shards,
        })
    }

    /// Load `path`, falling back to the `<path>.prev` generation the
    /// atomic writer keeps when the newest file is torn, corrupt, or
    /// missing. The boolean is `true` when the fallback was taken (a
    /// warning is logged); the error is the *primary* file's when both
    /// generations are unreadable.
    pub fn load_with_fallback(path: &Path) -> std::io::Result<(Checkpoint, bool)> {
        match Checkpoint::load(path) {
            Ok(c) => Ok((c, false)),
            Err(e) => {
                let prev = sibling(path, ".prev");
                match Checkpoint::load(&prev) {
                    Ok(c) => {
                        eprintln!(
                            "warning: checkpoint {} unreadable ({e}); \
                             resuming from previous generation {}",
                            path.display(),
                            prev.display()
                        );
                        Ok((c, true))
                    }
                    Err(_) => Err(e),
                }
            }
        }
    }
}

/// A bounded ring of checkpoint generations in one directory (the
/// `--checkpoint-dir` mode): every save writes `gen-<rounds>.ccckpt`
/// atomically and prunes the oldest generations beyond `keep`;
/// [`CheckpointDir::load_latest_valid`] scans newest → oldest, skipping
/// torn or corrupt files with a logged warning, so a crash during a
/// save costs at most the generation being written.
#[derive(Debug, Clone)]
pub struct CheckpointDir {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointDir {
    /// Open (creating if needed) a generation directory keeping at most
    /// `keep` generations (clamped to ≥ 1).
    pub fn new(dir: impl Into<PathBuf>, keep: usize) -> std::io::Result<CheckpointDir> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(CheckpointDir {
            dir,
            keep: keep.max(1),
        })
    }

    /// The file a given generation number lives at.
    pub fn generation_path(&self, generation: u64) -> PathBuf {
        self.dir.join(format!("gen-{generation:012}.ccckpt"))
    }

    /// All `(generation, path)` pairs present, oldest first. The atomic
    /// writer's `.tmp` / `.prev` artifacts are not generations.
    pub fn generations(&self) -> std::io::Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some(num) = name
                .strip_prefix("gen-")
                .and_then(|r| r.strip_suffix(".ccckpt"))
            else {
                continue;
            };
            if let Ok(g) = num.parse::<u64>() {
                out.push((g, path));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Atomically save `ckpt` as `generation`, then prune beyond `keep`.
    pub fn save(&self, ckpt: &Checkpoint, generation: u64) -> std::io::Result<PathBuf> {
        let path = self.generation_path(generation);
        ckpt.save(&path)?;
        let gens = self.generations()?;
        if gens.len() > self.keep {
            for (_, old) in &gens[..gens.len() - self.keep] {
                let _ = std::fs::remove_file(old);
                let _ = std::fs::remove_file(sibling(old, ".prev"));
            }
        }
        Ok(path)
    }

    /// The newest generation that parses and checksums clean, or `None`
    /// when the directory holds no loadable checkpoint. Corrupt newer
    /// generations are skipped with a logged warning — the torn result
    /// of a crash mid-save must not block resume from the generation
    /// before it.
    pub fn load_latest_valid(&self) -> std::io::Result<Option<(u64, Checkpoint)>> {
        let mut gens = self.generations()?;
        gens.reverse();
        for (g, path) in gens {
            match Checkpoint::load(&path) {
                Ok(c) => return Ok(Some((g, c))),
                Err(e) => eprintln!(
                    "warning: skipping corrupt checkpoint generation {} ({e})",
                    path.display()
                ),
            }
        }
        Ok(None)
    }
}

impl<'a> Coordinator<'a> {
    /// Persist the latent state.
    pub fn save_checkpoint(&self, path: &Path) -> std::io::Result<()> {
        Checkpoint::capture(self).save(path)
    }

    /// Rebuild a coordinator from a checkpoint against the SAME dataset
    /// (sufficient statistics are recomputed from assignments; every
    /// shard is integrity-checked before the chain may continue). The
    /// saved model tag, μ vector, granularity mode, and per-shard kernel
    /// assignment must all be consistent with `cfg` — a mismatch is an
    /// error, never a silent reconfiguration.
    pub fn resume(
        data: impl Into<DataRef<'a>>,
        cfg: CoordinatorConfig,
        ckpt: &Checkpoint,
        rng: &mut Pcg64,
    ) -> Result<Coordinator<'a>, String> {
        let data = data.into();
        if ckpt.shards.len() != cfg.workers {
            return Err(format!(
                "checkpoint has {} shards, config wants {} workers",
                ckpt.shards.len(),
                cfg.workers
            ));
        }
        if ckpt.model_tag != cfg.model.tag() {
            return Err(format!(
                "checkpoint model tag {} does not match configured model {:?} (tag {})",
                ckpt.model_tag,
                cfg.model.name(),
                cfg.model.tag()
            ));
        }
        if ckpt.mu_mode != cfg.mu_mode {
            return Err(format!(
                "checkpoint was written under μ mode {}, config wants {}",
                ckpt.mu_mode.describe(),
                cfg.mu_mode.describe()
            ));
        }
        if ckpt.mu.len() != cfg.workers {
            return Err(format!(
                "checkpoint μ has {} components for {} workers",
                ckpt.mu.len(),
                cfg.workers
            ));
        }
        let mu_total: f64 = ckpt.mu.iter().sum();
        if !ckpt.mu.iter().all(|&m| m > 0.0 && m.is_finite())
            || (mu_total - 1.0).abs() > 1e-6
        {
            return Err(format!("checkpoint μ is not a simplex: {:?}", ckpt.mu));
        }
        let want_kernels = cfg.kernel_assignment.resolve(cfg.workers)?;
        if ckpt.kernels != want_kernels {
            return Err(format!(
                "checkpoint kernel assignment {:?} does not match config {:?}",
                ckpt.kernels, want_kernels
            ));
        }
        // kind-check the model/data pairing up front: `Coordinator::new`
        // panics on it, and resume must return Err instead
        cfg.model.build(data, cfg.init_beta)?;
        let mut coord = Coordinator::new(data, cfg, rng);
        // restore the granularity state: a resumed SizeProportional or
        // Adaptive run must continue from the saved μ, not restart uniform
        coord.mu = ckpt.mu.clone();
        coord.alpha = ckpt.alpha;
        // restore the sampled hypers (Bernoulli β; fixed-hyper models
        // validate bit-equality) — the LUT rebuild runs inside, handling
        // the asymmetric-β case itself (clears the LUT)
        coord.model.restore_hyper(&ckpt.hyper, data.rows() + 1)?;
        coord.rounds = ckpt.rounds;
        coord.modeled_time_s = ckpt.modeled_time_s;
        coord.measured_time_s = ckpt.measured_time_s;
        let states: Result<Vec<Shard>, String> = ckpt
            .shards
            .iter()
            .enumerate()
            .map(|(kk, (rows, assign))| {
                let rows: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
                let st = Shard::from_parts(
                    data,
                    rows,
                    assign.clone(),
                    rng.split(1000 + kk as u64),
                )?;
                st.check_invariants(data)
                    .map_err(|e| format!("shard {kk}: {e}"))?;
                Ok(st)
            })
            .collect();
        coord.replace_states(states?);
        coord.check_invariants()?;
        Ok(coord)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;
    use crate::mapreduce::CommModel;
    use crate::runtime::FallbackScorer;

    fn ckpt_dir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cc_ckpt_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_preserves_state_exactly() {
        let ds = SyntheticConfig {
            n: 500,
            d: 16,
            clusters: 4,
            beta: 0.2,
            seed: 1,
        }
        .generate();
        // non-uniform μ mode + mixed kernels (including a split–merge
        // composite, so the v2 kernel tags roundtrip): the file must
        // carry the full granularity state, not just the partition
        let cfg = CoordinatorConfig {
            workers: 3,
            comm: CommModel::free(),
            mu_mode: MuMode::SizeProportional,
            kernel_assignment: crate::sampler::KernelAssignment::RoundRobin(vec![
                KernelKind::CollapsedGibbs,
                KernelKind::SplitMergeWalker,
            ]),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(2);
        let mut coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
        for _ in 0..5 {
            coord.step(&mut rng);
        }
        let path = ckpt_dir().join("rt.ccckpt");
        coord.save_checkpoint(&path).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, Checkpoint::capture(&coord));
        assert_eq!(ckpt.mu_mode, MuMode::SizeProportional);
        assert_eq!(
            ckpt.kernels,
            vec![
                KernelKind::CollapsedGibbs,
                KernelKind::SplitMergeWalker,
                KernelKind::CollapsedGibbs,
            ]
        );
        // μ has been resampled from Dir(1 + J_k): almost surely non-uniform,
        // and the file must carry it bit-exactly
        assert!(ckpt.mu.iter().any(|&m| (m - 1.0 / 3.0).abs() > 1e-12));
        assert_eq!(
            ckpt.mu.iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
            coord.mu().iter().map(|m| m.to_bits()).collect::<Vec<_>>()
        );

        let mut rng2 = Pcg64::seed_from(3);
        let mut resumed = Coordinator::resume(&ds.train, cfg, &ckpt, &mut rng2).unwrap();
        assert_eq!(resumed.num_clusters(), coord.num_clusters());
        assert_eq!(resumed.alpha(), coord.alpha());
        assert_eq!(resumed.rounds, coord.rounds);
        assert_eq!(resumed.assignments(), coord.assignments());
        assert_eq!(
            resumed.mu().iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
            coord.mu().iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
            "resume must continue from the saved μ, not reinitialize uniform"
        );
        // and the resumed chain runs + scores
        resumed.step(&mut rng2);
        let mut sc = FallbackScorer::new();
        let ll = resumed.predictive_loglik(&ds.test, &mut sc);
        assert!(ll.is_finite());
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let ds = SyntheticConfig {
            n: 100,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 4,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(5);
        let coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let path = ckpt_dir().join("corrupt.ccckpt");
        coord.save_checkpoint(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5a;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn atomic_save_keeps_previous_generation() {
        let ds = SyntheticConfig {
            n: 120,
            d: 8,
            clusters: 2,
            beta: 0.25,
            seed: 21,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(22);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let path = ckpt_dir().join("atomic.ccckpt");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(Checkpoint::prev_path(&path));

        coord.step(&mut rng);
        let first = Checkpoint::capture(&coord);
        first.save(&path).unwrap();
        coord.step(&mut rng);
        let second = Checkpoint::capture(&coord);
        second.save(&path).unwrap();

        // no temp artifact survives a completed save, and the prior
        // generation is intact at <path>.prev
        assert!(!sibling(&path, ".tmp").exists());
        assert_eq!(Checkpoint::load(&path).unwrap(), second);
        assert_eq!(Checkpoint::load(&Checkpoint::prev_path(&path)).unwrap(), first);

        // a torn newest file falls back to the previous generation
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        let (recovered, fell_back) = Checkpoint::load_with_fallback(&path).unwrap();
        assert!(fell_back);
        assert_eq!(recovered, first);
    }

    #[test]
    fn checkpoint_dir_ring_prunes_and_falls_back() {
        let ds = SyntheticConfig {
            n: 100,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 23,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(24);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let dir = ckpt_dir().join("ring");
        let _ = std::fs::remove_dir_all(&dir);
        let ring = CheckpointDir::new(&dir, 2).unwrap();

        let mut captures = Vec::new();
        for g in 1..=4u64 {
            coord.step(&mut rng);
            let c = Checkpoint::capture(&coord);
            ring.save(&c, g).unwrap();
            captures.push(c);
        }
        // keep=2: only the two newest generations remain
        let gens = ring.generations().unwrap();
        assert_eq!(gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(), vec![3, 4]);

        // a torn newest generation is skipped with a warning and the
        // one before it is resumed from
        let newest = ring.generation_path(4);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (g, c) = ring.load_latest_valid().unwrap().unwrap();
        assert_eq!(g, 3);
        assert_eq!(c, captures[2]);

        // every generation torn → no valid checkpoint, not an error
        let older = ring.generation_path(3);
        std::fs::write(&older, b"CCCKPT3\ngarbage").unwrap();
        let _ = std::fs::remove_file(sibling(&older, ".prev"));
        let _ = std::fs::remove_file(sibling(&newest, ".prev"));
        assert!(ring.load_latest_valid().unwrap().is_none());
    }

    #[test]
    fn mismatched_config_rejected() {
        let ds = SyntheticConfig {
            n: 100,
            d: 8,
            clusters: 2,
            beta: 0.3,
            seed: 6,
        }
        .generate_with_test_fraction(0.0);
        let cfg = CoordinatorConfig {
            workers: 2,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
        let ckpt = Checkpoint::capture(&coord);
        let cfg4 = CoordinatorConfig {
            workers: 4,
            ..cfg.clone()
        };
        assert!(Coordinator::resume(&ds.train, cfg4, &ckpt, &mut rng).is_err());
        // μ-mode mismatch: a Uniform checkpoint may not silently resume
        // as Adaptive (and vice versa)
        let cfg_adaptive = CoordinatorConfig {
            mu_mode: MuMode::Adaptive {
                target_occupancy: 1.0,
            },
            ..cfg.clone()
        };
        let e = Coordinator::resume(&ds.train, cfg_adaptive, &ckpt, &mut rng).unwrap_err();
        assert!(e.contains("μ mode"), "{e}");
        // kernel-assignment mismatch is rejected too
        let cfg_walker = CoordinatorConfig {
            kernel_assignment: crate::sampler::KernelAssignment::AllSame(
                KernelKind::WalkerSlice,
            ),
            ..cfg
        };
        let e = Coordinator::resume(&ds.train, cfg_walker, &ckpt, &mut rng).unwrap_err();
        assert!(e.contains("kernel assignment"), "{e}");
    }
}
