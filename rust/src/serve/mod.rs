//! `repro serve` — clustering as a long-running service (DESIGN.md §13).
//!
//! A serve process holds the current chain state and answers
//! assign / score / density / stats queries over the length-prefixed
//! binary protocol in [`protocol`], on a TCP or Unix socket, while the
//! MCMC coordinator keeps refining in a background **driver thread**.
//!
//! ## Snapshot publication contract
//!
//! Reads never touch live sampler state. At every round boundary the
//! driver exports an immutable [`ServingSnapshot`] — the packed
//! [`TableSet`] of every live cluster plus α and the model's
//! empty-cluster predictive — and publishes it with an `Arc` swap.
//! Connection threads clone the `Arc` (one short mutex hold, no data
//! copy) and score against it with a private
//! [`FallbackScorer`], so:
//!
//! * every query is answered from **some exact posterior sample** —
//!   a state the chain actually visited at a round boundary — never
//!   from torn mid-sweep state;
//! * reads never block the chain and the chain never blocks reads
//!   (the sampler holds no lock a reader waits on, and vice versa);
//! * the response carries the snapshot's round, so a client (or the
//!   consistency gate `rust/tests/serve_consistency.rs`) can pin the
//!   exact posterior sample that answered.
//!
//! ## Online insert / delete
//!
//! Row inserts and deletes are queued ([`Request::Insert`] /
//! [`Request::Delete`]) and applied at the **next round boundary**:
//! the driver captures a [`Checkpoint`], applies the queued edits to
//! the owned data matrix and the checkpointed assignments (an insert
//! joins shard 0 as a fresh singleton cluster; a delete removes the
//! row and shifts higher row ids down), and resumes. The sufficient-
//! stat work is O(nnz) per edited row, but rebuilding shard state from
//! the checkpoint is O(N) — honest scope: this is an edit path for
//! trickle updates, not a bulk-load path. When no edits are queued the
//! rebuild never runs, so a read-only serve process consumes exactly
//! the canonical master-RNG draw sequence of an offline chain — the
//! property the consistency gate pins bit-for-bit.
//!
//! ## Durability
//!
//! Rides the PR 9 checkpoint ring unchanged: with `--checkpoint-dir`,
//! the driver saves a [`CheckpointDir`] generation every
//! `--checkpoint-every` rounds plus one final generation on shutdown,
//! and on startup auto-resumes from the latest valid generation
//! (torn generations are skipped by [`CheckpointDir::load_latest_valid`]).
//! Kill the process and restart it with the same flags: it resumes the
//! chain and serves again.
//!
//! ## Observability
//!
//! `--serve-trace FILE` appends JSONL records (via [`crate::util::json`])
//! with per-query-kind count / p50 / p99 latency columns
//! ([`LatencyHistogram`]), overall queries/sec, and rounds refined.

pub mod protocol;

use std::fs::OpenOptions;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{Checkpoint, CheckpointDir, Coordinator, CoordinatorConfig};
use crate::data::BinMat;
use crate::mapreduce::{DelayHook, FaultHook};
use crate::metrics::LatencyHistogram;
use crate::model::ModelSpec;
use crate::rng::Pcg64;
use crate::runtime::FallbackScorer;
use crate::sampler::TableSet;
use crate::special::logsumexp;
use crate::util::json::Json;

use protocol::{
    decode_request, encode_response, validate_frame_len, write_frame, AssignBody, DensityBody,
    Request, Response, RowBits, ScoreBody, StatsBody, OP_DELETE, OP_INSERT,
};

/// Configuration of one serve process (the `repro serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// listen address: `host:port` for TCP (port 0 = ephemeral), or
    /// `unix:/path/to.sock` for a Unix domain socket
    pub addr: String,
    /// total refinement rounds before the driver idles (0 = refine
    /// until shutdown); resumed rounds count toward the budget
    pub rounds: u64,
    /// checkpoint generation-ring directory (`None` = no durability)
    pub checkpoint_dir: Option<PathBuf>,
    /// save a generation every this many rounds (0 = final save only)
    pub checkpoint_every: u64,
    /// generations retained in the ring
    pub checkpoint_keep: usize,
    /// JSONL latency-trace file (`None` = no trace)
    pub trace_path: Option<PathBuf>,
    /// emit a trace record every this many rounds (0 = shutdown only)
    pub trace_every: u64,
    /// master RNG seed for the background chain
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            rounds: 0,
            checkpoint_dir: None,
            checkpoint_every: 10,
            checkpoint_keep: 3,
            trace_path: None,
            trace_every: 0,
            seed: 0,
        }
    }
}

/// One immutable published posterior sample — everything a read needs,
/// behind one `Arc`: queries against it are bit-reproducible for as
/// long as the client holds the `Arc`, regardless of how far the
/// background chain has moved on.
#[derive(Debug)]
pub struct ServingSnapshot {
    /// coordinator round this snapshot was exported at
    pub round: u64,
    /// concentration α at that round
    pub alpha: f64,
    /// rows in the served dataset at that round
    pub n_rows: u64,
    /// binary dimensions of the served dataset
    pub dims: u32,
    /// the model's empty-cluster predictive log-likelihood (−D·ln 2
    /// for the symmetric Beta–Bernoulli)
    pub log_pred_empty: f64,
    /// packed predictive tables of every live cluster, canonical order
    pub tables: TableSet,
}

/// A queued online edit, applied at the next round boundary.
enum PendingOp {
    /// row content as `BinMat` row words
    Insert(Vec<u64>),
    /// row index to remove (interpreted at application time, after
    /// earlier queued ops have shifted indices)
    Delete(u64),
}

// per-query-kind latency slots
const K_PING: usize = 0;
const K_STATS: usize = 1;
const K_SCORE: usize = 2;
const K_ASSIGN: usize = 3;
const K_DENSITY: usize = 4;
const K_INSERT: usize = 5;
const K_DELETE: usize = 6;
const KIND_NAMES: [&str; 7] = ["ping", "stats", "score", "assign", "density", "insert", "delete"];

/// Server-wide latency book (one histogram per query kind).
struct LatBook {
    started: Instant,
    hist: [LatencyHistogram; 7],
}

/// State shared between the driver, acceptor, and connection threads.
struct Shared {
    /// the published snapshot (`None` only before the first publish,
    /// which happens before the acceptor starts)
    snap: Mutex<Option<Arc<ServingSnapshot>>>,
    /// cooperative shutdown flag, polled by every thread
    stop: AtomicBool,
    /// rounds the background chain has completed (mirror of the
    /// published snapshot's round, readable without the mutex)
    rounds: AtomicU64,
    /// the driver exhausted its round budget and is idling
    refine_done: AtomicBool,
    /// queued online edits
    pending: Mutex<Vec<PendingOp>>,
    /// total queries answered
    queries: AtomicU64,
    /// latency histograms per query kind
    lat: Mutex<LatBook>,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            snap: Mutex::new(None),
            stop: AtomicBool::new(false),
            rounds: AtomicU64::new(0),
            refine_done: AtomicBool::new(false),
            pending: Mutex::new(Vec::new()),
            queries: AtomicU64::new(0),
            lat: Mutex::new(LatBook {
                started: Instant::now(),
                hist: std::array::from_fn(|_| LatencyHistogram::new()),
            }),
        }
    }
}

/// Handle to a running serve process: address, cooperative stop, join.
pub struct ServeHandle {
    addr: String,
    shared: Arc<Shared>,
    driver: thread::JoinHandle<Result<(), String>>,
    acceptor: thread::JoinHandle<()>,
}

impl ServeHandle {
    /// The resolved listen address (`host:port` with the real port for
    /// TCP — useful with port 0 — or the `unix:`-prefixed socket path).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The currently published snapshot.
    pub fn snapshot(&self) -> Option<Arc<ServingSnapshot>> {
        self.shared.snap.lock().unwrap().clone()
    }

    /// Rounds the background chain has completed.
    pub fn rounds_refined(&self) -> u64 {
        self.shared.rounds.load(Ordering::SeqCst)
    }

    /// Whether the driver has exhausted its round budget and is idling.
    pub fn refinement_done(&self) -> bool {
        self.shared.refine_done.load(Ordering::SeqCst)
    }

    /// Request cooperative shutdown (idempotent): the driver saves a
    /// final checkpoint generation and every thread exits.
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Block until shutdown is requested by someone else — a client's
    /// `SHUTDOWN` frame or another thread calling through [`Self::stop`]
    /// — then join. This is the `repro serve` foreground loop.
    pub fn serve_forever(self) -> Result<(), String> {
        while !self.shared.stop.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(50));
        }
        self.join()
    }

    /// Stop (if not already stopping) and wait for every thread. The
    /// driver's terminal result is returned; a driver panic becomes an
    /// `Err`.
    pub fn join(self) -> Result<(), String> {
        self.stop();
        let r = match self.driver.join() {
            Ok(r) => r,
            Err(p) => Err(format!("serve driver panicked: {}", panic_text(&*p))),
        };
        let _ = self.acceptor.join();
        r
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

/// Start a serve process over an owned dataset. Binds the listener,
/// starts the background driver (which publishes the first snapshot —
/// resuming from the checkpoint ring when one is valid — before this
/// function returns), then starts accepting connections.
///
/// Restricted to the Bernoulli model: the wire protocol carries binary
/// rows. Returns `Err` on bind failure, on a non-Bernoulli config, or
/// when checkpoint resume fails.
pub fn spawn(data: BinMat, ccfg: CoordinatorConfig, scfg: ServeConfig) -> Result<ServeHandle, String> {
    spawn_with_hooks(data, ccfg, scfg, None, None)
}

/// [`spawn`] with injected map-layer hooks — the consistency gate's
/// lever for stalling / crashing background rounds
/// ([`DelayHook`] / [`FaultHook`], installed on the coordinator exactly
/// as in the fault-tolerance suite) while the serving side keeps
/// answering from published snapshots.
pub fn spawn_with_hooks(
    data: BinMat,
    ccfg: CoordinatorConfig,
    scfg: ServeConfig,
    delay: Option<DelayHook>,
    fault: Option<FaultHook>,
) -> Result<ServeHandle, String> {
    if !matches!(ccfg.model, ModelSpec::Bernoulli) {
        return Err(format!(
            "repro serve requires the Bernoulli model (wire rows are binary); got {}",
            ccfg.model.name()
        ));
    }
    if data.rows() == 0 {
        return Err("cannot serve an empty dataset".to_string());
    }
    let (listener, addr) =
        Listener::bind(&scfg.addr).map_err(|e| format!("bind {}: {e}", scfg.addr))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("set_nonblocking: {e}"))?;
    let shared = Arc::new(Shared::new());
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    let driver = {
        let shared = Arc::clone(&shared);
        let scfg = scfg.clone();
        thread::Builder::new()
            .name("serve-driver".to_string())
            .spawn(move || driver_loop(data, ccfg, scfg, delay, fault, &shared, ready_tx))
            .map_err(|e| format!("spawn driver: {e}"))?
    };
    match ready_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            let _ = driver.join();
            return Err(e);
        }
        Err(_) => {
            // driver died before signaling readiness
            return Err(match driver.join() {
                Ok(Err(e)) => e,
                Ok(Ok(())) => "serve driver exited before publishing a snapshot".to_string(),
                Err(p) => format!("serve driver panicked: {}", panic_text(&*p)),
            });
        }
    }
    let acceptor = {
        let shared = Arc::clone(&shared);
        thread::Builder::new()
            .name("serve-acceptor".to_string())
            .spawn(move || acceptor_loop(listener, &shared))
            .map_err(|e| format!("spawn acceptor: {e}"))?
    };
    Ok(ServeHandle {
        addr,
        shared,
        driver,
        acceptor,
    })
}

// ---------------------------------------------------------------------------
// background driver

fn driver_loop(
    mut data: BinMat,
    ccfg: CoordinatorConfig,
    scfg: ServeConfig,
    delay: Option<DelayHook>,
    fault: Option<FaultHook>,
    shared: &Shared,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> Result<(), String> {
    let mut ready = Some(ready_tx);
    // an error before readiness must surface from spawn(); after
    // readiness the serving side keeps answering from the last
    // published snapshot and the error surfaces from join()
    macro_rules! fail {
        ($e:expr) => {{
            let e: String = $e;
            if let Some(tx) = ready.take() {
                let _ = tx.send(Err(e.clone()));
            }
            return Err(e);
        }};
    }
    let ring = match &scfg.checkpoint_dir {
        Some(d) => match CheckpointDir::new(d, scfg.checkpoint_keep) {
            Ok(r) => Some(r),
            Err(e) => fail!(format!("checkpoint dir {}: {e}", d.display())),
        },
        None => None,
    };
    let mut resume_from: Option<Checkpoint> = match &ring {
        Some(r) => match r.load_latest_valid() {
            Ok(found) => found.map(|(_, c)| c),
            Err(e) => fail!(format!("scanning checkpoint ring: {e}")),
        },
        None => None,
    };
    let mut rng = Pcg64::seed_from(scfg.seed);
    'outer: loop {
        let mut coord = match resume_from.take() {
            Some(ck) => match Coordinator::resume(&data, ccfg.clone(), &ck, &mut rng) {
                Ok(c) => c,
                Err(e) => fail!(format!("checkpoint resume: {e}")),
            },
            None => Coordinator::new(&data, ccfg.clone(), &mut rng),
        };
        coord.set_map_delay_hook(delay.clone());
        coord.set_map_fault_hook(fault.clone());
        publish(shared, &mut coord, data.rows());
        if let Some(tx) = ready.take() {
            let _ = tx.send(Ok(()));
        }
        loop {
            if shared.stop.load(Ordering::SeqCst) {
                if let Some(r) = &ring {
                    if let Err(e) = r.save(&Checkpoint::capture(&coord), coord.rounds) {
                        eprintln!("warning: final checkpoint save failed: {e}");
                    }
                }
                emit_trace(&scfg, shared, coord.rounds);
                return Ok(());
            }
            let ops: Vec<PendingOp> = std::mem::take(&mut *shared.pending.lock().unwrap());
            if !ops.is_empty() {
                let mut ck = Checkpoint::capture(&coord);
                drop(coord);
                apply_pending(&mut data, &mut ck, ops);
                resume_from = Some(ck);
                continue 'outer;
            }
            if scfg.rounds > 0 && coord.rounds >= scfg.rounds {
                shared.refine_done.store(true, Ordering::SeqCst);
                thread::sleep(Duration::from_millis(2));
                continue;
            }
            coord.step(&mut rng);
            publish(shared, &mut coord, data.rows());
            if let Some(r) = &ring {
                if scfg.checkpoint_every > 0 && coord.rounds % scfg.checkpoint_every == 0 {
                    if let Err(e) = r.save(&Checkpoint::capture(&coord), coord.rounds) {
                        eprintln!("warning: periodic checkpoint save failed: {e}");
                    }
                }
            }
            if scfg.trace_every > 0 && coord.rounds % scfg.trace_every == 0 {
                emit_trace(&scfg, shared, coord.rounds);
            }
        }
    }
}

/// Round-boundary snapshot publication: export the packed tables (no
/// RNG consumed, no chain state changed) and swap the `Arc`.
fn publish(shared: &Shared, coord: &mut Coordinator<'_>, n_rows: usize) {
    let tables = coord.export_table_set();
    let bern = coord.model.as_bernoulli();
    let snap = ServingSnapshot {
        round: coord.rounds,
        alpha: coord.alpha,
        n_rows: n_rows as u64,
        dims: bern.d as u32,
        log_pred_empty: bern.empty_cluster_loglik(),
        tables,
    };
    *shared.snap.lock().unwrap() = Some(Arc::new(snap));
    shared.rounds.store(coord.rounds, Ordering::SeqCst);
}

/// Apply queued edits to the owned data matrix and the checkpointed
/// assignments. Inserts append to the matrix and join shard 0 as a
/// fresh singleton cluster; deletes remove the row everywhere and
/// shift higher row ids down. Stale deletes (index out of range at
/// application time) are dropped with a warning.
fn apply_pending(data: &mut BinMat, ck: &mut Checkpoint, ops: Vec<PendingOp>) {
    let d = data.dims();
    let wpr = d.div_ceil(64);
    let mut n = data.rows();
    let mut words: Vec<u64> = data.words().to_vec();
    for op in ops {
        match op {
            PendingOp::Insert(row_words) => {
                debug_assert_eq!(row_words.len(), wpr);
                words.extend_from_slice(&row_words);
                let sh = &mut ck.shards[0];
                // fresh singleton: one past the shard's highest slot
                let next_slot = sh.1.iter().map(|&a| a + 1).max().unwrap_or(0);
                sh.0.push(n as u64);
                sh.1.push(next_slot);
                n += 1;
            }
            PendingOp::Delete(r) => {
                let r = r as usize;
                if r >= n {
                    eprintln!("warning: dropping stale delete of row {r} (have {n} rows)");
                    continue;
                }
                words.drain(r * wpr..(r + 1) * wpr);
                n -= 1;
                for (rows, assign) in ck.shards.iter_mut() {
                    let mut i = 0;
                    while i < rows.len() {
                        if rows[i] == r as u64 {
                            rows.remove(i);
                            assign.remove(i);
                        } else {
                            if rows[i] > r as u64 {
                                rows[i] -= 1;
                            }
                            i += 1;
                        }
                    }
                }
            }
        }
    }
    *data = BinMat::from_words(n, d, words);
}

/// Append one JSONL trace record: rounds refined, overall queries/sec,
/// and per-kind count / p50 / p99 latency columns.
fn emit_trace(scfg: &ServeConfig, shared: &Shared, rounds: u64) {
    let Some(path) = &scfg.trace_path else {
        return;
    };
    let mut j = Json::obj();
    {
        let book = shared.lat.lock().unwrap();
        let elapsed = book.started.elapsed().as_secs_f64().max(1e-9);
        let total: u64 = book.hist.iter().map(|h| h.count()).sum();
        j.set("rounds_refined", Json::num(rounds as f64));
        j.set("elapsed_s", Json::num(elapsed));
        j.set("queries", Json::num(total as f64));
        j.set("qps", Json::num(total as f64 / elapsed));
        for (name, h) in KIND_NAMES.iter().zip(book.hist.iter()) {
            j.set(&format!("{name}_count"), Json::num(h.count() as f64));
            j.set(&format!("{name}_p50_us"), Json::num(h.quantile(0.50)));
            j.set(&format!("{name}_p99_us"), Json::num(h.quantile(0.99)));
        }
    }
    let line = j.to_string();
    match OpenOptions::new().create(true).append(true).open(path) {
        Ok(mut f) => {
            if let Err(e) = writeln!(f, "{line}") {
                eprintln!("warning: serve-trace write failed: {e}");
            }
        }
        Err(e) => eprintln!("warning: serve-trace open failed: {e}"),
    }
}

// ---------------------------------------------------------------------------
// sockets

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Listener {
    /// Bind `host:port` (TCP) or `unix:/path` and return the handle
    /// plus the resolved display address.
    fn bind(addr: &str) -> std::io::Result<(Listener, String)> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                return Ok((Listener::Unix(l), format!("unix:{path}")));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix sockets are not supported on this platform",
                ));
            }
        }
        let l = TcpListener::bind(addr)?;
        let resolved = l.local_addr()?.to_string();
        Ok((Listener::Tcp(l), resolved))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Stream::Unix(s))
            }
        }
    }
}

impl Stream {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl std::io::Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl std::io::Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------------
// acceptor + connections

fn acceptor_loop(listener: Listener, shared: &Arc<Shared>) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => {
                let shared = Arc::clone(shared);
                if let Ok(h) = thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || conn_loop(stream, &shared))
                {
                    conns.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Outcome of one server-side frame read.
enum FrameRead {
    Frame(Vec<u8>),
    /// clean EOF, peer reset, or cooperative stop
    Closed,
    /// length-prefix violation or mid-frame EOF: respond + disconnect
    FramingError(String),
}

enum ReadStatus {
    Done,
    Closed,
    Error(String),
}

/// Fill `buf` from the stream, polling the stop flag across read
/// timeouts. `at_boundary` distinguishes a clean EOF (no bytes of this
/// frame read yet) from a truncated frame.
fn read_full(stream: &mut Stream, buf: &mut [u8], shared: &Shared, at_boundary: bool) -> ReadStatus {
    use std::io::Read as _;
    let mut got = 0usize;
    while got < buf.len() {
        if shared.stop.load(Ordering::SeqCst) {
            return ReadStatus::Closed;
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return if at_boundary && got == 0 {
                    ReadStatus::Closed
                } else {
                    ReadStatus::Error("unexpected end of stream mid-frame".to_string())
                };
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return ReadStatus::Closed,
        }
    }
    ReadStatus::Done
}

fn read_frame_server(stream: &mut Stream, shared: &Shared) -> FrameRead {
    let mut hdr = [0u8; 4];
    match read_full(stream, &mut hdr, shared, true) {
        ReadStatus::Done => {}
        ReadStatus::Closed => return FrameRead::Closed,
        ReadStatus::Error(e) => return FrameRead::FramingError(e),
    }
    let len = u32::from_le_bytes(hdr);
    // the pre-allocation gate: a hostile prefix cannot OOM the server
    if let Err(e) = validate_frame_len(len) {
        return FrameRead::FramingError(e.0);
    }
    let mut payload = vec![0u8; len as usize];
    match read_full(stream, &mut payload, shared, false) {
        ReadStatus::Done => FrameRead::Frame(payload),
        ReadStatus::Closed => FrameRead::Closed,
        ReadStatus::Error(e) => FrameRead::FramingError(e),
    }
}

fn conn_loop(mut stream: Stream, shared: &Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut scorer = FallbackScorer::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let payload = match read_frame_server(&mut stream, shared) {
            FrameRead::Frame(p) => p,
            FrameRead::Closed => return,
            FrameRead::FramingError(e) => {
                let resp = encode_response(&Response::Error(format!("framing error: {e}")));
                let _ = write_frame(&mut stream, &resp);
                return;
            }
        };
        let t0 = Instant::now();
        let (resp, kind) = match decode_request(&payload) {
            Ok(req) => handle_request(req, shared, &mut scorer),
            Err(e) => (Response::Error(format!("protocol error: {e}")), None),
        };
        if let Some(k) = kind {
            shared.lat.lock().unwrap().hist[k].record(t0.elapsed());
            shared.queries.fetch_add(1, Ordering::SeqCst);
        }
        let shutting = matches!(resp, Response::ShuttingDown);
        if write_frame(&mut stream, &encode_response(&resp)).is_err() {
            return;
        }
        if shutting {
            return;
        }
    }
}

fn current(shared: &Shared) -> Option<Arc<ServingSnapshot>> {
    shared.snap.lock().unwrap().clone()
}

/// Score one wire row against the current snapshot's tables — the
/// exact offline reference call
/// ([`TableSet::score_rows`] through the pure-Rust [`FallbackScorer`]).
fn score_row(
    shared: &Shared,
    row: &RowBits,
    scorer: &mut FallbackScorer,
) -> Result<(Arc<ServingSnapshot>, Vec<f64>), String> {
    let Some(s) = current(shared) else {
        return Err("no snapshot published yet".to_string());
    };
    if row.d != s.dims {
        return Err(format!(
            "row has {} dims, served dataset has {}",
            row.d, s.dims
        ));
    }
    let m = row.to_binmat();
    let mut out = Vec::new();
    s.tables.score_rows(scorer, &m, &[0], &mut out);
    Ok((s, out))
}

fn handle_request(
    req: Request,
    shared: &Shared,
    scorer: &mut FallbackScorer,
) -> (Response, Option<usize>) {
    match req {
        Request::Ping => (Response::Pong, Some(K_PING)),
        Request::Stats => {
            let resp = match current(shared) {
                Some(s) => Response::Stats(StatsBody {
                    round: s.round,
                    rows: s.n_rows,
                    dims: s.dims,
                    clusters: s.tables.num_clusters() as u32,
                    alpha: s.alpha,
                    queries: shared.queries.load(Ordering::SeqCst),
                }),
                None => Response::Error("no snapshot published yet".to_string()),
            };
            (resp, Some(K_STATS))
        }
        Request::Score(row) => {
            let resp = match score_row(shared, &row, scorer) {
                Ok((s, scores)) => Response::Score(ScoreBody {
                    round: s.round,
                    log_pred_empty: s.log_pred_empty,
                    scores,
                }),
                Err(e) => Response::Error(e),
            };
            (resp, Some(K_SCORE))
        }
        Request::Assign(row) => {
            let resp = match score_row(shared, &row, scorer) {
                Ok((s, scores)) => {
                    // deterministic MAP: start from the new-cluster
                    // weight; an existing cluster must strictly exceed
                    // the incumbent, so ties resolve to the earliest
                    // candidate in snapshot order
                    let mut cluster = -1i64;
                    let mut w = s.alpha.ln() + s.log_pred_empty;
                    for (i, &sc) in scores.iter().enumerate() {
                        let wi = s.tables.logn()[i] + sc;
                        if wi > w {
                            w = wi;
                            cluster = i as i64;
                        }
                    }
                    Response::Assign(AssignBody {
                        round: s.round,
                        cluster,
                        log_weight: w,
                    })
                }
                Err(e) => Response::Error(e),
            };
            (resp, Some(K_ASSIGN))
        }
        Request::Density(row) => {
            let resp = match score_row(shared, &row, scorer) {
                Ok((s, scores)) => {
                    let mut terms: Vec<f64> = scores
                        .iter()
                        .enumerate()
                        .map(|(i, &sc)| s.tables.logn()[i] + sc)
                        .collect();
                    terms.push(s.alpha.ln() + s.log_pred_empty);
                    let log_density = logsumexp(&terms) - (s.n_rows as f64 + s.alpha).ln();
                    Response::Density(DensityBody {
                        round: s.round,
                        log_density,
                    })
                }
                Err(e) => Response::Error(e),
            };
            (resp, Some(K_DENSITY))
        }
        Request::Insert(row) => {
            let resp = match current(shared) {
                Some(s) if row.d == s.dims => {
                    let mut q = shared.pending.lock().unwrap();
                    let queued_inserts = q
                        .iter()
                        .filter(|op| matches!(op, PendingOp::Insert(_)))
                        .count() as u64;
                    let provisional = s.n_rows + queued_inserts;
                    q.push(PendingOp::Insert(row.to_words()));
                    Response::Queued {
                        op: OP_INSERT,
                        row: provisional,
                    }
                }
                Some(s) => Response::Error(format!(
                    "row has {} dims, served dataset has {}",
                    row.d, s.dims
                )),
                None => Response::Error("no snapshot published yet".to_string()),
            };
            (resp, Some(K_INSERT))
        }
        Request::Delete(r) => {
            shared.pending.lock().unwrap().push(PendingOp::Delete(r));
            (Response::Queued { op: OP_DELETE, row: r }, Some(K_DELETE))
        }
        Request::Shutdown => {
            shared.stop.store(true, Ordering::SeqCst);
            (Response::ShuttingDown, None)
        }
    }
}

// ---------------------------------------------------------------------------
// client

/// Minimal blocking client for the serve protocol — the loopback test
/// harness and the `repro serve --ping` probe. One request in flight
/// at a time.
pub struct Client {
    stream: Stream,
}

impl Client {
    /// Connect to `host:port` (TCP) or `unix:/path`.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                Stream::Unix(UnixStream::connect(path)?)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix sockets are not supported on this platform",
                ));
            }
        } else {
            let s = TcpStream::connect(addr)?;
            let _ = s.set_nodelay(true);
            Stream::Tcp(s)
        };
        Ok(Client { stream })
    }

    /// Cap how long [`Self::request`] / [`Self::read_response`] wait
    /// for a response (tests use this so a server bug cannot hang them).
    pub fn set_timeout(&mut self, d: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(d)
    }

    /// Send one request and wait for its response.
    pub fn request(&mut self, req: &Request) -> std::io::Result<Response> {
        write_frame(&mut self.stream, &protocol::encode_request(req))?;
        self.read_response()
    }

    /// Send raw bytes as-is — the fuzz suite's malformed-frame lever.
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        use std::io::Write as _;
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Half-close the write side (TCP only) so the server sees EOF
    /// while responses can still be read.
    pub fn finish_writes(&mut self) -> std::io::Result<()> {
        match &self.stream {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Write),
            #[cfg(unix)]
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Write),
        }
    }

    /// Read one response frame.
    pub fn read_response(&mut self) -> std::io::Result<Response> {
        let payload = protocol::read_frame(&mut self.stream)?;
        protocol::decode_response(&payload)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.0))
    }
}
