//! Length-prefixed binary wire protocol for [`repro serve`](crate::serve).
//!
//! A **frame** is a little-endian `u32` payload length followed by
//! exactly that many payload bytes. The payload is one opcode byte plus
//! an opcode-specific body. Responses use the same framing with
//! response tags in the `0x80+` range so a stream captured mid-flight
//! is self-describing.
//!
//! The codec is deliberately split from the socket layer: every decode
//! path here is a pure, bounds-checked, `Result`-returning function
//! over a byte slice, so the fuzz suite (`rust/tests/serve_protocol.rs`)
//! can hammer truncations, bit flips, and random garbage without a
//! socket in the loop — and the connection loop in [`crate::serve`]
//! reaches the exact same functions, so loopback coverage and pure
//! coverage certify the same code.
//!
//! Hardening contract (mirrors the checkpoint loader's hostile-length
//! discipline in [`crate::coordinator::checkpoint`]):
//!
//! * a length prefix larger than [`MAX_FRAME`] is rejected **before**
//!   any allocation — a hostile header cannot OOM the server;
//! * every multi-byte read is bounds-checked against the slice;
//! * trailing bytes after a complete body are an error (no smuggling);
//! * row bitmaps must zero their padding bits, so each (d, row) value
//!   has exactly one wire encoding.

use std::fmt;
use std::io::{self, Read, Write};

use crate::data::BinMat;

/// Hard cap on a frame's payload length in bytes (1 MiB). Checked
/// against the raw length prefix before any buffer is allocated.
pub const MAX_FRAME: u32 = 1 << 20;

/// Request opcode: liveness probe, empty body.
pub const OP_PING: u8 = 0x01;
/// Request opcode: snapshot + counter summary, empty body.
pub const OP_STATS: u8 = 0x02;
/// Request opcode: per-cluster log-likelihood block of one row.
pub const OP_SCORE: u8 = 0x03;
/// Request opcode: MAP cluster assignment of one row.
pub const OP_ASSIGN: u8 = 0x04;
/// Request opcode: predictive log-density of one row.
pub const OP_DENSITY: u8 = 0x05;
/// Request opcode: queue a row insert for the next round boundary.
pub const OP_INSERT: u8 = 0x06;
/// Request opcode: queue a row delete for the next round boundary.
pub const OP_DELETE: u8 = 0x07;
/// Request opcode: stop refining, checkpoint, and shut the server down.
pub const OP_SHUTDOWN: u8 = 0x0F;

/// Response tag: reply to [`OP_PING`].
pub const RESP_PONG: u8 = 0x81;
/// Response tag: reply to [`OP_STATS`].
pub const RESP_STATS: u8 = 0x82;
/// Response tag: reply to [`OP_SCORE`].
pub const RESP_SCORE: u8 = 0x83;
/// Response tag: reply to [`OP_ASSIGN`].
pub const RESP_ASSIGN: u8 = 0x84;
/// Response tag: reply to [`OP_DENSITY`].
pub const RESP_DENSITY: u8 = 0x85;
/// Response tag: insert/delete acknowledged and queued.
pub const RESP_QUEUED: u8 = 0x86;
/// Response tag: reply to [`OP_SHUTDOWN`].
pub const RESP_SHUTDOWN: u8 = 0x8F;
/// Response tag: protocol or query error (UTF-8 message body).
pub const RESP_ERROR: u8 = 0xEE;

/// A malformed frame or payload. Carries a human-readable reason; the
/// connection loop forwards it to the client as a [`Response::Error`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ProtoError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ProtoError> {
    Err(ProtoError(msg.into()))
}

/// One binary data row on the wire: `d` dimensions as an LSB-first
/// bitmap of `ceil(d/8)` bytes. Padding bits above `d` in the last
/// byte MUST be zero (enforced on decode), so every row has exactly
/// one encoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowBits {
    /// number of binary dimensions (must match the served dataset)
    pub d: u32,
    /// `ceil(d/8)` bitmap bytes, bit `i` of byte `i/8` = dimension `i`
    pub bytes: Vec<u8>,
}

impl RowBits {
    /// Build from an explicit list of set dimensions (`ones` may be in
    /// any order; out-of-range indices panic — this is the trusted,
    /// sender-side constructor).
    pub fn from_ones(d: u32, ones: &[u32]) -> RowBits {
        let mut bytes = vec![0u8; (d as usize).div_ceil(8)];
        for &i in ones {
            assert!(i < d, "dimension {i} out of range for d={d}");
            bytes[(i / 8) as usize] |= 1 << (i % 8);
        }
        RowBits { d, bytes }
    }

    /// Encode row `r` of a [`BinMat`] (the loopback test path: the same
    /// rows the offline reference scores go over the wire bit-for-bit).
    pub fn from_binmat(m: &BinMat, r: usize) -> RowBits {
        let d = m.dims() as u32;
        let mut bytes = vec![0u8; m.dims().div_ceil(8)];
        m.for_each_one(r, |i| bytes[i / 8] |= 1 << (i % 8));
        RowBits { d, bytes }
    }

    /// Unpack into the `u64` row-word layout of [`BinMat`]
    /// (`ceil(d/64)` little-endian words, LSB-first within each word).
    pub fn to_words(&self) -> Vec<u64> {
        let d = self.d as usize;
        let mut words = vec![0u64; d.div_ceil(64)];
        for (bi, &b) in self.bytes.iter().enumerate() {
            words[bi / 8] |= (b as u64) << ((bi % 8) * 8);
        }
        words
    }

    /// Wrap into a 1-row [`BinMat`] for the read-only scoring path.
    pub fn to_binmat(&self) -> BinMat {
        BinMat::from_words(1, self.d as usize, self.to_words())
    }
}

/// One decoded client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// liveness probe
    Ping,
    /// snapshot + counter summary
    Stats,
    /// per-cluster log-likelihood block of one row
    Score(RowBits),
    /// MAP cluster assignment of one row
    Assign(RowBits),
    /// predictive log-density of one row
    Density(RowBits),
    /// queue a row insert for the next round boundary
    Insert(RowBits),
    /// queue a delete of the given row index for the next round boundary
    Delete(u64),
    /// stop refining, save a final checkpoint, and shut down
    Shutdown,
}

/// Stats summary body ([`RESP_STATS`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StatsBody {
    /// coordinator round of the published snapshot
    pub round: u64,
    /// rows in the served dataset at that snapshot
    pub rows: u64,
    /// binary dimensions of the served dataset
    pub dims: u32,
    /// live clusters in the snapshot
    pub clusters: u32,
    /// concentration α at the snapshot
    pub alpha: f64,
    /// queries answered by this server process so far
    pub queries: u64,
}

/// Score body ([`RESP_SCORE`]): the raw per-cluster log-likelihood
/// block, bit-identical to offline
/// [`Scorer::score_rows_against_clusters`](crate::runtime::Scorer::score_rows_against_clusters)
/// over the snapshot's exported [`TableSet`](crate::sampler::TableSet).
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreBody {
    /// coordinator round of the snapshot that answered
    pub round: u64,
    /// empty-cluster predictive log-likelihood for this model
    pub log_pred_empty: f64,
    /// one log-likelihood per live cluster, snapshot slot order
    pub scores: Vec<f64>,
}

/// Assign body ([`RESP_ASSIGN`]).
#[derive(Debug, Clone, PartialEq)]
pub struct AssignBody {
    /// coordinator round of the snapshot that answered
    pub round: u64,
    /// MAP cluster index in the snapshot's slot order, `-1` = new cluster
    pub cluster: i64,
    /// the winning unnormalized log posterior weight
    pub log_weight: f64,
}

/// Density body ([`RESP_DENSITY`]).
#[derive(Debug, Clone, PartialEq)]
pub struct DensityBody {
    /// coordinator round of the snapshot that answered
    pub round: u64,
    /// predictive log-density of the queried row
    pub log_density: f64,
}

/// One decoded server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// reply to [`Request::Ping`]
    Pong,
    /// reply to [`Request::Stats`]
    Stats(StatsBody),
    /// reply to [`Request::Score`]
    Score(ScoreBody),
    /// reply to [`Request::Assign`]
    Assign(AssignBody),
    /// reply to [`Request::Density`]
    Density(DensityBody),
    /// insert/delete queued: echoes the opcode and the row index
    /// (provisional for inserts — applied at the next round boundary)
    Queued {
        /// the request opcode being acknowledged
        op: u8,
        /// affected row index (provisional for inserts)
        row: u64,
    },
    /// reply to [`Request::Shutdown`]
    ShuttingDown,
    /// protocol or query error (the connection stays up for in-frame
    /// decode errors; framing errors disconnect)
    Error(String),
}

// ---------------------------------------------------------------------------
// cursor primitives

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.b.len() - self.i < n {
            return err(format!(
                "truncated payload: need {n} more bytes, have {}",
                self.b.len() - self.i
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.i != self.b.len() {
            return err(format!(
                "{} trailing bytes after complete body",
                self.b.len() - self.i
            ));
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn decode_row(cur: &mut Cur<'_>) -> Result<RowBits, ProtoError> {
    let d = cur.u32()?;
    if d == 0 {
        return err("row with zero dimensions");
    }
    let nbytes = (d as usize).div_ceil(8);
    let bytes = cur.take(nbytes)?.to_vec();
    // reject nonzero padding bits so each row has exactly one encoding
    let pad = (nbytes * 8 - d as usize) as u32;
    if pad > 0 {
        let last = bytes[nbytes - 1];
        if last >> (8 - pad) != 0 {
            return err("nonzero padding bits in row bitmap");
        }
    }
    Ok(RowBits { d, bytes })
}

fn encode_row(out: &mut Vec<u8>, row: &RowBits) {
    debug_assert_eq!(row.bytes.len(), (row.d as usize).div_ceil(8));
    put_u32(out, row.d);
    out.extend_from_slice(&row.bytes);
}

// ---------------------------------------------------------------------------
// request codec

/// Decode one request payload (the bytes after the length prefix).
/// Never panics on any input; all failures are [`ProtoError`]s.
pub fn decode_request(payload: &[u8]) -> Result<Request, ProtoError> {
    let mut cur = Cur::new(payload);
    let op = match cur.u8() {
        Ok(op) => op,
        Err(_) => return err("empty payload"),
    };
    let req = match op {
        OP_PING => Request::Ping,
        OP_STATS => Request::Stats,
        OP_SCORE => Request::Score(decode_row(&mut cur)?),
        OP_ASSIGN => Request::Assign(decode_row(&mut cur)?),
        OP_DENSITY => Request::Density(decode_row(&mut cur)?),
        OP_INSERT => Request::Insert(decode_row(&mut cur)?),
        OP_DELETE => Request::Delete(cur.u64()?),
        OP_SHUTDOWN => Request::Shutdown,
        other => return err(format!("unknown opcode 0x{other:02x}")),
    };
    cur.done()?;
    Ok(req)
}

/// Encode one request into a frame payload (no length prefix).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    match req {
        Request::Ping => out.push(OP_PING),
        Request::Stats => out.push(OP_STATS),
        Request::Score(row) => {
            out.push(OP_SCORE);
            encode_row(&mut out, row);
        }
        Request::Assign(row) => {
            out.push(OP_ASSIGN);
            encode_row(&mut out, row);
        }
        Request::Density(row) => {
            out.push(OP_DENSITY);
            encode_row(&mut out, row);
        }
        Request::Insert(row) => {
            out.push(OP_INSERT);
            encode_row(&mut out, row);
        }
        Request::Delete(r) => {
            out.push(OP_DELETE);
            put_u64(&mut out, *r);
        }
        Request::Shutdown => out.push(OP_SHUTDOWN),
    }
    out
}

// ---------------------------------------------------------------------------
// response codec

/// Decode one response payload. Never panics on any input.
pub fn decode_response(payload: &[u8]) -> Result<Response, ProtoError> {
    let mut cur = Cur::new(payload);
    let tag = match cur.u8() {
        Ok(t) => t,
        Err(_) => return err("empty response payload"),
    };
    let resp = match tag {
        RESP_PONG => Response::Pong,
        RESP_STATS => Response::Stats(StatsBody {
            round: cur.u64()?,
            rows: cur.u64()?,
            dims: cur.u32()?,
            clusters: cur.u32()?,
            alpha: cur.f64()?,
            queries: cur.u64()?,
        }),
        RESP_SCORE => {
            let round = cur.u64()?;
            let log_pred_empty = cur.f64()?;
            let j = cur.u32()? as usize;
            // j is implicitly bounded: each score costs 8 payload bytes,
            // and the payload already passed the MAX_FRAME gate
            let mut scores = Vec::with_capacity(j.min(MAX_FRAME as usize / 8));
            for _ in 0..j {
                scores.push(cur.f64()?);
            }
            Response::Score(ScoreBody {
                round,
                log_pred_empty,
                scores,
            })
        }
        RESP_ASSIGN => Response::Assign(AssignBody {
            round: cur.u64()?,
            cluster: cur.u64()? as i64,
            log_weight: cur.f64()?,
        }),
        RESP_DENSITY => Response::Density(DensityBody {
            round: cur.u64()?,
            log_density: cur.f64()?,
        }),
        RESP_QUEUED => Response::Queued {
            op: cur.u8()?,
            row: cur.u64()?,
        },
        RESP_SHUTDOWN => Response::ShuttingDown,
        RESP_ERROR => {
            let n = cur.u32()? as usize;
            let bytes = cur.take(n)?.to_vec();
            match String::from_utf8(bytes) {
                Ok(s) => Response::Error(s),
                Err(_) => return err("error message is not UTF-8"),
            }
        }
        other => return err(format!("unknown response tag 0x{other:02x}")),
    };
    cur.done()?;
    Ok(resp)
}

/// Encode one response into a frame payload (no length prefix).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    match resp {
        Response::Pong => out.push(RESP_PONG),
        Response::Stats(s) => {
            out.push(RESP_STATS);
            put_u64(&mut out, s.round);
            put_u64(&mut out, s.rows);
            put_u32(&mut out, s.dims);
            put_u32(&mut out, s.clusters);
            put_f64(&mut out, s.alpha);
            put_u64(&mut out, s.queries);
        }
        Response::Score(s) => {
            out.push(RESP_SCORE);
            put_u64(&mut out, s.round);
            put_f64(&mut out, s.log_pred_empty);
            put_u32(&mut out, s.scores.len() as u32);
            for &v in &s.scores {
                put_f64(&mut out, v);
            }
        }
        Response::Assign(a) => {
            out.push(RESP_ASSIGN);
            put_u64(&mut out, a.round);
            put_u64(&mut out, a.cluster as u64);
            put_f64(&mut out, a.log_weight);
        }
        Response::Density(d) => {
            out.push(RESP_DENSITY);
            put_u64(&mut out, d.round);
            put_f64(&mut out, d.log_density);
        }
        Response::Queued { op, row } => {
            out.push(RESP_QUEUED);
            out.push(*op);
            put_u64(&mut out, *row);
        }
        Response::ShuttingDown => out.push(RESP_SHUTDOWN),
        Response::Error(msg) => {
            out.push(RESP_ERROR);
            let bytes = msg.as_bytes();
            // clamp so an error response always fits a frame
            let n = bytes.len().min(MAX_FRAME as usize - 16);
            put_u32(&mut out, n as u32);
            out.extend_from_slice(&bytes[..n]);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// frame IO

/// Write one frame (length prefix + payload). Panics if the payload
/// exceeds [`MAX_FRAME`] — oversized frames are a sender-side bug, not
/// a wire condition.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    assert!(
        payload.len() <= MAX_FRAME as usize,
        "frame payload exceeds MAX_FRAME"
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame payload. A length prefix of zero or above
/// [`MAX_FRAME`] yields `InvalidData` **before any allocation**.
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr);
    validate_frame_len(len).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.0))?;
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// The pre-allocation length-prefix gate shared by [`read_frame`] and
/// the server's incremental reader: zero-length and oversized prefixes
/// are both rejected.
pub fn validate_frame_len(len: u32) -> Result<(), ProtoError> {
    if len == 0 {
        return err("zero-length frame");
    }
    if len > MAX_FRAME {
        return err(format!(
            "frame length {len} exceeds MAX_FRAME ({MAX_FRAME})"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_requests() -> Vec<Request> {
        let row = RowBits::from_ones(13, &[0, 5, 12]);
        vec![
            Request::Ping,
            Request::Stats,
            Request::Score(row.clone()),
            Request::Assign(row.clone()),
            Request::Density(row.clone()),
            Request::Insert(row),
            Request::Delete(42),
            Request::Shutdown,
        ]
    }

    #[test]
    fn request_roundtrip() {
        for req in all_requests() {
            let bytes = encode_request(&req);
            assert_eq!(decode_request(&bytes).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Pong,
            Response::Stats(StatsBody {
                round: 7,
                rows: 120,
                dims: 8,
                clusters: 3,
                alpha: 1.25,
                queries: 99,
            }),
            Response::Score(ScoreBody {
                round: 3,
                log_pred_empty: -5.5,
                scores: vec![-1.0, -2.5, f64::NEG_INFINITY],
            }),
            Response::Assign(AssignBody {
                round: 3,
                cluster: -1,
                log_weight: -4.0,
            }),
            Response::Density(DensityBody {
                round: 1,
                log_density: -10.25,
            }),
            Response::Queued {
                op: OP_INSERT,
                row: 120,
            },
            Response::ShuttingDown,
            Response::Error("nope".to_string()),
        ];
        for resp in resps {
            let bytes = encode_response(&resp);
            assert_eq!(decode_response(&bytes).unwrap(), resp);
        }
    }

    #[test]
    fn row_bits_roundtrip_through_binmat() {
        let mut m = BinMat::zeros(3, 70);
        m.set(1, 0, true);
        m.set(1, 63, true);
        m.set(1, 64, true);
        m.set(1, 69, true);
        let row = RowBits::from_binmat(&m, 1);
        let back = row.to_binmat();
        for c in 0..70 {
            assert_eq!(back.get(0, c), m.get(1, c), "dim {c}");
        }
        // and through the explicit-ones constructor
        let row2 = RowBits::from_ones(70, &[0, 63, 64, 69]);
        assert_eq!(row, row2);
    }

    #[test]
    fn nonzero_padding_rejected() {
        // d=13 → 2 bytes, top 3 bits of byte 1 are padding
        let mut payload = vec![OP_SCORE];
        payload.extend_from_slice(&13u32.to_le_bytes());
        payload.extend_from_slice(&[0xFF, 0xFF]);
        assert!(decode_request(&payload).is_err());
        // same bitmap with padding cleared decodes fine
        let mut ok = vec![OP_SCORE];
        ok.extend_from_slice(&13u32.to_le_bytes());
        ok.extend_from_slice(&[0xFF, 0x1F]);
        assert!(decode_request(&ok).is_ok());
    }

    #[test]
    fn zero_dim_row_rejected() {
        let mut payload = vec![OP_ASSIGN];
        payload.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_request(&payload).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = encode_request(&Request::Ping);
        bytes.push(0);
        assert!(decode_request(&bytes).is_err());
        let mut resp = encode_response(&Response::Pong);
        resp.push(7);
        assert!(decode_response(&resp).is_err());
    }

    #[test]
    fn frame_len_gate() {
        assert!(validate_frame_len(0).is_err());
        assert!(validate_frame_len(1).is_ok());
        assert!(validate_frame_len(MAX_FRAME).is_ok());
        assert!(validate_frame_len(MAX_FRAME + 1).is_err());
        assert!(validate_frame_len(u32::MAX).is_err());
    }

    #[test]
    fn frame_io_roundtrip() {
        let payload = encode_request(&Request::Delete(9));
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        let mut rd = io::Cursor::new(buf);
        assert_eq!(read_frame(&mut rd).unwrap(), payload);
    }

    #[test]
    fn hostile_length_prefix_rejected_without_allocation() {
        // u32::MAX length prefix followed by nothing: must fail fast
        // with InvalidData from the pre-allocation gate, not OOM or
        // UnexpectedEof from attempting the body read
        let mut rd = io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        let e = read_frame(&mut rd).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
    }
}
