//! # ClusterCluster
//!
//! A production-quality reproduction of *ClusterCluster: Parallel Markov
//! chain Monte Carlo for Dirichlet Process Mixtures* (Lovell, Malmaud,
//! Adams, Mansinghka; 2013) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's insight: a Dirichlet process `DP(α, H)` can be generated as
//! a Dirichlet-weighted mixture of `K` *independent* Dirichlet processes
//! `DP(αμ_k, H)` ("superclusters"). The induced conditional independencies
//! let the expensive per-datum Gibbs sweeps run in parallel — one
//! supercluster per worker — while three cheap centralized updates keep
//! the chain *exactly* invariant for the true DPM posterior:
//!
//! * concentration `α` (Eq. 6, slice sampling),
//! * base-measure hyperparameters `β_d` (griddy Gibbs on pooled stats),
//! * cluster→supercluster assignments `s_j` (Eq. 7, Dirichlet-multinomial).
//!
//! ## Layer map
//!
//! * **Layer 3 (this crate)** — [`sampler`]: the unified sampler core
//!   (`ClusterSet` + `Shard` + the pluggable `TransitionKernel`s);
//!   [`coordinator`]: the map-reduce-shaped parallel sampler;
//!   [`serial`]: the single-shard baseline; [`serve`]: the long-running
//!   query service over published round snapshots; [`mapreduce`]: the
//!   in-process map-reduce runtime (persistent worker pool) with a
//!   communication cost model; plus every substrate ([`rng`],
//!   [`special`], [`data`], [`linalg`], [`metrics`], [`bench`],
//!   [`testing`], [`cli`], [`util`]).
//! * **Layer 2/1 (build-time Python)** — `python/compile/`: the JAX model
//!   graph calling a Pallas kernel, AOT-lowered to HLO text artifacts.
//! * **Runtime bridge** — [`runtime`]: loads `artifacts/*.hlo.txt` through
//!   the PJRT CPU client (`xla` crate) and serves batched scoring on the
//!   Rust hot path. Python never runs at sampling time.
//!
//! ## Granularity and kernel mixing
//!
//! The supercluster weights μ are runtime-controllable
//! ([`coordinator::MuMode`]: uniform, size-proportional, adaptive —
//! every mode exactness-preserving, DESIGN.md §6), and different shards
//! may run different transition kernels within one exact chain
//! ([`sampler::KernelAssignment`], CLI
//! `--local-kernel gibbs,split_merge:walker`). Three kernel families
//! ship: collapsed Gibbs, Walker slice, and the Jain–Neal split–merge
//! composites ([`sampler::SplitMerge`]; selection guide in DESIGN.md §7).
//!
//! ## Component likelihoods
//!
//! The sampler core is likelihood-generic over [`model::ComponentModel`]
//! (DESIGN.md §11): collapsed Beta–Bernoulli on bit-packed binary data,
//! collapsed diagonal Gaussian (Normal–Inverse-Gamma) on real data, and
//! Dirichlet–multinomial on categorical data, selected at the CLI with
//! `--model bernoulli|gaussian|categorical` ([`model::ModelSpec`]). Both
//! entry points and every kernel run against [`model::Model`] through
//! one [`data::DataRef`] view; the 203-partition enumeration gates hold
//! for all three likelihoods.
//!
//! ## Quickstart
//!
//! ```no_run
//! use clustercluster::prelude::*;
//!
//! let mut rng = Pcg64::seed_from(7);
//! let data = SyntheticConfig { n: 2_000, d: 16, clusters: 8, beta: 0.2, seed: 7 }
//!     .generate();
//! let cfg = CoordinatorConfig { workers: 4, ..Default::default() };
//! let mut coord = Coordinator::new(&data.train, cfg, &mut rng);
//! for _ in 0..20 { coord.step(&mut rng); }
//! println!("clusters: {}", coord.num_clusters());
//! ```

#![warn(missing_docs)]

/// Compiles the README's Rust examples as doc-tests (`cargo test
/// --doc`), so the quickstart in `README.md` can never rot against the
/// real API. Exists only under `cfg(doctest)`.
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub struct ReadmeDoctests;

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod mapreduce;
pub mod metrics;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod serial;
pub mod serve;
pub mod special;
pub mod supercluster;
pub mod testing;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::{Coordinator, CoordinatorConfig, MuMode, ShardRoundStat};
    pub use crate::data::synthetic::{Dataset, SyntheticConfig};
    pub use crate::metrics::{ShardTrace, ShardTraceRow};
    pub use crate::model::{BetaBernoulli, ClusterStats, ComponentModel, Model, ModelSpec};
    pub use crate::rng::Pcg64;
    pub use crate::runtime::{FallbackScorer, Scorer, ScorerKind};
    pub use crate::sampler::{
        ClusterSet, KernelAssignment, KernelKind, ScoreMode, Shard, SplitMerge,
        TransitionKernel,
    };
    pub use crate::serial::SerialGibbs;
}
