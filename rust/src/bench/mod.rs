//! Criterion-less bench harness (criterion is not in the offline crate
//! universe): warmup + timed iterations with mean/p50/p95 reporting, and
//! a figure emitter that prints the paper-style rows and mirrors them to
//! JSON under `bench_results/`.

use crate::util::json::Json;
use crate::util::{mean, percentile};
use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// case label
    pub name: String,
    /// measured iterations (after warm-up)
    pub iters: usize,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// median seconds per iteration
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration
    pub p95_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        p50_s: percentile(&samples, 0.5),
        p95_s: percentile(&samples, 0.95),
    };
    println!(
        "bench {:<40} mean {:>10.6}s  p50 {:>10.6}s  p95 {:>10.6}s  ({} iters)",
        r.name, r.mean_s, r.p50_s, r.p95_s, iters
    );
    r
}

/// Collects the rows/series that regenerate one paper figure and writes
/// them to `bench_results/<figure>.json` + stdout.
pub struct FigureEmitter {
    figure: String,
    rows: Vec<Json>,
}

impl FigureEmitter {
    /// Emitter for one figure; prints the banner immediately.
    pub fn new(figure: &str) -> Self {
        println!("\n=== {figure} ===");
        FigureEmitter {
            figure: figure.to_string(),
            rows: Vec::new(),
        }
    }

    /// Add one row: prints `key=value` pairs and records them.
    pub fn row(&mut self, pairs: &[(&str, f64)]) {
        let mut obj = Json::obj();
        let mut line = String::new();
        for (k, v) in pairs {
            obj.set(k, Json::num(*v));
            line.push_str(&format!("{k}={v:.6}  "));
        }
        println!("  {line}");
        self.rows.push(obj);
    }

    /// Add a labeled series (e.g. one convergence curve).
    pub fn series(&mut self, label: &str, xs: &[f64], ys: &[f64]) {
        let mut obj = Json::obj();
        obj.set("label", Json::str(label));
        obj.set("x", Json::arr_nums(xs));
        obj.set("y", Json::arr_nums(ys));
        println!(
            "  series {label}: {} points, x∈[{:.3},{:.3}], y last {:.4}",
            xs.len(),
            xs.first().copied().unwrap_or(0.0),
            xs.last().copied().unwrap_or(0.0),
            ys.last().copied().unwrap_or(0.0)
        );
        self.rows.push(obj);
    }

    /// Free-form note attached to the figure output.
    pub fn note(&mut self, text: &str) {
        println!("  # {text}");
        let mut obj = Json::obj();
        obj.set("note", Json::str(text));
        self.rows.push(obj);
    }

    /// Write `bench_results/<figure>.json`.
    pub fn finish(self) {
        let mut doc = Json::obj();
        doc.set("figure", Json::str(&self.figure));
        doc.set("rows", Json::Arr(self.rows));
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.json", self.figure));
            if let Err(e) = std::fs::write(&path, doc.to_string()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("  -> {}", path.display());
            }
        }
    }
}

/// Scaling helper: figures accept `--full` for paper-scale runs.
pub fn is_full_scale() -> bool {
    std::env::args().any(|a| a == "--full")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 10, || {
            std::hint::black_box(42);
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn figure_emitter_writes_json() {
        let dir = std::path::Path::new("bench_results");
        let mut f = FigureEmitter::new("test_fig");
        f.row(&[("k", 2.0), ("speedup", 1.9)]);
        f.series("curve", &[0.0, 1.0], &[-5.0, -4.0]);
        f.finish();
        let text = std::fs::read_to_string(dir.join("test_fig.json")).unwrap();
        let j = crate::util::json::parse(&text).unwrap();
        assert_eq!(j.get("figure").unwrap().as_str().unwrap(), "test_fig");
        let _ = std::fs::remove_file(dir.join("test_fig.json"));
    }
}
