//! Criterion-less bench harness (criterion is not in the offline crate
//! universe): warmup + timed iterations with mean/p50/p95 reporting, and
//! a figure emitter that prints the paper-style rows and mirrors them to
//! JSON under `bench_results/`.

use crate::util::json::Json;
use crate::util::{mean, percentile};
use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// case label
    pub name: String,
    /// measured iterations (after warm-up)
    pub iters: usize,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// median seconds per iteration
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration
    pub p95_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        p50_s: percentile(&samples, 0.5),
        p95_s: percentile(&samples, 0.95),
    };
    println!(
        "bench {:<40} mean {:>10.6}s  p50 {:>10.6}s  p95 {:>10.6}s  ({} iters)",
        r.name, r.mean_s, r.p50_s, r.p95_s, iters
    );
    r
}

/// Collects the rows/series that regenerate one paper figure and writes
/// them to `bench_results/<figure>.json` + stdout.
pub struct FigureEmitter {
    figure: String,
    rows: Vec<Json>,
}

impl FigureEmitter {
    /// Emitter for one figure; prints the banner immediately.
    pub fn new(figure: &str) -> Self {
        println!("\n=== {figure} ===");
        FigureEmitter {
            figure: figure.to_string(),
            rows: Vec::new(),
        }
    }

    /// Add one row: prints `key=value` pairs and records them.
    pub fn row(&mut self, pairs: &[(&str, f64)]) {
        let mut obj = Json::obj();
        let mut line = String::new();
        for (k, v) in pairs {
            obj.set(k, Json::num(*v));
            line.push_str(&format!("{k}={v:.6}  "));
        }
        println!("  {line}");
        self.rows.push(obj);
    }

    /// Add a labeled series (e.g. one convergence curve).
    pub fn series(&mut self, label: &str, xs: &[f64], ys: &[f64]) {
        let mut obj = Json::obj();
        obj.set("label", Json::str(label));
        obj.set("x", Json::arr_nums(xs));
        obj.set("y", Json::arr_nums(ys));
        println!(
            "  series {label}: {} points, x∈[{:.3},{:.3}], y last {:.4}",
            xs.len(),
            xs.first().copied().unwrap_or(0.0),
            xs.last().copied().unwrap_or(0.0),
            ys.last().copied().unwrap_or(0.0)
        );
        self.rows.push(obj);
    }

    /// Free-form note attached to the figure output.
    pub fn note(&mut self, text: &str) {
        println!("  # {text}");
        let mut obj = Json::obj();
        obj.set("note", Json::str(text));
        self.rows.push(obj);
    }

    /// Write `bench_results/<figure>.json`.
    pub fn finish(self) {
        let mut doc = Json::obj();
        doc.set("figure", Json::str(&self.figure));
        doc.set("rows", Json::Arr(self.rows));
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.json", self.figure));
            if let Err(e) = std::fs::write(&path, doc.to_string()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("  -> {}", path.display());
            }
        }
    }
}

/// Scaling helper: figures accept `--full` for paper-scale runs.
pub fn is_full_scale() -> bool {
    std::env::args().any(|a| a == "--full")
}

/// CI helper: figures accept `--smoke` (or `CC_BENCH_SMOKE=1`) for
/// reduced-scale runs that still exercise every measured case — what
/// the per-push bench job runs before the regression gate.
pub fn is_smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("CC_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Whether `--update-baseline` was passed: the hot-path harness then
/// ALSO rewrites the committed baseline file at the repo root
/// (`BENCH_hotpath.json`) with the fresh numbers.
pub fn update_baseline() -> bool {
    std::env::args().any(|a| a == "--update-baseline")
}

/// One measured case of the hot-path perf baseline matrix
/// (kernel × cluster count × density × scoring mode).
#[derive(Debug, Clone)]
pub struct BaselineCase {
    /// transition-kernel name (`collapsed-gibbs` / `walker-slice`)
    pub kernel: String,
    /// planted live-cluster scale of the workload
    pub clusters: usize,
    /// Bernoulli bit density of the synthetic rows
    pub density: f64,
    /// scoring mode (`scalar` | `batched` | `batched-eager`)
    pub mode: String,
    /// measured sweep throughput (data rows per second)
    pub rows_per_s: f64,
}

impl BaselineCase {
    /// The (kernel, clusters, density, mode) identity key the regression
    /// gate matches cases on.
    pub fn key(&self) -> String {
        format!(
            "{}|J{}|p{:.2}|{}",
            self.kernel, self.clusters, self.density, self.mode
        )
    }
}

/// Collects the hot-path perf-baseline matrix and writes it as the
/// `BENCH_hotpath.json` schema: `cases` keyed by
/// (kernel, clusters, density, mode) with `rows_per_s`, plus free-form
/// `derived` ratios (e.g. incremental-vs-eager speedups). CI re-runs
/// the harness in `--smoke` mode on every push and fails on a > 20 %
/// sweep-throughput regression against the committed file
/// (`scripts/check_bench_regression.py`).
pub struct BaselineEmitter {
    name: String,
    provenance: String,
    cases: Vec<BaselineCase>,
    derived: Vec<(String, f64)>,
}

impl BaselineEmitter {
    /// Emitter named `name` with a provenance note (host/scale info).
    pub fn new(name: &str, provenance: &str) -> Self {
        BaselineEmitter {
            name: name.to_string(),
            provenance: provenance.to_string(),
            cases: Vec::new(),
            derived: Vec::new(),
        }
    }

    /// Record (and echo) one measured case.
    pub fn case(&mut self, c: BaselineCase) {
        println!(
            "  baseline {:<46} {:>12.0} rows/s",
            c.key(),
            c.rows_per_s
        );
        self.cases.push(c);
    }

    /// Record (and echo) a derived ratio (speedups etc.).
    pub fn derived(&mut self, key: &str, v: f64) {
        println!("  baseline derived {key} = {v:.3}");
        self.derived.push((key.to_string(), v));
    }

    /// Throughput of a recorded case by key (for in-harness ratios).
    pub fn rows_per_s(&self, key: &str) -> Option<f64> {
        self.cases.iter().find(|c| c.key() == key).map(|c| c.rows_per_s)
    }

    /// Serialize to the `BENCH_hotpath.json` document.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("figure", Json::str(&self.name));
        doc.set("schema", Json::num(1.0));
        doc.set("provenance", Json::str(&self.provenance));
        let mut cases = Vec::new();
        for c in &self.cases {
            let mut o = Json::obj();
            o.set("kernel", Json::str(&c.kernel));
            o.set("clusters", Json::num(c.clusters as f64));
            o.set("density", Json::num(c.density));
            o.set("mode", Json::str(&c.mode));
            o.set("rows_per_s", Json::num(c.rows_per_s));
            cases.push(o);
        }
        doc.set("cases", Json::Arr(cases));
        let mut derived = Json::obj();
        for (k, v) in &self.derived {
            derived.set(k, Json::num(*v));
        }
        doc.set("derived", derived);
        doc
    }

    /// Write the document to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())?;
        println!("  -> {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 10, || {
            std::hint::black_box(42);
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn baseline_emitter_roundtrips_schema() {
        let mut b = BaselineEmitter::new("hotpath_baseline", "unit-test");
        b.case(BaselineCase {
            kernel: "collapsed-gibbs".into(),
            clusters: 16,
            density: 0.5,
            mode: "batched".into(),
            rows_per_s: 1234.5,
        });
        b.derived("batched_vs_eager", 1.7);
        assert_eq!(
            b.rows_per_s("collapsed-gibbs|J16|p0.50|batched"),
            Some(1234.5)
        );
        let dir = std::env::temp_dir().join("cc_bench_baseline_test");
        let path = dir.join("BENCH_test.json");
        b.write(&path).unwrap();
        let j = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            j.get("figure").unwrap().as_str().unwrap(),
            "hotpath_baseline"
        );
        let cases = j.get("cases").unwrap();
        let c0 = cases.index(0).unwrap();
        assert_eq!(c0.get("mode").unwrap().as_str().unwrap(), "batched");
        assert!(
            (c0.get("rows_per_s").unwrap().as_f64().unwrap() - 1234.5).abs() < 1e-9
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn figure_emitter_writes_json() {
        let dir = std::path::Path::new("bench_results");
        let mut f = FigureEmitter::new("test_fig");
        f.row(&[("k", 2.0), ("speedup", 1.9)]);
        f.series("curve", &[0.0, 1.0], &[-5.0, -4.0]);
        f.finish();
        let text = std::fs::read_to_string(dir.join("test_fig.json")).unwrap();
        let j = crate::util::json::parse(&text).unwrap();
        assert_eq!(j.get("figure").unwrap().as_str().unwrap(), "test_fig");
        let _ = std::fs::remove_file(dir.join("test_fig.json"));
    }
}
