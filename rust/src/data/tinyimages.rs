//! Tiny-Images substitute (§6, Figs. 9–10): the real 80M-Tiny-Images
//! subset is unavailable offline, so we synthesize a corpus with the same
//! relevant structure — visually-coherent clusters of small "images"
//! (shared low-rank templates + pixel noise) — and run the paper's exact
//! feature pipeline on it: randomized PCA on a calibration subset, then
//! per-component **median binarization** into D binary features.
//!
//! What matters to the downstream experiment is (a) binary vectors,
//! (b) correlated low-rank cluster structure, (c) the median threshold
//! making every feature marginally ~Bernoulli(1/2) — all preserved here.

use super::binmat::BinMat;
use super::rpca::{rpca, Rpca};
use crate::linalg::{column_medians, Mat};
use crate::rng::{normal, Pcg64};

/// Configuration for the synthetic image corpus + feature pipeline.
#[derive(Debug, Clone, Copy)]
pub struct TinyImagesConfig {
    /// number of images (paper: 1MM; scaled default in benches)
    pub n: usize,
    /// image side in pixels (raw dim = side², paper-equivalent 32×32×3)
    pub side: usize,
    /// number of latent visual categories in the corpus
    pub categories: usize,
    /// binary feature dimensionality = #principal components (paper: 256)
    pub features: usize,
    /// rows used for the PCA calibration pass (paper: 100k of 1MM)
    pub calibration_rows: usize,
    /// pixel noise stddev relative to template contrast
    pub noise: f64,
    /// master RNG seed
    pub seed: u64,
}

impl Default for TinyImagesConfig {
    fn default() -> Self {
        TinyImagesConfig {
            n: 10_000,
            side: 24, // 576 raw dims ≥ 256 features
            categories: 100,
            features: 256,
            calibration_rows: 2_000,
            noise: 0.6,
            seed: 0,
        }
    }
}

/// The featurized corpus.
#[derive(Debug, Clone)]
pub struct TinyImages {
    /// binarized features, n × features
    pub features: BinMat,
    /// latent category of each image (for coherence evaluation, Fig. 10)
    pub category: Vec<u32>,
    /// the fitted PCA (kept for inspecting the pipeline)
    pub pca: Rpca,
    /// per-component median thresholds
    pub medians: Vec<f64>,
    /// the configuration that generated this corpus
    pub config: TinyImagesConfig,
}

/// Generate one raw image row for category `cat` given templates.
fn raw_image(
    templates: &Mat,
    cat: usize,
    noise: f64,
    rng: &mut Pcg64,
    out: &mut [f64],
) {
    let t = templates.row(cat);
    for (i, o) in out.iter_mut().enumerate() {
        *o = t[i] + noise * normal(rng);
    }
}

/// Smooth random template per category: sum of a few random 2-D cosine
/// bumps — gives images spatial correlation like natural tiny images.
fn make_templates(cfg: &TinyImagesConfig, rng: &mut Pcg64) -> Mat {
    let d = cfg.side * cfg.side;
    let mut t = Mat::zeros(cfg.categories, d);
    for c in 0..cfg.categories {
        // 3 cosine bumps with random frequency/phase/amplitude
        for _ in 0..3 {
            let fx = 1.0 + 3.0 * rng.next_f64();
            let fy = 1.0 + 3.0 * rng.next_f64();
            let px = std::f64::consts::TAU * rng.next_f64();
            let py = std::f64::consts::TAU * rng.next_f64();
            let amp = 0.5 + rng.next_f64();
            for y in 0..cfg.side {
                for x in 0..cfg.side {
                    let v = amp
                        * (fx * x as f64 / cfg.side as f64 * std::f64::consts::TAU + px).cos()
                        * (fy * y as f64 / cfg.side as f64 * std::f64::consts::TAU + py).cos();
                    *t.at_mut(c, y * cfg.side + x) += v;
                }
            }
        }
    }
    t
}

/// Run the full pipeline: synthesize corpus → rPCA on a calibration
/// subset → project everything → median-binarize.
pub fn generate(cfg: &TinyImagesConfig) -> TinyImages {
    assert!(cfg.features <= cfg.side * cfg.side, "features exceed raw dims");
    assert!(cfg.calibration_rows >= 2 * cfg.features, "calibration too small for PCA");
    let d_raw = cfg.side * cfg.side;
    let mut rng = Pcg64::new(cfg.seed, 0x714);
    let templates = make_templates(cfg, &mut rng);

    // latent categories (Zipf-ish sizes: some visual themes are common)
    let mut cat_weights: Vec<f64> = (1..=cfg.categories).map(|i| 1.0 / i as f64).collect();
    let total: f64 = cat_weights.iter().sum();
    cat_weights.iter_mut().for_each(|w| *w /= total);
    let category: Vec<u32> = (0..cfg.n)
        .map(|_| crate::rng::categorical(&mut rng, &cat_weights) as u32)
        .collect();

    // calibration pass (paper: rPCA on 100k of the 1MM rows)
    let ncal = cfg.calibration_rows.min(cfg.n);
    let mut cal = Mat::zeros(ncal, d_raw);
    for r in 0..ncal {
        let row = category[r] as usize;
        let mut buf = vec![0.0; d_raw];
        // per-row RNG stream so the same pixels can be re-generated in the
        // median pass and the full pass without storing the raw corpus
        let mut row_rng = Pcg64::new(cfg.seed ^ (r as u64).wrapping_mul(0x9e3779b97f4a7c15), 0x1111);
        raw_image(&templates, row, cfg.noise, &mut row_rng, &mut buf);
        cal.data[r * d_raw..(r + 1) * d_raw].copy_from_slice(&buf);
    }
    let oversample = 10.min(d_raw - cfg.features);
    let pca = rpca(&mut cal, cfg.features, oversample, 2, cfg.seed ^ 0xabc);

    // project calibration rows to get the medians (paper: component-wise
    // median over the calibration subset)
    // (cal was centred in place by rpca; re-generate scores via project
    // on a fresh copy for clarity)
    let mut scores_cal = Mat::zeros(ncal, cfg.features);
    {
        let mut buf = vec![0.0; d_raw];
        for r in 0..ncal {
            let mut row_rng =
                Pcg64::new(cfg.seed ^ (r as u64).wrapping_mul(0x9e3779b97f4a7c15), 0x1111);
            raw_image(&templates, category[r] as usize, cfg.noise, &mut row_rng, &mut buf);
            for c in 0..cfg.features {
                let mut acc = 0.0;
                for dim in 0..d_raw {
                    acc += (buf[dim] - pca.means[dim]) * pca.components.at(dim, c);
                }
                *scores_cal.at_mut(r, c) = acc;
            }
        }
    }
    let medians = column_medians(&scores_cal);

    // full pass: stream every image through project + threshold
    let mut features = BinMat::zeros(cfg.n, cfg.features);
    let mut buf = vec![0.0; d_raw];
    for r in 0..cfg.n {
        let mut row_rng =
            Pcg64::new(cfg.seed ^ (r as u64).wrapping_mul(0x9e3779b97f4a7c15), 0x1111);
        raw_image(&templates, category[r] as usize, cfg.noise, &mut row_rng, &mut buf);
        for c in 0..cfg.features {
            let mut acc = 0.0;
            for dim in 0..d_raw {
                acc += (buf[dim] - pca.means[dim]) * pca.components.at(dim, c);
            }
            if acc > medians[c] {
                features.set(r, c, true);
            }
        }
    }

    TinyImages {
        features,
        category,
        pca,
        medians,
        config: *cfg,
    }
}

/// Mean within-group Hamming distance over feature vectors — the Fig. 10
/// coherence metric (compared against random row pairs).
pub fn mean_hamming(features: &BinMat, rows: &[usize]) -> f64 {
    if rows.len() < 2 {
        return 0.0;
    }
    let mut acc = 0.0;
    let mut pairs = 0u64;
    for i in 0..rows.len().min(64) {
        for j in (i + 1)..rows.len().min(64) {
            let a = features.row_words(rows[i]);
            let b = features.row_words(rows[j]);
            let h: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
            acc += h as f64;
            pairs += 1;
        }
    }
    acc / pairs as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TinyImagesConfig {
        TinyImagesConfig {
            n: 400,
            side: 12,   // 144 raw dims
            categories: 8,
            features: 32,
            calibration_rows: 200,
            noise: 0.4,
            seed: 1,
        }
    }

    #[test]
    fn pipeline_shapes_and_determinism() {
        let a = generate(&small_cfg());
        let b = generate(&small_cfg());
        assert_eq!(a.features, b.features);
        assert_eq!(a.features.rows(), 400);
        assert_eq!(a.features.dims(), 32);
        assert_eq!(a.category.len(), 400);
        assert_eq!(a.medians.len(), 32);
    }

    #[test]
    fn median_threshold_balances_features() {
        // each feature is thresholded at its median ⇒ roughly half ones
        let t = generate(&small_cfg());
        for c in 0..t.features.dims() {
            let ones: usize = (0..t.features.rows())
                .filter(|&r| t.features.get(r, c))
                .count();
            let frac = ones as f64 / t.features.rows() as f64;
            assert!(
                (0.25..=0.75).contains(&frac),
                "feature {c} density {frac}"
            );
        }
    }

    #[test]
    fn same_category_rows_are_more_coherent() {
        let t = generate(&small_cfg());
        // rows of the most common category
        let cat0: Vec<usize> = (0..t.features.rows())
            .filter(|&r| t.category[r] == 0)
            .take(32)
            .collect();
        assert!(cat0.len() >= 8, "need enough rows in category 0");
        let all: Vec<usize> = (0..t.features.rows()).take(64).collect();
        let within = mean_hamming(&t.features, &cat0);
        let random = mean_hamming(&t.features, &all);
        assert!(
            within < random,
            "within-category Hamming {within} should beat random {random}"
        );
    }
}
