//! Non-binary data containers and the [`DataRef`] view that makes the
//! sampler stack likelihood-generic.
//!
//! * [`RealMat`] — dense row-major `f64` matrix for the collapsed
//!   Gaussian (diagonal Normal–Inverse-Gamma) likelihood.
//! * [`CatMat`] — categorical codes with per-dim cardinalities, stored
//!   as a one-hot [`BinMat`] so categorical sufficient statistics and
//!   packed-table scoring ride the existing bit-sparse fast path
//!   unchanged (one set bit per dim per row).
//! * [`DataRef`] — a `Copy` borrowed view over any of the three
//!   containers. Kernels, shards and cluster stores take `DataRef` (or
//!   `impl Into<DataRef>`), so the Bernoulli call sites that pass
//!   `&BinMat` compile unchanged while the same code path serves
//!   Gaussian and categorical data.

use super::binmat::BinMat;

/// Dense row-major real-valued matrix (N rows × D dims).
#[derive(Debug, Clone, PartialEq)]
pub struct RealMat {
    n: usize,
    d: usize,
    vals: Vec<f64>,
}

impl RealMat {
    /// All-zeros matrix of `n` rows × `d` real dims.
    pub fn zeros(n: usize, d: usize) -> RealMat {
        RealMat {
            n,
            d,
            vals: vec![0.0; n * d],
        }
    }

    /// Build from a dense row-major value buffer.
    pub fn from_dense(n: usize, d: usize, vals: Vec<f64>) -> RealMat {
        assert_eq!(vals.len(), n * d, "dense buffer must be n*d");
        RealMat { n, d, vals }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Number of real dimensions.
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Value at (row, dim).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.n && c < self.d);
        self.vals[r * self.d + c]
    }

    /// Set the value at (row, dim).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.n && c < self.d);
        self.vals[r * self.d + c] = v;
    }

    /// Row `r` as a contiguous slice (the per-datum hot-path view).
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.vals[r * self.d..(r + 1) * self.d]
    }

    /// Raw values (for IO).
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Copy a subset of rows into a new matrix (supercluster shards).
    pub fn select_rows(&self, rows: &[usize]) -> RealMat {
        let mut out = RealMat::zeros(rows.len(), self.d);
        for (i, &r) in rows.iter().enumerate() {
            out.vals[i * self.d..(i + 1) * self.d].copy_from_slice(self.row(r));
        }
        out
    }
}

/// Categorical data: N rows × D dims, dim `d` taking values in
/// `0..cards[d]`. Stored one-hot: column block `offsets[d]..offsets[d+1]`
/// of the inner [`BinMat`] holds the indicator of dim `d`, so every row
/// has exactly D set bits and the bit-sparse scoring path applies as-is.
#[derive(Debug, Clone, PartialEq)]
pub struct CatMat {
    cards: Vec<u32>,
    /// prefix sums of `cards`; `offsets[d]` is the first one-hot column
    /// of dim `d`, `offsets[D]` the total one-hot width W = Σ V_d
    offsets: Vec<u32>,
    onehot: BinMat,
}

impl CatMat {
    /// Build from per-row category codes (row-major, `codes[r*D + d] <
    /// cards[d]`).
    pub fn from_codes(n: usize, cards: &[u32], codes: &[u32]) -> CatMat {
        let d = cards.len();
        assert!(d >= 1, "need at least one categorical dim");
        assert!(cards.iter().all(|&v| v >= 2), "cardinalities must be >= 2");
        assert_eq!(codes.len(), n * d, "codes must be n*D");
        let mut offsets = Vec::with_capacity(d + 1);
        let mut acc = 0u32;
        for &v in cards {
            offsets.push(acc);
            acc += v;
        }
        offsets.push(acc);
        let mut onehot = BinMat::zeros(n, acc as usize);
        for r in 0..n {
            for (dim, &v) in cards.iter().enumerate() {
                let code = codes[r * d + dim];
                assert!(code < v, "code {code} out of range for dim {dim} (V={v})");
                onehot.set(r, (offsets[dim] + code) as usize, true);
            }
        }
        CatMat {
            cards: cards.to_vec(),
            offsets,
            onehot,
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.onehot.rows()
    }

    /// Number of categorical dimensions D (not the one-hot width).
    pub fn dims(&self) -> usize {
        self.cards.len()
    }

    /// Per-dim cardinalities V_d.
    pub fn cards(&self) -> &[u32] {
        &self.cards
    }

    /// One-hot column offsets (len D+1; `offsets[D]` = width).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Total one-hot width W = Σ V_d — the sufficient-statistic width.
    pub fn width(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    /// Category code of (row, dim).
    pub fn get(&self, r: usize, dim: usize) -> u32 {
        let lo = self.offsets[dim];
        let hi = self.offsets[dim + 1];
        for c in lo..hi {
            if self.onehot.get(r, c as usize) {
                return c - lo;
            }
        }
        unreachable!("CatMat row {r} has no set bit in dim {dim}");
    }

    /// The one-hot view (what sufficient stats and packed tables see).
    pub fn onehot(&self) -> &BinMat {
        &self.onehot
    }

    /// Copy a subset of rows into a new matrix (supercluster shards).
    pub fn select_rows(&self, rows: &[usize]) -> CatMat {
        CatMat {
            cards: self.cards.clone(),
            offsets: self.offsets.clone(),
            onehot: self.onehot.select_rows(rows),
        }
    }
}

/// Borrowed view over any supported data container. `Copy`, so it is
/// passed by value through the kernel and scoring layers.
///
/// The three accessor groups encode what each likelihood needs:
/// [`DataRef::bits`] yields the bit matrix for the sparse scoring path
/// (native bits for Bernoulli, one-hot bits for categorical),
/// [`DataRef::real`] the dense rows for the Gaussian path.
#[derive(Debug, Clone, Copy)]
pub enum DataRef<'a> {
    /// Binary data (Beta–Bernoulli likelihood).
    Binary(&'a BinMat),
    /// Categorical data (Dirichlet–multinomial likelihood).
    Categorical(&'a CatMat),
    /// Real-valued data (collapsed diagonal Gaussian likelihood).
    Real(&'a RealMat),
}

impl<'a> DataRef<'a> {
    /// Number of rows.
    pub fn rows(self) -> usize {
        match self {
            DataRef::Binary(m) => m.rows(),
            DataRef::Categorical(m) => m.rows(),
            DataRef::Real(m) => m.rows(),
        }
    }

    /// Sufficient-statistic width: the length of the per-cluster count /
    /// moment vectors (`D` binary, one-hot `W = Σ V_d` categorical, `D`
    /// real).
    pub fn dims(self) -> usize {
        match self {
            DataRef::Binary(m) => m.dims(),
            DataRef::Categorical(m) => m.width(),
            DataRef::Real(m) => m.dims(),
        }
    }

    /// Packed-table rows per cluster column: `D` binary, `W` categorical,
    /// `2D` real (a location plane and a scale plane — see
    /// `model::DiagGaussian`). Keyed on the data kind alone so shard
    /// construction needs no model handle.
    pub fn table_rows(self) -> usize {
        match self {
            DataRef::Binary(m) => m.dims(),
            DataRef::Categorical(m) => m.width(),
            DataRef::Real(m) => 2 * m.dims(),
        }
    }

    /// The bit matrix backing the sparse scoring path, if this data kind
    /// has one (binary: the matrix itself; categorical: the one-hot
    /// expansion; real: `None`).
    pub fn bits(self) -> Option<&'a BinMat> {
        match self {
            DataRef::Binary(m) => Some(m),
            DataRef::Categorical(m) => Some(m.onehot()),
            DataRef::Real(_) => None,
        }
    }

    /// The dense real matrix, if this is real-valued data.
    pub fn real(self) -> Option<&'a RealMat> {
        match self {
            DataRef::Real(m) => Some(m),
            _ => None,
        }
    }

    /// Short human-readable kind name (error messages, CLI banners).
    pub fn kind_name(self) -> &'static str {
        match self {
            DataRef::Binary(_) => "binary",
            DataRef::Categorical(_) => "categorical",
            DataRef::Real(_) => "real",
        }
    }
}

impl<'a> From<&'a BinMat> for DataRef<'a> {
    fn from(m: &'a BinMat) -> Self {
        DataRef::Binary(m)
    }
}

impl<'a> From<&'a CatMat> for DataRef<'a> {
    fn from(m: &'a CatMat) -> Self {
        DataRef::Categorical(m)
    }
}

impl<'a> From<&'a RealMat> for DataRef<'a> {
    fn from(m: &'a RealMat) -> Self {
        DataRef::Real(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realmat_rows_and_select() {
        let m = RealMat::from_dense(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.get(2, 1), 6.0);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn catmat_onehot_layout_and_roundtrip() {
        // D=2 dims with cards [3, 2]; W = 5
        let codes = [2u32, 0, 1, 1, 0, 1];
        let m = CatMat::from_codes(3, &[3, 2], &codes);
        assert_eq!(m.width(), 5);
        assert_eq!(m.offsets(), &[0, 3, 5]);
        for r in 0..3 {
            for d in 0..2 {
                assert_eq!(m.get(r, d), codes[r * 2 + d], "({r},{d})");
            }
            // exactly one bit per dim
            assert_eq!(m.onehot().row_popcount(r), 2);
        }
        let s = m.select_rows(&[1]);
        assert_eq!(s.get(0, 0), 1);
        assert_eq!(s.get(0, 1), 1);
    }

    #[test]
    fn dataref_widths_per_kind() {
        let b = BinMat::zeros(4, 7);
        let c = CatMat::from_codes(2, &[3, 2], &[0, 0, 1, 1]);
        let r = RealMat::zeros(5, 3);
        let db: DataRef = (&b).into();
        let dc: DataRef = (&c).into();
        let dr: DataRef = (&r).into();
        assert_eq!((db.rows(), db.dims(), db.table_rows()), (4, 7, 7));
        assert_eq!((dc.rows(), dc.dims(), dc.table_rows()), (2, 5, 5));
        assert_eq!((dr.rows(), dr.dims(), dr.table_rows()), (5, 3, 6));
        assert!(db.bits().is_some() && dc.bits().is_some() && dr.bits().is_none());
        assert!(dr.real().is_some() && db.real().is_none());
        assert_eq!(dc.bits().unwrap().dims(), 5);
    }
}
