//! Data substrate: the bit-packed binary matrix the samplers operate on,
//! real-valued and categorical containers behind the likelihood-generic
//! [`DataRef`] view, the paper's synthetic balanced Beta–Bernoulli
//! mixture generator (§6) plus Gaussian/categorical counterparts, the
//! Tiny-Images substitute pipeline (synthetic corpus → randomized PCA →
//! per-component median binarization, §6), and dataset (de)serialization.

pub mod binmat;
pub mod containers;
pub mod io;
pub mod rpca;
pub mod synthetic;
pub mod tinyimages;

pub use binmat::BinMat;
pub use containers::{CatMat, DataRef, RealMat};
pub use synthetic::{
    Dataset, SyntheticCategoricalConfig, SyntheticConfig, SyntheticGaussianConfig,
};
