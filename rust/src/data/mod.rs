//! Data substrate: the bit-packed binary matrix the samplers operate on,
//! the paper's synthetic balanced Beta–Bernoulli mixture generator (§6),
//! the Tiny-Images substitute pipeline (synthetic corpus → randomized PCA
//! → per-component median binarization, §6), and dataset (de)serialization.

pub mod binmat;
pub mod io;
pub mod rpca;
pub mod synthetic;
pub mod tinyimages;

pub use binmat::BinMat;
pub use synthetic::{Dataset, SyntheticConfig};
