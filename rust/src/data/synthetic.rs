//! The paper's synthetic workload (§6): balanced finite Bernoulli mixtures.
//!
//! "Each mixture component θ_j was parameterized by a set of coin weights
//! drawn from a Beta(β_d, β_d) distribution ... The binary data were
//! Bernoulli draws based on the weight parameters of their respective
//! clusters." Datasets range 200k–1MM rows, 128–2048 clusters, 256 dims;
//! this generator is parameterized over the whole grid (scaled defaults
//! in the benches, full-scale behind flags).

use super::binmat::BinMat;
use super::containers::{CatMat, RealMat};
use crate::rng::{beta, categorical, dirichlet, normal, Pcg64};

/// Configuration for a balanced synthetic mixture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticConfig {
    /// total number of training rows (split evenly over clusters)
    pub n: usize,
    /// binary dimensionality (paper: 256)
    pub d: usize,
    /// number of true mixture components
    pub clusters: usize,
    /// Beta(β, β) hyperparameter for the coin weights (paper's β_d;
    /// small β ⇒ near-deterministic coins ⇒ well-separated clusters)
    pub beta: f64,
    /// master RNG seed
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            n: 10_000,
            d: 256,
            clusters: 128,
            beta: 0.1,
            seed: 0,
        }
    }
}

/// A generated dataset: train/test splits, ground-truth assignments and
/// component coin weights, and the generator's entropy estimate.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// training rows
    pub train: BinMat,
    /// held-out test rows
    pub test: BinMat,
    /// ground-truth cluster of each train row
    pub train_z: Vec<u32>,
    /// ground-truth cluster of each test row
    pub test_z: Vec<u32>,
    /// true coin weights, [clusters][d]
    pub weights: Vec<Vec<f64>>,
    /// the configuration that generated this dataset
    pub config: SyntheticConfig,
}

impl SyntheticConfig {
    /// Generate with a 10% held-out test split (paper evaluates test-set
    /// predictive log-likelihood).
    pub fn generate(&self) -> Dataset {
        self.generate_with_test_fraction(0.10)
    }

    /// Generate with an explicit held-out fraction (0.0 = no test set).
    pub fn generate_with_test_fraction(&self, test_frac: f64) -> Dataset {
        assert!(self.clusters >= 1 && self.d >= 1 && self.n >= self.clusters);
        let mut rng = Pcg64::new(self.seed, 0x5337);

        // component coin weights θ_jd ~ Beta(β, β)
        let weights: Vec<Vec<f64>> = (0..self.clusters)
            .map(|_| (0..self.d).map(|_| beta(&mut rng, self.beta, self.beta)).collect())
            .collect();

        let n_test = ((self.n as f64) * test_frac).round() as usize;
        let n_train = self.n - n_test;

        // balanced assignment then shuffle (paper: balanced mixtures)
        let mut z_all: Vec<u32> = (0..self.n)
            .map(|i| (i % self.clusters) as u32)
            .collect();
        rng.shuffle(&mut z_all);

        let mut train = BinMat::zeros(n_train, self.d);
        let mut test = BinMat::zeros(n_test, self.d);
        let mut train_z = Vec::with_capacity(n_train);
        let mut test_z = Vec::with_capacity(n_test);
        for (i, &z) in z_all.iter().enumerate() {
            let w = &weights[z as usize];
            if i < n_train {
                for (dim, &p) in w.iter().enumerate() {
                    if rng.next_f64() < p {
                        train.set(i, dim, true);
                    }
                }
                train_z.push(z);
            } else {
                let r = i - n_train;
                for (dim, &p) in w.iter().enumerate() {
                    if rng.next_f64() < p {
                        test.set(r, dim, true);
                    }
                }
                test_z.push(z);
            }
        }

        Dataset {
            train,
            test,
            train_z,
            test_z,
            weights,
            config: *self,
        }
    }
}

impl Dataset {
    /// True per-datum log density of row `r` of `m` under the generating
    /// mixture (uniform weights over components — the balanced design).
    pub fn true_log_density(&self, m: &BinMat, r: usize) -> f64 {
        let logj = (self.config.clusters as f64).ln();
        let mut terms = Vec::with_capacity(self.config.clusters);
        for w in &self.weights {
            let mut ll = 0.0;
            for (dim, &p) in w.iter().enumerate() {
                // clamp: beta draws can be within float-eps of 0/1
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                ll += if m.get(r, dim) { p.ln() } else { (1.0 - p).ln() };
            }
            terms.push(ll);
        }
        crate::special::logsumexp(&terms) - logj
    }

    /// Monte-Carlo estimate of the generator's entropy rate
    /// H = E[-log p(x)] using the test rows — the "true entropy" line of
    /// Fig. 5.
    pub fn true_entropy_estimate(&self) -> f64 {
        let n = self.test.rows();
        assert!(n > 0, "need a test split for the entropy estimate");
        let mut acc = 0.0;
        for r in 0..n {
            acc -= self.true_log_density(&self.test, r);
        }
        acc / n as f64
    }
}

/// Balanced Gaussian mixture generator for the real-valued workload:
/// component means drawn `N(0, spread²)` per dim, unit observation
/// noise. The density-estimation analogue of [`SyntheticConfig`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticGaussianConfig {
    /// total number of rows (split evenly over clusters)
    pub n: usize,
    /// real dimensionality
    pub d: usize,
    /// number of true mixture components
    pub clusters: usize,
    /// std-dev of the component means (large ⇒ well-separated clusters)
    pub spread: f64,
    /// master RNG seed
    pub seed: u64,
}

impl SyntheticGaussianConfig {
    /// Generate the data matrix and ground-truth assignments.
    pub fn generate(&self) -> (RealMat, Vec<u32>) {
        assert!(self.clusters >= 1 && self.d >= 1 && self.n >= self.clusters);
        let mut rng = Pcg64::new(self.seed, 0x6a55);
        let means: Vec<Vec<f64>> = (0..self.clusters)
            .map(|_| (0..self.d).map(|_| self.spread * normal(&mut rng)).collect())
            .collect();
        let mut z: Vec<u32> = (0..self.n).map(|i| (i % self.clusters) as u32).collect();
        rng.shuffle(&mut z);
        let mut m = RealMat::zeros(self.n, self.d);
        for (r, &k) in z.iter().enumerate() {
            for (dim, &mu) in means[k as usize].iter().enumerate() {
                m.set(r, dim, mu + normal(&mut rng));
            }
        }
        (m, z)
    }
}

/// Balanced categorical mixture generator: per-component category
/// distributions drawn `Dirichlet(γ·1)` per dim. The NLP-flavored
/// analogue of [`SyntheticConfig`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticCategoricalConfig {
    /// total number of rows (split evenly over clusters)
    pub n: usize,
    /// number of categorical dims
    pub d: usize,
    /// cardinality shared by every dim
    pub card: u32,
    /// number of true mixture components
    pub clusters: usize,
    /// symmetric Dirichlet concentration for the per-component category
    /// distributions (small γ ⇒ peaked ⇒ well-separated clusters)
    pub gamma: f64,
    /// master RNG seed
    pub seed: u64,
}

impl SyntheticCategoricalConfig {
    /// Generate the data matrix and ground-truth assignments.
    pub fn generate(&self) -> (CatMat, Vec<u32>) {
        assert!(self.clusters >= 1 && self.d >= 1 && self.card >= 2);
        assert!(self.n >= self.clusters);
        let mut rng = Pcg64::new(self.seed, 0xca7);
        let alphas = vec![self.gamma; self.card as usize];
        let dists: Vec<Vec<Vec<f64>>> = (0..self.clusters)
            .map(|_| (0..self.d).map(|_| dirichlet(&mut rng, &alphas)).collect())
            .collect();
        let mut z: Vec<u32> = (0..self.n).map(|i| (i % self.clusters) as u32).collect();
        rng.shuffle(&mut z);
        let cards = vec![self.card; self.d];
        let mut codes = vec![0u32; self.n * self.d];
        for (r, &k) in z.iter().enumerate() {
            for dim in 0..self.d {
                codes[r * self.d + dim] =
                    categorical(&mut rng, &dists[k as usize][dim]) as u32;
            }
        }
        (CatMat::from_codes(self.n, &cards, &codes), z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_counts_and_shapes() {
        let cfg = SyntheticConfig {
            n: 1000,
            d: 16,
            clusters: 10,
            beta: 0.5,
            seed: 1,
        };
        let ds = cfg.generate();
        assert_eq!(ds.train.rows() + ds.test.rows(), 1000);
        assert_eq!(ds.test.rows(), 100);
        assert_eq!(ds.train.dims(), 16);
        // balanced: every cluster appears n/clusters times overall
        let mut counts = [0u32; 10];
        for &z in ds.train_z.iter().chain(&ds.test_z) {
            counts[z as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SyntheticConfig {
            n: 200,
            d: 8,
            clusters: 4,
            beta: 0.3,
            seed: 42,
        };
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.train, b.train);
        assert_eq!(a.train_z, b.train_z);
    }

    #[test]
    fn small_beta_separates_clusters() {
        // with β → 0 the coins are near 0/1: rows of the same cluster are
        // near-identical, rows of different clusters differ a lot
        let cfg = SyntheticConfig {
            n: 200,
            d: 64,
            clusters: 2,
            beta: 0.02,
            seed: 7,
        };
        let ds = cfg.generate_with_test_fraction(0.0);
        let ham = |a: usize, b: usize| -> u32 {
            let mut h = 0;
            for dim in 0..64 {
                if ds.train.get(a, dim) != ds.train.get(b, dim) {
                    h += 1;
                }
            }
            h
        };
        // find two same-cluster and two different-cluster rows
        let z = &ds.train_z;
        let same = (1..200).find(|&i| z[i] == z[0]).unwrap();
        let diff = (1..200).find(|&i| z[i] != z[0]).unwrap();
        assert!(ham(0, same) + 5 < ham(0, diff), "{} vs {}", ham(0, same), ham(0, diff));
    }

    #[test]
    fn gaussian_generator_shapes_and_separation() {
        let cfg = SyntheticGaussianConfig {
            n: 120,
            d: 4,
            clusters: 3,
            spread: 10.0,
            seed: 5,
        };
        let (m, z) = cfg.generate();
        assert_eq!(m.rows(), 120);
        assert_eq!(m.dims(), 4);
        assert_eq!(z.len(), 120);
        // well-separated means: same-cluster rows are closer than
        // different-cluster rows
        let dist = |a: usize, b: usize| -> f64 {
            (0..4).map(|d| (m.get(a, d) - m.get(b, d)).powi(2)).sum()
        };
        let same = (1..120).find(|&i| z[i] == z[0]).unwrap();
        let diff = (1..120).find(|&i| z[i] != z[0]).unwrap();
        assert!(dist(0, same) < dist(0, diff), "{} vs {}", dist(0, same), dist(0, diff));
    }

    #[test]
    fn categorical_generator_shapes_and_determinism() {
        let cfg = SyntheticCategoricalConfig {
            n: 60,
            d: 5,
            card: 4,
            clusters: 3,
            gamma: 0.2,
            seed: 9,
        };
        let (m, z) = cfg.generate();
        assert_eq!(m.rows(), 60);
        assert_eq!(m.dims(), 5);
        assert_eq!(m.width(), 20);
        assert_eq!(z.len(), 60);
        let (m2, z2) = cfg.generate();
        assert_eq!(m, m2);
        assert_eq!(z, z2);
    }

    #[test]
    fn entropy_estimate_close_to_marginal_bound() {
        // entropy of the mixture is at most D·ln2 and at least 0
        let cfg = SyntheticConfig {
            n: 500,
            d: 16,
            clusters: 4,
            beta: 1.0,
            seed: 3,
        };
        let ds = cfg.generate();
        let h = ds.true_entropy_estimate();
        assert!(h > 0.0 && h < 16.0 * std::f64::consts::LN_2 + 1.0, "H = {h}");
    }
}
