//! Dataset (de)serialization: a simple length-prefixed binary container
//! for [`BinMat`] + labels, and CSV emitters for traces. Hand-rolled (no
//! serde in the offline universe); format is versioned and checksummed.

use super::binmat::BinMat;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CCBIN01\n";

/// Write a BinMat (+ optional labels) to `path`.
pub fn save_binmat(path: &Path, m: &BinMat, labels: Option<&[u32]>) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(m.rows() as u64).to_le_bytes())?;
    f.write_all(&(m.dims() as u64).to_le_bytes())?;
    let nl = labels.map(|l| l.len()).unwrap_or(0);
    f.write_all(&(nl as u64).to_le_bytes())?;
    let mut sum: u64 = 0;
    for &w in m.words() {
        sum = sum.wrapping_add(w);
        f.write_all(&w.to_le_bytes())?;
    }
    if let Some(l) = labels {
        for &z in l {
            sum = sum.wrapping_add(z as u64);
            f.write_all(&z.to_le_bytes())?;
        }
    }
    f.write_all(&sum.to_le_bytes())?;
    Ok(())
}

/// Load a BinMat (+ labels) previously written by [`save_binmat`].
pub fn load_binmat(path: &Path) -> std::io::Result<(BinMat, Option<Vec<u32>>)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not a CCBIN01 file",
        ));
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |f: &mut std::fs::File| -> std::io::Result<u64> {
        f.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n = read_u64(&mut f)? as usize;
    let d = read_u64(&mut f)? as usize;
    let nl = read_u64(&mut f)? as usize;
    let wpr = d.div_ceil(64);
    let mut words = Vec::with_capacity(n * wpr);
    let mut sum: u64 = 0;
    let mut buf = [0u8; 8];
    for _ in 0..n * wpr {
        f.read_exact(&mut buf)?;
        let w = u64::from_le_bytes(buf);
        sum = sum.wrapping_add(w);
        words.push(w);
    }
    let labels = if nl > 0 {
        let mut l = Vec::with_capacity(nl);
        let mut b4 = [0u8; 4];
        for _ in 0..nl {
            f.read_exact(&mut b4)?;
            let z = u32::from_le_bytes(b4);
            sum = sum.wrapping_add(z as u64);
            l.push(z);
        }
        Some(l)
    } else {
        None
    };
    f.read_exact(&mut buf)?;
    if u64::from_le_bytes(buf) != sum {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "checksum mismatch: corrupt dataset file",
        ));
    }
    Ok((BinMat::from_words(n, d, words), labels))
}

/// Append-style CSV writer for metric traces.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    /// Create the file and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    /// Append one row of numeric values.
    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.file, "{line}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn binmat_roundtrip_with_labels() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ccbin");
        let mut rng = Pcg64::seed_from(1);
        let mut m = BinMat::zeros(17, 100);
        for r in 0..17 {
            for c in 0..100 {
                if rng.next_f64() < 0.4 {
                    m.set(r, c, true);
                }
            }
        }
        let labels: Vec<u32> = (0..17).map(|i| i * 3).collect();
        save_binmat(&path, &m, Some(&labels)).unwrap();
        let (m2, l2) = load_binmat(&path).unwrap();
        assert_eq!(m, m2);
        assert_eq!(l2.unwrap(), labels);
    }

    #[test]
    fn corrupt_file_is_rejected() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.ccbin");
        let m = BinMat::zeros(4, 64);
        save_binmat(&path, &m, None).unwrap();
        // flip a byte in the middle
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_binmat(&path).is_err());
    }

    #[test]
    fn wrong_magic_is_rejected() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_magic.ccbin");
        std::fs::write(&path, b"NOTMAGIC plus some garbage").unwrap();
        assert!(load_binmat(&path).is_err());
    }

    #[test]
    fn csv_writer_emits_header_and_rows() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "loglik"]).unwrap();
            w.row(&[1.0, -2.5]).unwrap();
            w.row(&[2.0, -2.25]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,loglik\n"));
        assert!(text.contains("2,-2.25"));
    }
}
