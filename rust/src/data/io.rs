//! Dataset (de)serialization: a simple length-prefixed binary container
//! for [`BinMat`] + labels, and CSV emitters for traces. Hand-rolled (no
//! serde in the offline universe); format is versioned and checksummed.

use super::binmat::BinMat;
use super::containers::{CatMat, RealMat};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CCBIN01\n";
const MAGIC_REAL: &[u8; 8] = b"CCREAL1\n";
const MAGIC_CAT: &[u8; 8] = b"CCCAT01\n";

/// Write a BinMat (+ optional labels) to `path`.
pub fn save_binmat(path: &Path, m: &BinMat, labels: Option<&[u32]>) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(m.rows() as u64).to_le_bytes())?;
    f.write_all(&(m.dims() as u64).to_le_bytes())?;
    let nl = labels.map(|l| l.len()).unwrap_or(0);
    f.write_all(&(nl as u64).to_le_bytes())?;
    let mut sum: u64 = 0;
    for &w in m.words() {
        sum = sum.wrapping_add(w);
        f.write_all(&w.to_le_bytes())?;
    }
    if let Some(l) = labels {
        for &z in l {
            sum = sum.wrapping_add(z as u64);
            f.write_all(&z.to_le_bytes())?;
        }
    }
    f.write_all(&sum.to_le_bytes())?;
    Ok(())
}

/// Load a BinMat (+ labels) previously written by [`save_binmat`].
pub fn load_binmat(path: &Path) -> std::io::Result<(BinMat, Option<Vec<u32>>)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not a CCBIN01 file",
        ));
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |f: &mut std::fs::File| -> std::io::Result<u64> {
        f.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n = read_u64(&mut f)? as usize;
    let d = read_u64(&mut f)? as usize;
    let nl = read_u64(&mut f)? as usize;
    let wpr = d.div_ceil(64);
    let mut words = Vec::with_capacity(n * wpr);
    let mut sum: u64 = 0;
    let mut buf = [0u8; 8];
    for _ in 0..n * wpr {
        f.read_exact(&mut buf)?;
        let w = u64::from_le_bytes(buf);
        sum = sum.wrapping_add(w);
        words.push(w);
    }
    let labels = if nl > 0 {
        let mut l = Vec::with_capacity(nl);
        let mut b4 = [0u8; 4];
        for _ in 0..nl {
            f.read_exact(&mut b4)?;
            let z = u32::from_le_bytes(b4);
            sum = sum.wrapping_add(z as u64);
            l.push(z);
        }
        Some(l)
    } else {
        None
    };
    f.read_exact(&mut buf)?;
    if u64::from_le_bytes(buf) != sum {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "checksum mismatch: corrupt dataset file",
        ));
    }
    Ok((BinMat::from_words(n, d, words), labels))
}

/// Write a [`RealMat`] to `path` (CCREAL1: dims + f64 bit-patterns +
/// wrapping checksum, mirroring the CCBIN01 layout).
pub fn save_realmat(path: &Path, m: &RealMat) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC_REAL)?;
    f.write_all(&(m.rows() as u64).to_le_bytes())?;
    f.write_all(&(m.dims() as u64).to_le_bytes())?;
    let mut sum: u64 = 0;
    for &v in m.values() {
        sum = sum.wrapping_add(v.to_bits());
        f.write_all(&v.to_le_bytes())?;
    }
    f.write_all(&sum.to_le_bytes())?;
    Ok(())
}

/// Load a [`RealMat`] previously written by [`save_realmat`].
pub fn load_realmat(path: &Path) -> std::io::Result<RealMat> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC_REAL {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not a CCREAL1 file",
        ));
    }
    let mut buf = [0u8; 8];
    f.read_exact(&mut buf)?;
    let n = u64::from_le_bytes(buf) as usize;
    f.read_exact(&mut buf)?;
    let d = u64::from_le_bytes(buf) as usize;
    let mut vals = Vec::with_capacity(n * d);
    let mut sum: u64 = 0;
    for _ in 0..n * d {
        f.read_exact(&mut buf)?;
        let v = f64::from_le_bytes(buf);
        sum = sum.wrapping_add(v.to_bits());
        vals.push(v);
    }
    f.read_exact(&mut buf)?;
    if u64::from_le_bytes(buf) != sum {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "checksum mismatch: corrupt real dataset file",
        ));
    }
    Ok(RealMat::from_dense(n, d, vals))
}

/// Write a [`CatMat`] to `path` (CCCAT01: cardinalities + row-major
/// category codes + wrapping checksum).
pub fn save_catmat(path: &Path, m: &CatMat) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC_CAT)?;
    f.write_all(&(m.rows() as u64).to_le_bytes())?;
    f.write_all(&(m.dims() as u64).to_le_bytes())?;
    let mut sum: u64 = 0;
    for &v in m.cards() {
        sum = sum.wrapping_add(v as u64);
        f.write_all(&v.to_le_bytes())?;
    }
    for r in 0..m.rows() {
        for dim in 0..m.dims() {
            let code = m.get(r, dim);
            sum = sum.wrapping_add(code as u64);
            f.write_all(&code.to_le_bytes())?;
        }
    }
    f.write_all(&sum.to_le_bytes())?;
    Ok(())
}

/// Load a [`CatMat`] previously written by [`save_catmat`].
pub fn load_catmat(path: &Path) -> std::io::Result<CatMat> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC_CAT {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not a CCCAT01 file",
        ));
    }
    let mut buf = [0u8; 8];
    f.read_exact(&mut buf)?;
    let n = u64::from_le_bytes(buf) as usize;
    f.read_exact(&mut buf)?;
    let d = u64::from_le_bytes(buf) as usize;
    let mut b4 = [0u8; 4];
    let mut sum: u64 = 0;
    let mut cards = Vec::with_capacity(d);
    for _ in 0..d {
        f.read_exact(&mut b4)?;
        let v = u32::from_le_bytes(b4);
        sum = sum.wrapping_add(v as u64);
        cards.push(v);
    }
    let mut codes = Vec::with_capacity(n * d);
    for _ in 0..n * d {
        f.read_exact(&mut b4)?;
        let v = u32::from_le_bytes(b4);
        sum = sum.wrapping_add(v as u64);
        codes.push(v);
    }
    f.read_exact(&mut buf)?;
    if u64::from_le_bytes(buf) != sum {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "checksum mismatch: corrupt categorical dataset file",
        ));
    }
    if cards.iter().any(|&v| v < 2) || codes.iter().enumerate().any(|(i, &c)| c >= cards[i % d]) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "invalid categorical file: code out of range",
        ));
    }
    Ok(CatMat::from_codes(n, &cards, &codes))
}

/// Append-style CSV writer for metric traces.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    /// Create the file and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    /// Append one row of numeric values.
    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.file, "{line}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn binmat_roundtrip_with_labels() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ccbin");
        let mut rng = Pcg64::seed_from(1);
        let mut m = BinMat::zeros(17, 100);
        for r in 0..17 {
            for c in 0..100 {
                if rng.next_f64() < 0.4 {
                    m.set(r, c, true);
                }
            }
        }
        let labels: Vec<u32> = (0..17).map(|i| i * 3).collect();
        save_binmat(&path, &m, Some(&labels)).unwrap();
        let (m2, l2) = load_binmat(&path).unwrap();
        assert_eq!(m, m2);
        assert_eq!(l2.unwrap(), labels);
    }

    #[test]
    fn corrupt_file_is_rejected() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.ccbin");
        let m = BinMat::zeros(4, 64);
        save_binmat(&path, &m, None).unwrap();
        // flip a byte in the middle
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_binmat(&path).is_err());
    }

    #[test]
    fn wrong_magic_is_rejected() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_magic.ccbin");
        std::fs::write(&path, b"NOTMAGIC plus some garbage").unwrap();
        assert!(load_binmat(&path).is_err());
    }

    #[test]
    fn realmat_roundtrip_and_corruption() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ccreal");
        let mut rng = Pcg64::seed_from(2);
        let vals: Vec<f64> = (0..5 * 3).map(|_| rng.next_f64() * 10.0 - 5.0).collect();
        let m = crate::data::RealMat::from_dense(5, 3, vals);
        save_realmat(&path, &m).unwrap();
        assert_eq!(load_realmat(&path).unwrap(), m);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_realmat(&path).is_err());
    }

    #[test]
    fn catmat_roundtrip_and_wrong_magic() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.cccat");
        let m = crate::data::CatMat::from_codes(3, &[3, 2], &[2, 0, 1, 1, 0, 1]);
        save_catmat(&path, &m).unwrap();
        assert_eq!(load_catmat(&path).unwrap(), m);
        // a binary file must be rejected by magic, and vice versa
        let bpath = dir.join("as_bin.ccbin");
        save_binmat(&bpath, &BinMat::zeros(2, 4), None).unwrap();
        assert!(load_catmat(&bpath).is_err());
        assert!(load_realmat(&path).is_err());
    }

    #[test]
    fn csv_writer_emits_header_and_rows() {
        let dir = std::env::temp_dir().join("cc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "loglik"]).unwrap();
            w.row(&[1.0, -2.5]).unwrap();
            w.row(&[2.0, -2.25]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,loglik\n"));
        assert!(text.contains("2,-2.25"));
    }
}
