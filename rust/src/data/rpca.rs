//! Randomized PCA (Halko–Martinsson–Tropp randomized range finder with
//! power iterations) — the paper's feature pipeline runs "a randomized
//! approximation to PCA on 100,000 rows" and thresholds "the top 256
//! principal components ... at their component-wise median" (§6).
//!
//! Algorithm: Ω ~ N(0,1)^{d×(k+p)}; Y = A Ω; q power iterations
//! Y ← A (Aᵀ Y) with re-orthonormalization; Q = orth(Y);
//! B = Qᵀ A; eigendecompose the small Gram B Bᵀ; right singular vectors
//! V = Bᵀ U Λ^{-1/2}; principal scores = A V.

use crate::linalg::{jacobi_eigen_sym, Mat};
use crate::rng::{normal, Pcg64};

/// Result of a randomized PCA.
#[derive(Debug, Clone)]
pub struct Rpca {
    /// [d, k] right singular vectors (principal directions)
    pub components: Mat,
    /// top-k singular values of the (centred) data matrix
    pub singular_values: Vec<f64>,
    /// column means subtracted before factorization
    pub means: Vec<f64>,
}

/// Randomized PCA of `a` (n×d, consumed centred in place): top `k`
/// components with oversampling `p` and `q` power iterations.
pub fn rpca(a: &mut Mat, k: usize, p: usize, q: usize, seed: u64) -> Rpca {
    let (n, d) = (a.rows, a.cols);
    assert!(k >= 1 && k + p <= d.min(n), "k+p must be <= min(n,d)");
    let means = a.center_columns();
    let l = k + p;
    let mut rng = Pcg64::new(seed, 0x9ca);

    // Ω: d × l gaussian
    let mut omega = Mat::zeros(d, l);
    for x in omega.data.iter_mut() {
        *x = normal(&mut rng);
    }

    // range finder with power iterations
    let mut y = a.matmul(&omega); // n × l
    y.orthonormalize_columns();
    for _ in 0..q {
        let z = a.t_matmul(&y); // d × l  (Aᵀ Y)
        let mut z = z;
        z.orthonormalize_columns();
        y = a.matmul(&z); // n × l
        y.orthonormalize_columns();
    }

    // B = Qᵀ A : l × d  — small
    let b = y.t_matmul(a);
    // Gram G = B Bᵀ : l × l ; eigen G = U Λ Uᵀ
    let g = b.matmul(&b.transpose());
    let (evals, u) = jacobi_eigen_sym(&g, 60);

    // V = Bᵀ U Λ^{-1/2}, keep top k
    let mut components = Mat::zeros(d, k);
    let mut singular_values = Vec::with_capacity(k);
    let bt = b.transpose(); // d × l
    for j in 0..k {
        let lam = evals[j].max(0.0);
        let sv = lam.sqrt();
        singular_values.push(sv);
        if sv > 1e-12 {
            for r in 0..d {
                let mut acc = 0.0;
                for c in 0..bt.cols {
                    acc += bt.at(r, c) * u.at(c, j);
                }
                *components.at_mut(r, j) = acc / sv;
            }
        }
    }

    Rpca {
        components,
        singular_values,
        means,
    }
}

impl Rpca {
    /// Project (already-raw) rows onto the principal components:
    /// scores = (X - mean) · V, shape [n, k].
    pub fn project(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.components.rows);
        let mut centred = x.clone();
        for r in 0..centred.rows {
            for c in 0..centred.cols {
                *centred.at_mut(r, c) -= self.means[c];
            }
        }
        centred.matmul(&self.components)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a low-rank-plus-noise matrix with known dominant directions.
    fn low_rank_matrix(n: usize, d: usize, rank: usize, noise: f64, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from(seed);
        let mut u = Mat::zeros(n, rank);
        let mut v = Mat::zeros(rank, d);
        for x in u.data.iter_mut() {
            *x = normal(&mut rng);
        }
        for x in v.data.iter_mut() {
            *x = normal(&mut rng);
        }
        // scale factor per rank so singular values are separated
        for r in 0..rank {
            let s = 10.0 / (r + 1) as f64;
            for c in 0..d {
                *v.at_mut(r, c) *= s;
            }
        }
        let mut a = u.matmul(&v);
        for x in a.data.iter_mut() {
            *x += noise * normal(&mut rng);
        }
        a
    }

    #[test]
    fn recovers_low_rank_energy() {
        let mut a = low_rank_matrix(120, 40, 3, 0.01, 1);
        let total_energy = {
            let mut c = a.clone();
            c.center_columns();
            c.fro_norm().powi(2)
        };
        let res = rpca(&mut a, 3, 8, 3, 2);
        let captured: f64 = res.singular_values.iter().map(|s| s * s).sum();
        assert!(
            captured > 0.98 * total_energy,
            "captured {captured} of {total_energy}"
        );
        // singular values sorted descending
        assert!(res
            .singular_values
            .windows(2)
            .all(|w| w[0] >= w[1] - 1e-9));
    }

    #[test]
    fn components_are_orthonormal() {
        let mut a = low_rank_matrix(80, 30, 4, 0.05, 3);
        let res = rpca(&mut a, 4, 6, 2, 4);
        for i in 0..4 {
            for j in 0..4 {
                let mut dot = 0.0;
                for r in 0..30 {
                    dot += res.components.at(r, i) * res.components.at(r, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-6, "({i},{j}) = {dot}");
            }
        }
    }

    #[test]
    fn projection_matches_training_scores() {
        // project() on the training data should reproduce A_centred · V
        let mut a = low_rank_matrix(50, 20, 2, 0.0, 5);
        let raw = a.clone();
        let res = rpca(&mut a, 2, 4, 2, 6);
        let scores = res.project(&raw);
        assert_eq!(scores.rows, 50);
        assert_eq!(scores.cols, 2);
        // score variance along component 0 ≈ (σ_0² / n)
        let var0: f64 = (0..50).map(|r| scores.at(r, 0).powi(2)).sum::<f64>();
        let sv0 = res.singular_values[0];
        assert!(
            (var0 - sv0 * sv0).abs() / (sv0 * sv0) < 0.05,
            "var {var0} vs σ² {}",
            sv0 * sv0
        );
    }
}
