//! Bit-packed binary data matrix: N rows × D binary dims, 64 dims per
//! word. This is the at-rest representation of every dataset in the repo
//! (the paper's data are Bernoulli vectors). The Gibbs hot path iterates
//! set bits via `for_each_one` (trailing_zeros loop) so scoring cost
//! scales with row density, and the runtime unpacks blocks to f32 for the
//! PJRT artifacts.

/// Bit-packed binary matrix, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct BinMat {
    n: usize,
    d: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl BinMat {
    /// All-zeros matrix of `n` rows × `d` binary dims.
    pub fn zeros(n: usize, d: usize) -> BinMat {
        let wpr = d.div_ceil(64);
        BinMat {
            n,
            d,
            words_per_row: wpr,
            bits: vec![0; n * wpr],
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Number of binary dimensions.
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Bit at (row, dim).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.n && c < self.d);
        let w = self.bits[r * self.words_per_row + c / 64];
        (w >> (c % 64)) & 1 == 1
    }

    /// Set the bit at (row, dim).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.n && c < self.d);
        let w = &mut self.bits[r * self.words_per_row + c / 64];
        if v {
            *w |= 1 << (c % 64);
        } else {
            *w &= !(1 << (c % 64));
        }
    }

    /// The packed words of row `r`.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.bits[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Number of ones in row `r`.
    pub fn row_popcount(&self, r: usize) -> u32 {
        self.row_words(r).iter().map(|w| w.count_ones()).sum()
    }

    /// Call `f(dim)` for every set bit of row `r`, in ascending dim order.
    #[inline]
    pub fn for_each_one(&self, r: usize, mut f: impl FnMut(usize)) {
        for (wi, &w) in self.row_words(r).iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                f(wi * 64 + b);
                w &= w - 1;
            }
        }
    }

    /// Unpack rows [start, start+len) into an f32 buffer of shape
    /// [len, d_out], zero-padding dims beyond `self.d` — the exact layout
    /// the PJRT artifacts expect (pad dims are no-ops, see L1 tests).
    pub fn unpack_block_f32(&self, start: usize, len: usize, d_out: usize, out: &mut [f32]) {
        assert!(d_out >= self.d, "d_out must cover data dims");
        assert_eq!(out.len(), len * d_out);
        out.fill(0.0);
        for i in 0..len {
            let r = start + i;
            if r >= self.n {
                break; // trailing pad rows stay zero
            }
            let base = i * d_out;
            self.for_each_one(r, |dim| out[base + dim] = 1.0);
        }
    }

    /// Build from a dense 0/1 byte matrix (row-major), for tests/IO.
    pub fn from_dense(n: usize, d: usize, dense: &[u8]) -> BinMat {
        assert_eq!(dense.len(), n * d);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if dense[r * d + c] != 0 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// Raw words (for IO).
    pub fn words(&self) -> &[u64] {
        &self.bits
    }

    /// Rebuild from the packed word representation (see [`Self::words`]).
    pub fn from_words(n: usize, d: usize, words: Vec<u64>) -> BinMat {
        let wpr = d.div_ceil(64);
        assert_eq!(words.len(), n * wpr);
        BinMat {
            n,
            d,
            words_per_row: wpr,
            bits: words,
        }
    }

    /// Copy a subset of rows into a new matrix (supercluster shards).
    pub fn select_rows(&self, rows: &[usize]) -> BinMat {
        let mut out = BinMat::zeros(rows.len(), self.d);
        for (i, &r) in rows.iter().enumerate() {
            let src = r * self.words_per_row;
            let dst = i * self.words_per_row;
            out.bits[dst..dst + self.words_per_row]
                .copy_from_slice(&self.bits[src..src + self.words_per_row]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn set_get_roundtrip_across_word_boundaries() {
        let mut m = BinMat::zeros(3, 130);
        m.set(0, 0, true);
        m.set(1, 63, true);
        m.set(1, 64, true);
        m.set(2, 129, true);
        assert!(m.get(0, 0) && m.get(1, 63) && m.get(1, 64) && m.get(2, 129));
        assert!(!m.get(0, 1) && !m.get(2, 128));
        m.set(1, 64, false);
        assert!(!m.get(1, 64));
        assert_eq!(m.row_popcount(1), 1);
    }

    #[test]
    fn for_each_one_visits_exactly_set_bits() {
        let mut rng = Pcg64::seed_from(1);
        let (n, d) = (5, 200);
        let mut m = BinMat::zeros(n, d);
        let mut truth = vec![vec![]; n];
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.3 {
                    m.set(r, c, true);
                    truth[r].push(c);
                }
            }
        }
        for r in 0..n {
            let mut seen = vec![];
            m.for_each_one(r, |c| seen.push(c));
            assert_eq!(seen, truth[r]);
        }
    }

    #[test]
    fn unpack_block_pads_dims_and_rows() {
        let mut m = BinMat::zeros(3, 5);
        m.set(0, 1, true);
        m.set(2, 4, true);
        let mut buf = vec![9.0f32; 4 * 8]; // 4 rows (one past end), d_out=8
        m.unpack_block_f32(1, 4, 8, &mut buf);
        // row 1 of matrix = all zero
        assert!(buf[0..8].iter().all(|&x| x == 0.0));
        // row 2 has bit 4
        assert_eq!(buf[8 + 4], 1.0);
        assert_eq!(buf[8..16].iter().sum::<f32>(), 1.0);
        // rows 3,4 past the end: zero
        assert!(buf[16..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dense_roundtrip_and_select_rows() {
        let dense = [1u8, 0, 1, 0, 0, 1, 1, 1, 0];
        let m = BinMat::from_dense(3, 3, &dense);
        let sel = m.select_rows(&[2, 0]);
        assert_eq!(sel.rows(), 2);
        assert!(sel.get(0, 0) && sel.get(0, 1) && !sel.get(0, 2));
        assert!(sel.get(1, 0) && !sel.get(1, 1) && sel.get(1, 2));
    }

    #[test]
    fn words_roundtrip() {
        let mut m = BinMat::zeros(2, 70);
        m.set(0, 69, true);
        m.set(1, 0, true);
        let m2 = BinMat::from_words(2, 70, m.words().to_vec());
        assert_eq!(m, m2);
    }
}
