//! Cyclic Jacobi eigendecomposition for small symmetric matrices — used
//! by the randomized-PCA pipeline (`data::rpca`) to diagonalize the
//! (k+p)×(k+p) Gram matrix B·Bᵀ. O(n³) per sweep but n ≲ 300 here.

use super::Mat;

/// Eigendecomposition of a symmetric matrix: returns (eigenvalues,
/// eigenvectors-as-columns), sorted by descending eigenvalue.
pub fn jacobi_eigen_sym(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "jacobi needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::zeros(n, n);
    for i in 0..n {
        *v.at_mut(i, i) = 1.0;
    }

    for _ in 0..max_sweeps {
        // off-diagonal magnitude
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    *m.at_mut(k, p) = c * mkp - s * mkq;
                    *m.at_mut(k, q) = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    *m.at_mut(p, k) = c * mpk - s * mqk;
                    *m.at_mut(q, k) = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            *sorted_vecs.at_mut(r, newc) = v.at(r, oldc);
        }
    }
    (sorted_vals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonalizes_known_matrix() {
        // eigenvalues of [[2,1],[1,2]] are 3 and 1
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = jacobi_eigen_sym(&a, 50);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
        // eigenvector for 3 is (1,1)/sqrt2 up to sign
        let (v0, v1) = (vecs.at(0, 0), vecs.at(1, 0));
        assert!((v0.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((v0 - v1).abs() < 1e-8);
    }

    #[test]
    fn reconstructs_a_random_symmetric_matrix() {
        use crate::rng::{normal, Pcg64};
        let n = 12;
        let mut rng = Pcg64::seed_from(9);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = normal(&mut rng);
                *a.at_mut(i, j) = x;
                *a.at_mut(j, i) = x;
            }
        }
        let (vals, vecs) = jacobi_eigen_sym(&a, 100);
        // A ≈ V Λ Vᵀ
        let mut lam = Mat::zeros(n, n);
        for i in 0..n {
            *lam.at_mut(i, i) = vals[i];
        }
        let recon = vecs.matmul(&lam).matmul(&vecs.transpose());
        let mut err = 0.0;
        for i in 0..n * n {
            err += (recon.data[i] - a.data[i]).powi(2);
        }
        assert!(err.sqrt() < 1e-8, "reconstruction error {err}");
        // eigenvalues sorted descending
        assert!(vals.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }
}
