//! Minimal dense linear algebra — just enough to implement the paper's
//! feature pipeline (§6: "a randomized approximation to PCA ... top 256
//! principal components"): row-major matrices, matmul, transpose-matmul,
//! Gram–Schmidt QR, and column centring. Built from scratch; validated
//! against hand-computed and power-iteration ground truths in tests and
//! against dense eigendecomposition in `data::rpca` tests.

pub mod eigen;

pub use eigen::jacobi_eigen_sym;

/// Row-major dense f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// row count
    pub rows: usize,
    /// column count
    pub cols: usize,
    /// row-major storage, length rows × cols
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Build from row vectors (all must share one length).
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    /// Element at (r, c).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    /// Mutable element at (r, c).
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// C = A · B (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for j in 0..other.cols {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// C = Aᵀ · B without materializing Aᵀ.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        for r in 0..self.rows {
            let arow = self.row(r);
            let brow = other.row(r);
            for i in 0..self.cols {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for j in 0..other.cols {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Subtract the column means in place; returns the means.
    pub fn center_columns(&mut self) -> Vec<f64> {
        let mut means = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                means[c] += self.at(r, c);
            }
        }
        for m in &mut means {
            *m /= self.rows.max(1) as f64;
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                *self.at_mut(r, c) -= means[c];
            }
        }
        means
    }

    /// In-place modified Gram–Schmidt orthonormalization of the columns.
    /// Columns with near-zero residual norm are replaced by zeros.
    pub fn orthonormalize_columns(&mut self) {
        for j in 0..self.cols {
            // subtract projections on previous columns
            for p in 0..j {
                let mut dot = 0.0;
                for r in 0..self.rows {
                    dot += self.at(r, j) * self.at(r, p);
                }
                for r in 0..self.rows {
                    *self.at_mut(r, j) -= dot * self.at(r, p);
                }
            }
            let mut norm = 0.0;
            for r in 0..self.rows {
                norm += self.at(r, j) * self.at(r, j);
            }
            let norm = norm.sqrt();
            if norm > 1e-12 {
                for r in 0..self.rows {
                    *self.at_mut(r, j) /= norm;
                }
            } else {
                for r in 0..self.rows {
                    *self.at_mut(r, j) = 0.0;
                }
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

/// Column-wise median of a row-major matrix (used by the §6 binarization
/// pipeline: threshold each principal component at its median).
pub fn column_medians(m: &Mat) -> Vec<f64> {
    let mut out = Vec::with_capacity(m.cols);
    let mut buf = vec![0.0; m.rows];
    for c in 0..m.cols {
        for r in 0..m.rows {
            buf[r] = m.at(r, c);
        }
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = m.rows / 2;
        out.push(if m.rows % 2 == 1 {
            buf[mid]
        } else {
            0.5 * (buf[mid - 1] + buf[mid])
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_product() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert_eq!(got, want);
    }

    #[test]
    fn center_columns_zeroes_means() {
        let mut a = Mat::from_rows(vec![vec![1.0, 10.0], vec![3.0, 30.0]]);
        let means = a.center_columns();
        assert_eq!(means, vec![2.0, 20.0]);
        for c in 0..2 {
            let s: f64 = (0..2).map(|r| a.at(r, c)).sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut a = Mat::from_rows(vec![
            vec![1.0, 1.0, 0.5],
            vec![1.0, 0.0, 0.3],
            vec![0.0, 1.0, 0.9],
            vec![1.0, 2.0, 0.1],
        ]);
        a.orthonormalize_columns();
        for i in 0..3 {
            for j in 0..3 {
                let mut dot = 0.0;
                for r in 0..4 {
                    dot += a.at(r, i) * a.at(r, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-10, "({i},{j}) dot {dot}");
            }
        }
    }

    #[test]
    fn degenerate_column_is_zeroed() {
        let mut a = Mat::from_rows(vec![vec![1.0, 2.0], vec![1.0, 2.0]]);
        a.orthonormalize_columns();
        // second column is linearly dependent — must be zero
        assert!(a.at(0, 1).abs() < 1e-12 && a.at(1, 1).abs() < 1e-12);
    }

    #[test]
    fn column_medians_even_odd() {
        let m = Mat::from_rows(vec![vec![1.0], vec![9.0], vec![5.0]]);
        assert_eq!(column_medians(&m), vec![5.0]);
        let m2 = Mat::from_rows(vec![vec![1.0], vec![9.0], vec![5.0], vec![7.0]]);
        assert_eq!(column_medians(&m2), vec![6.0]);
    }
}
