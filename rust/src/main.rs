//! `repro` — the ClusterCluster launcher.
//!
//! Subcommands:
//! * `gen-data`    — generate a synthetic balanced Bernoulli-mixture dataset
//! * `serial`      — run the serial collapsed-Gibbs baseline (Neal Alg. 3)
//! * `run`         — run the parallel supercluster sampler (the paper)
//! * `serve`       — long-running query service over published round snapshots
//! * `tiny-images` — build the Tiny-Images-substitute corpus and run VQ
//! * `help`        — this text

use clustercluster::cli::Args;
use clustercluster::coordinator::{
    Checkpoint, CheckpointDir, Coordinator, CoordinatorConfig, KernelAssignment, MuMode,
    SuperviseConfig,
};
use clustercluster::data::io::save_binmat;
use clustercluster::data::synthetic::{
    Dataset, SyntheticCategoricalConfig, SyntheticConfig, SyntheticGaussianConfig,
};
use clustercluster::data::tinyimages::{generate as gen_tiny, TinyImagesConfig};
use clustercluster::data::{CatMat, DataRef, RealMat};
use clustercluster::mapreduce::CommModel;
use clustercluster::model::ModelSpec;
use clustercluster::metrics::shard::{ShardTrace, ShardTraceRow};
use clustercluster::metrics::trace::{McmcTrace, TraceRow};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::ScorerKind;
use clustercluster::sampler::{KernelKind, ScoreMode};
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::serve::{self, ServeConfig};
use clustercluster::supercluster::ShuffleKernel;
use std::path::{Path, PathBuf};
use std::time::Duration;

const HELP: &str = "\
repro — ClusterCluster: parallel MCMC for Dirichlet process mixtures

USAGE: repro <command> [--flag value]...

COMMANDS
  gen-data     --n 10000 --d 256 --clusters 128 --beta 0.1 --seed 0 --out data.ccbin
  serial       --n 5000 --d 64 --clusters 32 --sweeps 50
               [--model bernoulli|gaussian[:k0,m0,a0,b0]|categorical[:gamma]]
               [--local-kernel gibbs|walker|split_merge:gibbs|split_merge:walker]
               [--scorer auto|fallback|pjrt] [--update-beta] [--trace out.csv]
               [--checkpoint out.ccckpt] [--resume in.ccckpt]
  run          --n 5000 --d 64 --clusters 32 --workers 8 --rounds 50
               [--model bernoulli|gaussian[:k0,m0,a0,b0]|categorical[:gamma]]
               [--local-sweeps 1] [--no-shuffle] [--eq7]
               [--local-kernel gibbs|walker|split_merge:gibbs|split_merge:walker
                |gibbs,split_merge:walker,...]
               [--mu-mode uniform|size-proportional|adaptive[:target]]
               [--scorer auto|fallback|pjrt] [--update-beta] [--latency 2.0]
               [--bandwidth 1e8] [--trace out.csv] [--shard-trace shards.csv]
               [--threads 1] [--checkpoint state.ccckpt]
               [--overlap on|off] [--max-bonus-sweeps 2]
               [--supervise on|off] [--round-timeout 30]
               [--max-retries 2] [--retry-backoff 0.025]
               [--retry-backoff-cap 1.0] [--quarantine-cooldown 3]
               [--checkpoint-dir ckpts/] [--checkpoint-every 10]
               [--checkpoint-keep 3]
  serve        --n 5000 --d 64 --clusters 32 --workers 8
               --addr 127.0.0.1:7878 [--rounds 0]
               [--serve-trace serve.jsonl] [--trace-every 10]
               [--checkpoint-dir ckpts/] [--checkpoint-every 10]
               [--checkpoint-keep 3] [+ the run sampler flags;
               bernoulli model only]
  tiny-images  --n 5000 --features 128 --workers 8 --rounds 30
  help

Both samplers run the same pluggable per-shard transition kernels
(--local-kernel): \"gibbs\" = Neal (2000) Alg. 3 collapsed Gibbs,
\"walker\" = Walker (2007) slice sampling, and the composite specs
\"split_merge:gibbs\" / \"split_merge:walker\" = Jain & Neal (2004)
restricted-Gibbs split-merge MH moves interleaved with the named
per-datum sweep (global cluster creation/dissolution in one step —
see the kernel selection guide, DESIGN.md section 7). A
comma-separated list (e.g. \"gibbs,split_merge:walker\") cycles the
kernels over the superclusters — different shards run different
operators within one exact chain.
(--walker is accepted as a legacy spelling of --local-kernel walker.)

--model picks the collapsed component likelihood (both samplers,
every kernel and mu-mode; see DESIGN.md section ComponentModel):
\"bernoulli\" = Beta-Bernoulli over binary data (the paper; beta comes
from --beta and may be resampled with --update-beta);
\"gaussian[:k0,m0,a0,b0]\" = Normal-Inverse-Gamma diagonal Gaussian
over real data (defaults 1,0,1,1; synthetic data takes --spread);
\"categorical[:gamma]\" = Dirichlet-multinomial over categorical data
(default gamma 0.5; synthetic data takes --card). The synthetic
dataset generator follows the model kind automatically.

--mu-mode sets the supercluster granularity (all modes are
exactness-preserving; see DESIGN.md §6): \"uniform\" = fixed 1/K (the
paper); \"size-proportional\" = Gibbs-resample mu from its conditional
given supercluster occupancies each round; \"adaptive[:target]\" =
Metropolis-Hastings retarget toward equalized per-shard work (target =
allowed per-shard data share as a multiple of 1/K, default 1.0).

--scorer picks the batched scoring backend the kernel sweeps (and
trace-time evaluation) run through: \"auto\" = PJRT artifacts when
loadable, pure-Rust fallback otherwise; \"fallback\" = always pure
Rust; \"pjrt\" = artifacts required (errors when unavailable).

--overlap on switches the coordinator to barrier-free rounds (see
DESIGN.md section 9): shuffle decisions are staged into a swap buffer,
the alpha/beta/mu updates run on the post-shuffle reduced statistics,
lightly-loaded shards run up to --max-bonus-sweeps extra local sweeps
instead of idling, and the modeled round wall-clock becomes
latency + stats upload + max(map, previous round's hidden tail)
instead of the serialized sum. Off (the default) keeps the pinned
bulk-synchronous reference schedule. Both schedules target the exact
DPM posterior.

--shard-trace writes the per-(round, shard) series (mu_k, occupancy,
cluster count, map seconds, sweep rows/s, idle_s, barrier_wait_s,
bonus_sweeps) that make the adaptive mode, the hot-path throughput,
and the barrier tax observable, and prints a per-round rows/sec +
shuffle-bytes line to stdout. idle_s is the shard's residual wait
against the round's map critical path after any bonus work;
barrier_wait_s is what that wait would have been with no bonus sweeps
(the two columns are equal with --overlap off); bonus_sweeps counts
the round's work-stealing grant (always 0 with --overlap off).

--supervise on makes coordinator rounds fault-tolerant (DESIGN.md
section 12): a shard whose map attempt panics, hits an I/O error, or
stalls past --round-timeout seconds is rebuilt from its pre-round
snapshot and retried with bounded exponential backoff (--retry-backoff
doubling per retry up to --retry-backoff-cap); a retried attempt
replays the identical sweep, so transient faults leave the chain
bit-identical to a fault-free run. After --max-retries the shard is
quarantined for --quarantine-cooldown rounds: its rows keep their
assignments, sweeps are skipped, but its statistics still fold into
the alpha/beta reduces and its clusters still shuffle — then it is
reintegrated automatically. Per-shard retries/watchdog_fires/
quarantined columns appear in --shard-trace. Off (the default) keeps
the legacy behavior bit-exactly: any shard failure aborts the round.

The serial chain checkpoints to the same CCCKPT3 format as the
coordinator: --checkpoint saves the latent state after the last sweep,
--resume continues a saved chain (run with the SAME
--n/--d/--seed/--model so the dataset and likelihood match; mismatches
are rejected, and older CCCKPT2 files load as Beta-Bernoulli). If the
primary file is torn, --resume falls back to the .prev generation the
atomic writer keeps. Checkpoint writes are crash-safe everywhere:
temp file + fsync + rename, prior generation kept as .prev.

--checkpoint-dir keeps a bounded ring of coordinator checkpoint
generations (gen-<round>.ccckpt, at most --checkpoint-keep files,
saved every --checkpoint-every rounds and at exit). When the directory
already holds a loadable generation, the run AUTO-RESUMES from the
newest valid one — torn files from a crash mid-save are skipped with a
warning — so re-launching the same command continues the chain.

serve keeps the chain alive as a long-running service (DESIGN.md
section 13): the sampler refines in a background thread and publishes
an immutable snapshot of the cluster predictive tables at every round
boundary, while client connections answer score / assign / density /
stats queries over a length-prefixed binary protocol on --addr (TCP
host:port, or \"unix:/path\" for a Unix socket) — every answer comes
from some exact posterior sample, never torn mid-sweep, and carries
the round it was sampled at. INSERT/DELETE frames queue row edits that
fold in at the next round boundary. --rounds bounds refinement (0 =
refine until shutdown; serving continues after the budget either way),
--serve-trace appends JSONL latency records (count/p50/p99 per query
kind, queries/sec) every --trace-every rounds and at exit, and the
--checkpoint-dir ring works exactly as in run: periodic + final
generation saves, auto-resume on restart. Stop with a SHUTDOWN frame.
";

/// Shared `--local-kernel` / legacy `--walker` parsing for both entry
/// points. Comma-separated lists cycle kernels over the shards.
fn kernel_arg(args: &Args) -> Result<KernelAssignment, String> {
    match args.get_opt_str("local-kernel")? {
        Some(_) if args.has("walker") => {
            Err("pass either --local-kernel or the legacy --walker, not both".into())
        }
        Some(s) => KernelAssignment::parse(&s),
        None if args.has("walker") => Ok(KernelAssignment::AllSame(KernelKind::WalkerSlice)),
        None => Ok(KernelAssignment::default()),
    }
}

/// The serial chain is a single shard: accept any `--local-kernel`
/// value that names exactly one kernel.
fn serial_kernel_arg(args: &Args) -> Result<KernelKind, String> {
    match kernel_arg(args)? {
        KernelAssignment::AllSame(k) => Ok(k),
        other => Err(format!(
            "the serial chain runs one kernel, got {}",
            other.describe()
        )),
    }
}

/// Shared `--scorer` parsing for both entry points. An explicit
/// `--scorer pjrt` is validated up front so the run fails before any
/// sampling when the backend is unavailable.
fn scorer_arg(args: &Args) -> Result<ScorerKind, String> {
    let kind = ScorerKind::parse(&args.get_str("scorer", "auto")?)?;
    kind.try_build().map_err(|e| format!("--scorer {}: {e}", kind.name()))?;
    Ok(kind)
}

/// Shared `--model` parsing for both samplers: which collapsed
/// component likelihood the chain runs (see DESIGN.md § ComponentModel).
fn model_arg(args: &Args) -> Result<ModelSpec, String> {
    ModelSpec::parse(&args.get_str("model", "bernoulli")?)
}

/// Model-matched synthetic data for both samplers. The Bernoulli path
/// keeps the paper's balanced coin-mixture generator (and its
/// ground-truth entropy target); the Gaussian / categorical paths use
/// the balanced synthetic analogues with a 10% held-out split.
enum SynthData {
    Bin(Box<Dataset>),
    Real { train: RealMat, test: RealMat },
    Cat { train: CatMat, test: CatMat },
}

impl SynthData {
    fn train(&self) -> DataRef<'_> {
        match self {
            SynthData::Bin(ds) => (&ds.train).into(),
            SynthData::Real { train, .. } => train.into(),
            SynthData::Cat { train, .. } => train.into(),
        }
    }

    fn test(&self) -> DataRef<'_> {
        match self {
            SynthData::Bin(ds) => (&ds.test).into(),
            SynthData::Real { test, .. } => test.into(),
            SynthData::Cat { test, .. } => test.into(),
        }
    }

    /// Ground-truth entropy estimate (only the Bernoulli generator
    /// reports one — it is the paper's test-loglik target line).
    fn entropy_target(&self) -> Option<f64> {
        match self {
            SynthData::Bin(ds) => Some(ds.true_entropy_estimate()),
            _ => None,
        }
    }
}

fn gen_model_data(args: &Args, spec: ModelSpec) -> Result<SynthData, String> {
    let n = args.get_usize("n", 5_000)?;
    let d = args.get_usize("d", 64)?;
    let clusters = args.get_usize("clusters", 32)?;
    let seed = args.get_u64("seed", 0)?;
    // the generators shuffle ground truth over rows, so a tail split is
    // an unbiased held-out set
    let n_test = (n / 10).max(1);
    let head: Vec<usize> = (0..n).collect();
    let tail: Vec<usize> = (n..n + n_test).collect();
    Ok(match spec {
        ModelSpec::Bernoulli => SynthData::Bin(Box::new(synth_cfg(args)?.generate())),
        ModelSpec::Gaussian { .. } => {
            let (all, _z) = SyntheticGaussianConfig {
                n: n + n_test,
                d,
                clusters,
                spread: args.get_f64("spread", 3.0)?,
                seed,
            }
            .generate();
            SynthData::Real {
                train: all.select_rows(&head),
                test: all.select_rows(&tail),
            }
        }
        ModelSpec::Categorical { gamma } => {
            let (all, _z) = SyntheticCategoricalConfig {
                n: n + n_test,
                d,
                card: args.get_usize("card", 6)? as u32,
                clusters,
                gamma,
                seed,
            }
            .generate();
            SynthData::Cat {
                train: all.select_rows(&head),
                test: all.select_rows(&tail),
            }
        }
    })
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "serial" => cmd_serial(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "tiny-images" => cmd_tiny_images(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{HELP}")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn synth_cfg(args: &Args) -> Result<SyntheticConfig, String> {
    Ok(SyntheticConfig {
        n: args.get_usize("n", 5_000)?,
        d: args.get_usize("d", 64)?,
        clusters: args.get_usize("clusters", 32)?,
        beta: args.get_f64("beta", 0.1)?,
        seed: args.get_u64("seed", 0)?,
    })
}

fn cmd_gen_data(args: &Args) -> Result<(), String> {
    let cfg = synth_cfg(args)?;
    let out = args.get_str("out", "data.ccbin")?;
    let ds = cfg.generate();
    save_binmat(Path::new(&out), &ds.train, Some(&ds.train_z)).map_err(|e| e.to_string())?;
    println!(
        "wrote {} ({} rows x {} dims, {} true clusters, H≈{:.3} nats)",
        out,
        ds.train.rows(),
        ds.train.dims(),
        cfg.clusters,
        ds.true_entropy_estimate()
    );
    Ok(())
}

fn cmd_serial(args: &Args) -> Result<(), String> {
    let cfg = synth_cfg(args)?;
    let sweeps = args.get_usize("sweeps", 50)?;
    let spec = model_arg(args)?;
    let data = gen_model_data(args, spec)?;
    let mut rng = Pcg64::seed_from(args.get_u64("seed", 0)? ^ 0xc0ffee);
    let scorer_kind = scorer_arg(args)?;
    let scfg = SerialConfig {
        update_beta: args.has("update-beta"),
        kernel: serial_kernel_arg(args)?,
        scoring: ScoreMode::Batched(scorer_kind),
        model: spec,
        ..Default::default()
    };
    let mut g = if let Some(path) = args.get_opt_str("resume")? {
        // a torn primary file falls back to the .prev generation the
        // atomic writer keeps (with a logged warning)
        let (ckpt, _from_prev) =
            Checkpoint::load_with_fallback(Path::new(&path)).map_err(|e| e.to_string())?;
        let g = SerialGibbs::resume(data.train(), scfg, &ckpt, &mut rng)?;
        println!("resumed {path} at sweep {}", g.sweeps_done);
        g
    } else {
        SerialGibbs::init_from_prior(data.train(), scfg, &mut rng)
    };
    let h = data.entropy_target();
    println!(
        "serial baseline: N={} D={} true J={} model={} kernel={} scorer={}{}",
        cfg.n,
        cfg.d,
        cfg.clusters,
        spec.name(),
        scfg.kernel.name(),
        scfg.scoring.name(),
        h.map(|h| format!(" (H≈{h:.3})")).unwrap_or_default()
    );
    let mut trace = McmcTrace::new("serial");
    for it in 0..sweeps {
        g.sweep(&mut rng);
        let sweep_abs = g.sweeps_done - 1; // absolute index across resumes
        let ll = g.predictive_loglik(data.test());
        // cumulative sweep compute time, persisted through checkpoints,
        // so a resumed run's trace keeps a monotone time axis
        let el = g.measured_time_s;
        trace.push(TraceRow {
            iter: sweep_abs,
            modeled_time_s: el,
            measured_time_s: el,
            predictive_loglik: ll,
            num_clusters: g.num_clusters() as u64,
            alpha: g.alpha(),
            bytes: 0,
        });
        if it % 10 == 0 || it + 1 == sweeps {
            println!(
                "  sweep {sweep_abs:>4}: J={:<5} α={:<8.3} test-loglik {ll:.4}{}",
                g.num_clusters(),
                g.alpha(),
                h.map(|h| format!(" (target ≈ {:.4})", -h)).unwrap_or_default()
            );
        }
    }
    if let Some(path) = args.get_opt_str("checkpoint")? {
        g.save_checkpoint(Path::new(&path)).map_err(|e| e.to_string())?;
        println!("checkpoint -> {path} (sweep {})", g.sweeps_done);
    }
    if let Some(path) = args.get_opt_str("trace")? {
        trace.write_csv(Path::new(&path)).map_err(|e| e.to_string())?;
        println!("trace -> {path}");
    }
    Ok(())
}

/// Shared `--supervise` family parsing (`run` and `tiny-images`):
/// the fault-tolerance policy of supervised coordinator rounds.
fn supervise_arg(args: &Args) -> Result<SuperviseConfig, String> {
    let defaults = SuperviseConfig::default();
    let timeout = args.get_f64("round-timeout", 0.0)?;
    if timeout < 0.0 || !timeout.is_finite() {
        return Err(format!("--round-timeout expects seconds >= 0, got {timeout}"));
    }
    let backoff = args.get_f64("retry-backoff", defaults.backoff_base.as_secs_f64())?;
    let backoff_cap =
        args.get_f64("retry-backoff-cap", defaults.backoff_cap.as_secs_f64())?;
    if backoff < 0.0 || backoff_cap < 0.0 || !backoff.is_finite() || !backoff_cap.is_finite()
    {
        return Err("--retry-backoff/--retry-backoff-cap expect seconds >= 0".into());
    }
    Ok(SuperviseConfig {
        enabled: args.get_on_off("supervise", false)?,
        max_retries: args.get_u64("max-retries", defaults.max_retries as u64)? as u32,
        backoff_base: Duration::from_secs_f64(backoff),
        backoff_cap: Duration::from_secs_f64(backoff_cap),
        round_timeout: (timeout > 0.0).then(|| Duration::from_secs_f64(timeout)),
        cooldown_rounds: args.get_u64("quarantine-cooldown", defaults.cooldown_rounds)?,
    })
}

fn coordinator_cfg(args: &Args) -> Result<CoordinatorConfig, String> {
    Ok(CoordinatorConfig {
        workers: args.get_usize("workers", 8)?,
        local_sweeps: args.get_usize("local-sweeps", 1)?,
        update_beta: args.has("update-beta"),
        shuffle: !args.has("no-shuffle"),
        shuffle_kernel: if args.has("eq7") {
            ShuffleKernel::PaperEq7
        } else {
            ShuffleKernel::Exact
        },
        mu_mode: MuMode::parse(&args.get_str("mu-mode", "uniform")?)?,
        kernel_assignment: kernel_arg(args)?,
        scoring: ScoreMode::Batched(scorer_arg(args)?),
        comm: CommModel {
            round_latency_s: args.get_f64("latency", 2.0)?,
            per_worker_latency_s: args.get_f64("worker-latency", 0.05)?,
            bandwidth_bytes_per_s: args.get_f64("bandwidth", 100e6)?,
        },
        parallelism: args.get_usize("threads", 1)?,
        overlap: args.get_on_off("overlap", false)?,
        max_bonus_sweeps: args.get_usize("max-bonus-sweeps", 2)?,
        model: model_arg(args)?,
        supervise: supervise_arg(args)?,
        ..Default::default()
    })
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let cfg = synth_cfg(args)?;
    let ccfg = coordinator_cfg(args)?;
    let rounds = args.get_usize("rounds", 50)?;
    let workers = ccfg.workers;
    let local_sweeps = ccfg.local_sweeps;
    let spec = ccfg.model;
    let kernel_desc = ccfg.kernel_assignment.describe();
    let mu_desc = ccfg.mu_mode.describe();
    let sched_desc = if ccfg.overlap {
        format!("overlapped(max-bonus={})", ccfg.max_bonus_sweeps)
    } else {
        "bulk-synchronous".to_string()
    };
    let data = gen_model_data(args, spec)?;
    let h = data.entropy_target();
    let n_train = data.train().rows();
    let mut rng = Pcg64::seed_from(args.get_u64("seed", 0)? ^ 0xfacade);
    // --checkpoint-dir: bounded generation ring + auto-resume from the
    // newest loadable generation (torn files are skipped with a warning)
    let ckpt_dir = match args.get_opt_str("checkpoint-dir")? {
        Some(d) => Some(
            CheckpointDir::new(&d, args.get_usize("checkpoint-keep", 3)?)
                .map_err(|e| format!("--checkpoint-dir {d}: {e}"))?,
        ),
        None => None,
    };
    let ckpt_every = args.get_u64("checkpoint-every", 10)?;
    let resumed = match ckpt_dir.as_ref() {
        Some(dir) => dir.load_latest_valid().map_err(|e| e.to_string())?,
        None => None,
    };
    let mut coord = match resumed {
        Some((generation, ckpt)) => {
            let c = Coordinator::resume(data.train(), ccfg, &ckpt, &mut rng)?;
            println!(
                "auto-resumed checkpoint generation {generation} (round {})",
                c.rounds
            );
            c
        }
        None => Coordinator::new(data.train(), ccfg, &mut rng),
    };
    // trace-time predictive evaluation runs through the same backend
    // selection as the sweep path
    let mut scorer = scorer_arg(args)?.try_build()?;
    println!(
        "parallel sampler: N={} D={} true J={} model={} | K={workers} workers, {local_sweeps} local sweeps/round, kernel={kernel_desc}, mu-mode={mu_desc}, rounds={sched_desc}, scorer={}{}",
        cfg.n,
        cfg.d,
        cfg.clusters,
        spec.name(),
        scorer.name(),
        h.map(|h| format!(" (H≈{h:.3})")).unwrap_or_default()
    );
    let mut trace = McmcTrace::new(&format!("run_k{workers}"));
    let mut shard_trace = args
        .get_opt_str("shard-trace")?
        .map(|_| ShardTrace::new(&format!("run_k{workers}")));
    for it in 0..rounds {
        let rs = coord.step(&mut rng);
        let ll = coord.predictive_loglik(data.test(), scorer.as_mut());
        trace.push(TraceRow {
            iter: it as u64,
            modeled_time_s: coord.modeled_time_s,
            measured_time_s: coord.measured_time_s,
            predictive_loglik: ll,
            num_clusters: coord.num_clusters() as u64,
            alpha: coord.alpha(),
            bytes: rs.bytes_transferred,
        });
        if let Some(st) = shard_trace.as_mut() {
            for s in coord.shard_stats() {
                st.push(ShardTraceRow {
                    round: it as u64,
                    shard: s.shard as u64,
                    mu: s.mu,
                    rows: s.rows,
                    clusters: s.clusters,
                    map_seconds: s.map_seconds,
                    rows_per_s: s.rows_per_s,
                    idle_s: s.idle_s,
                    barrier_wait_s: s.barrier_wait_s,
                    bonus_sweeps: s.bonus_sweeps,
                    retries: s.retries as u64,
                    watchdog_fires: s.watchdog_fires as u64,
                    quarantined: s.quarantined as u64,
                });
            }
            // per-round throughput + shuffle traffic, so bench numbers
            // are observable in real runs
            let crit = rs.map_critical_path().as_secs_f64();
            let swept = (n_train * local_sweeps) as f64;
            println!(
                "    [shard-trace] round {it}: sweep {:.0} rows/s (map critical path {crit:.4}s), shuffle {} B",
                if crit > 0.0 { swept / crit } else { 0.0 },
                coord.last_shuffle_bytes(),
            );
        }
        if it % 10 == 0 || it + 1 == rounds {
            println!(
                "  round {it:>4}: J={:<5} α={:<8.3} test-loglik {ll:.4} modeled_t {:.2}s{}",
                coord.num_clusters(),
                coord.alpha(),
                coord.modeled_time_s,
                h.map(|h| format!(" (target ≈ {:.4})", -h)).unwrap_or_default()
            );
        }
        if let Some(dir) = ckpt_dir.as_ref() {
            if ckpt_every > 0 && coord.rounds % ckpt_every == 0 {
                dir.save(&Checkpoint::capture(&coord), coord.rounds)
                    .map_err(|e| e.to_string())?;
            }
        }
    }
    if let Some(rate) = coord.mu_acceptance_rate() {
        println!("adaptive μ retarget acceptance: {:.1}%", 100.0 * rate);
    }
    println!("\nphase profile:\n{}", coord.timer.render());
    if let Some(dir) = ckpt_dir.as_ref() {
        let path = dir
            .save(&Checkpoint::capture(&coord), coord.rounds)
            .map_err(|e| e.to_string())?;
        println!("checkpoint generation {} -> {}", coord.rounds, path.display());
    }
    if let Some(path) = args.get_opt_str("checkpoint")? {
        coord
            .save_checkpoint(Path::new(&path))
            .map_err(|e| e.to_string())?;
        println!("checkpoint -> {path}");
    }
    if let Some(path) = args.get_opt_str("trace")? {
        trace.write_csv(Path::new(&path)).map_err(|e| e.to_string())?;
        println!("trace -> {path}");
    }
    if let (Some(st), Some(path)) = (shard_trace.as_ref(), args.get_opt_str("shard-trace")?) {
        st.write_csv(Path::new(&path)).map_err(|e| e.to_string())?;
        println!("shard trace -> {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let spec = model_arg(args)?;
    if !matches!(spec, ModelSpec::Bernoulli) {
        return Err(format!(
            "serve requires --model bernoulli (wire rows are binary), got {}",
            spec.name()
        ));
    }
    let cfg = synth_cfg(args)?;
    let ccfg = coordinator_cfg(args)?;
    let workers = ccfg.workers;
    let ds = cfg.generate();
    let scfg = ServeConfig {
        addr: args.get_str("addr", "127.0.0.1:7878")?,
        rounds: args.get_u64("rounds", 0)?,
        checkpoint_dir: args.get_opt_str("checkpoint-dir")?.map(PathBuf::from),
        checkpoint_every: args.get_u64("checkpoint-every", 10)?,
        checkpoint_keep: args.get_usize("checkpoint-keep", 3)?,
        trace_path: args.get_opt_str("serve-trace")?.map(PathBuf::from),
        trace_every: args.get_u64("trace-every", 0)?,
        seed: args.get_u64("seed", 0)? ^ 0x5e12e,
    };
    let rounds = scfg.rounds;
    let handle = serve::spawn(ds.train, ccfg, scfg)?;
    println!(
        "serving on {} (N={} D={}, K={workers} workers, rounds={}; send a SHUTDOWN frame to stop)",
        handle.addr(),
        cfg.n,
        cfg.d,
        if rounds == 0 { "unbounded".to_string() } else { rounds.to_string() },
    );
    handle.serve_forever()
}

fn cmd_tiny_images(args: &Args) -> Result<(), String> {
    let features = args.get_usize("features", 128)?;
    let tcfg = TinyImagesConfig {
        n: args.get_usize("n", 5_000)?,
        features,
        side: args.get_usize("side", 24)?,
        categories: args.get_usize("categories", 100)?,
        calibration_rows: args.get_usize("calibration", 2_000)?.max(2 * features),
        noise: args.get_f64("noise", 0.6)?,
        seed: args.get_u64("seed", 0)?,
    };
    println!(
        "building tiny-images substitute: {} images, {}x{} px, {} features...",
        tcfg.n, tcfg.side, tcfg.side, tcfg.features
    );
    let corpus = gen_tiny(&tcfg);
    let ccfg = coordinator_cfg(args)?;
    let workers = ccfg.workers;
    let rounds = args.get_usize("rounds", 30)?;
    let mut rng = Pcg64::seed_from(tcfg.seed ^ 0x717);
    let mut coord = Coordinator::new(&corpus.features, ccfg, &mut rng);
    println!("vector quantization with K={workers} workers:");
    for it in 0..rounds {
        coord.step(&mut rng);
        if it % 5 == 0 || it + 1 == rounds {
            println!(
                "  round {it:>4}: J={:<5} α={:<8.3} modeled_t {:.2}s",
                coord.num_clusters(),
                coord.alpha(),
                coord.modeled_time_s
            );
        }
    }
    Ok(())
}
