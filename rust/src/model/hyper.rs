//! Base-measure hyperparameter updates (reduce step): the per-dimension
//! `β_d` of the Beta(β_d, β_d) coin prior, updated by **griddy Gibbs**
//! (Ritter & Tanner 1992) exactly as in the paper's §6: "Our
//! implementation collapsed out the coin weights and updated each β_d
//! during the reduce step using a Griddy Gibbs kernel."
//!
//! The conditional for one dimension given all cluster sufficient
//! statistics {(n_j, c_jd)} is
//!
//! ```text
//!   p(β_d | stats) ∝ p(β_d) · Π_j B(c_jd + β_d, n_j − c_jd + β_d) / B(β_d, β_d)
//! ```
//!
//! which depends on the clusters only through (n_j, c_jd) — exactly what
//! the mappers transmit (Fig. 3's "sufficient statistics").

use crate::rng::{GriddyGibbs, Pcg64};
use crate::special::log_beta;

/// Per-dimension sufficient statistics pooled across superclusters:
/// (cluster size n_j, one-count c_jd).
pub type DimStats = Vec<(u64, u32)>;

/// Log conditional (up to a constant) of β for one dimension.
/// `prior_logpdf` is the log prior density on β (e.g. lognormal/gamma).
pub fn log_beta_conditional(
    beta: f64,
    stats: &[(u64, u32)],
    prior_logpdf: impl Fn(f64) -> f64,
) -> f64 {
    if beta <= 0.0 {
        return f64::NEG_INFINITY;
    }
    let lb0 = log_beta(beta, beta);
    let mut s = prior_logpdf(beta);
    for &(n, c) in stats {
        let c = c as f64;
        let n = n as f64;
        s += log_beta(c + beta, n - c + beta) - lb0;
    }
    s
}

/// Configuration for the griddy-Gibbs β updates.
#[derive(Debug, Clone, Copy)]
pub struct BetaGridConfig {
    /// smallest grid value
    pub lo: f64,
    /// largest grid value
    pub hi: f64,
    /// number of log-spaced grid points
    pub points: usize,
}

impl Default for BetaGridConfig {
    fn default() -> Self {
        // the paper's coins live between strongly-deterministic (β≪1)
        // and uniform (β=1); give headroom either side
        BetaGridConfig {
            lo: 1e-2,
            hi: 10.0,
            points: 24,
        }
    }
}

/// Reusable β_d updater: one griddy kernel shared across dims.
pub struct BetaUpdater {
    grid: GriddyGibbs,
}

impl BetaUpdater {
    /// Updater over the configured log-spaced grid.
    pub fn new(cfg: BetaGridConfig) -> Self {
        BetaUpdater {
            grid: GriddyGibbs::log_spaced(cfg.lo, cfg.hi, cfg.points),
        }
    }

    /// Sample β_d | stats with a flat-in-log prior (the scale-invariant
    /// reference prior; proper on the bounded grid).
    pub fn sample(&mut self, rng: &mut Pcg64, stats: &[(u64, u32)]) -> f64 {
        self.grid
            .sample(rng, |b| log_beta_conditional(b, stats, |x| -x.ln()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{beta as rbeta, Pcg64};
    use crate::util::mean;

    /// Simulate clusters whose coins come from Beta(β*, β*) and check the
    /// update concentrates near β*.
    fn posterior_mean_for_true_beta(true_beta: f64, seed: u64) -> f64 {
        let mut rng = Pcg64::seed_from(seed);
        // 60 clusters, 40 data each, one dimension
        let mut stats: Vec<(u64, u32)> = Vec::new();
        for _ in 0..60 {
            let p = rbeta(&mut rng, true_beta, true_beta);
            let n = 40u64;
            let mut c = 0u32;
            for _ in 0..n {
                if rng.next_f64() < p {
                    c += 1;
                }
            }
            stats.push((n, c));
        }
        let mut upd = BetaUpdater::new(BetaGridConfig::default());
        let draws: Vec<f64> = (0..800).map(|_| upd.sample(&mut rng, &stats)).collect();
        mean(&draws)
    }

    #[test]
    fn recovers_small_beta() {
        let m = posterior_mean_for_true_beta(0.1, 1);
        assert!(m > 0.03 && m < 0.35, "posterior mean {m} for β*=0.1");
    }

    #[test]
    fn recovers_large_beta() {
        let m = posterior_mean_for_true_beta(3.0, 2);
        assert!(m > 1.2 && m < 9.0, "posterior mean {m} for β*=3.0");
    }

    #[test]
    fn separates_regimes() {
        let small = posterior_mean_for_true_beta(0.05, 3);
        let large = posterior_mean_for_true_beta(2.0, 4);
        assert!(small < large, "β̂(0.05)={small} should be < β̂(2.0)={large}");
    }

    #[test]
    fn conditional_rejects_nonpositive() {
        assert_eq!(
            log_beta_conditional(0.0, &[(5, 2)], |_| 0.0),
            f64::NEG_INFINITY
        );
        assert_eq!(
            log_beta_conditional(-1.0, &[(5, 2)], |_| 0.0),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn empty_stats_returns_prior() {
        // with no clusters the conditional is just the prior
        let v = log_beta_conditional(0.5, &[], |x| -2.0 * x);
        assert!((v - (-1.0)).abs() < 1e-12);
    }
}
