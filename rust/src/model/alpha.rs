//! The concentration-parameter conditional, Eq. 6 of the paper:
//!
//! ```text
//!   p(α | {z}) ∝ p(α) · Γ(α)/Γ(N+α) · α^{Σ_k J_k}
//! ```
//!
//! A remarkable property of the supercluster representation (Eq. 5) is
//! that this is the SAME conditional as for a plain CRP — only the total
//! number of extant clusters `Σ_k J_k` enters. The update is centralized
//! but lightweight: each worker communicates one integer. Sampled by
//! slice sampling (the paper's suggestion).

use crate::rng::{slice_sample, Pcg64};
use crate::special::lgamma;

/// Gamma(shape, rate) prior on α.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GammaPrior {
    /// shape parameter a
    pub shape: f64,
    /// rate parameter b (mean = a/b)
    pub rate: f64,
}

impl Default for GammaPrior {
    fn default() -> Self {
        // weakly informative: mean 1, variance 1
        GammaPrior {
            shape: 1.0,
            rate: 1.0,
        }
    }
}

impl GammaPrior {
    /// Log density at `x` (−∞ for x ≤ 0).
    pub fn logpdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return f64::NEG_INFINITY;
        }
        (self.shape - 1.0) * x.ln() - self.rate * x
    }
}

/// Log of Eq. 6 (up to a constant): `ln p(α) + lnΓ(α) − lnΓ(N+α) + J·ln α`.
pub fn log_alpha_conditional(alpha: f64, n: u64, total_clusters: u64, prior: &GammaPrior) -> f64 {
    if alpha <= 0.0 {
        return f64::NEG_INFINITY;
    }
    prior.logpdf(alpha) + lgamma(alpha) - lgamma(n as f64 + alpha)
        + total_clusters as f64 * alpha.ln()
}

/// One slice-sampling transition for α given (N, ΣJ_k). Operates on
/// ln α (scale parameter ⇒ log parameterization mixes far better), with
/// the Jacobian term `+ln α` included.
pub fn sample_alpha(
    rng: &mut Pcg64,
    current: f64,
    n: u64,
    total_clusters: u64,
    prior: &GammaPrior,
) -> f64 {
    let logf = |la: f64| {
        let a = la.exp();
        log_alpha_conditional(a, n, total_clusters, prior) + la // Jacobian
    };
    let la = slice_sample(rng, logf, current.ln(), 1.0, 64, (-40.0, 40.0));
    la.exp()
}

/// Grid quadrature of the normalized posterior p(α | z) on a log-spaced
/// grid — used to regenerate Fig. 2b exactly (no Monte-Carlo noise).
pub fn alpha_posterior_grid(
    n: u64,
    total_clusters: u64,
    prior: &GammaPrior,
    lo: f64,
    hi: f64,
    points: usize,
) -> (Vec<f64>, Vec<f64>) {
    assert!(points >= 2 && lo > 0.0 && hi > lo);
    let (ll, lh) = (lo.ln(), hi.ln());
    let grid: Vec<f64> = (0..points)
        .map(|i| (ll + (lh - ll) * i as f64 / (points - 1) as f64).exp())
        .collect();
    // density on the log grid (with Jacobian α for measure dα = α d lnα)
    let mut logp: Vec<f64> = grid
        .iter()
        .map(|&a| log_alpha_conditional(a, n, total_clusters, prior) + a.ln())
        .collect();
    crate::special::exp_normalize(&mut logp);
    (grid, logp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    #[test]
    fn conditional_is_finite_and_peaked() {
        let prior = GammaPrior::default();
        let f = |a: f64| log_alpha_conditional(a, 1000, 50, &prior);
        assert!(f(1.0).is_finite() && f(10.0).is_finite());
        assert_eq!(f(-1.0), f64::NEG_INFINITY);
        // more clusters ⇒ conditional prefers larger α:
        // compare where the density puts relative mass
        let small_j = log_alpha_conditional(20.0, 1000, 10, &prior)
            - log_alpha_conditional(2.0, 1000, 10, &prior);
        let big_j = log_alpha_conditional(20.0, 1000, 200, &prior)
            - log_alpha_conditional(2.0, 1000, 200, &prior);
        assert!(big_j > small_j);
    }

    #[test]
    fn sampler_tracks_cluster_count() {
        // With many clusters the posterior concentrates at large α; with
        // few clusters at small α. Check the sampled means are ordered
        // and in sensible ranges.
        let prior = GammaPrior {
            shape: 1.0,
            rate: 0.1,
        };
        let run = |j: u64, seed: u64| {
            let mut rng = Pcg64::seed_from(seed);
            let mut a = 1.0;
            let mut xs = Vec::new();
            for i in 0..6000 {
                a = sample_alpha(&mut rng, a, 10_000, j, &prior);
                if i > 1000 {
                    xs.push(a);
                }
            }
            mean(&xs)
        };
        let low = run(5, 1);
        let high = run(500, 2);
        assert!(low < high, "E[α|J=5] = {low} should be < E[α|J=500] = {high}");
        assert!(low > 0.05 && low < 5.0, "low {low}");
        assert!(high > 30.0 && high < 500.0, "high {high}");
    }

    #[test]
    fn grid_posterior_normalizes_and_orders() {
        let prior = GammaPrior::default();
        let (grid, p) = alpha_posterior_grid(100_000, 128, &prior, 0.1, 1000.0, 200);
        assert_eq!(grid.len(), 200);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // posterior mean for J=128, N=100k sits roughly near α where
        // J ≈ α ln(1 + N/α); sanity: between 5 and 60
        let m: f64 = grid.iter().zip(&p).map(|(&g, &q)| g * q).sum();
        assert!(m > 5.0 && m < 60.0, "posterior mean {m}");
    }

    #[test]
    fn more_clusters_shift_grid_posterior_right() {
        let prior = GammaPrior::default();
        let mean_for = |j: u64| {
            let (grid, p) = alpha_posterior_grid(1_000_000, j, &prior, 0.01, 10_000.0, 400);
            grid.iter().zip(&p).map(|(&g, &q)| g * q).sum::<f64>()
        };
        // the Fig. 2b trend: 128 → 2048 clusters increases α
        assert!(mean_for(128) < mean_for(512));
        assert!(mean_for(512) < mean_for(2048));
    }
}
