//! The paper's observation model (§6): product-Bernoulli components with
//! per-dimension `Beta(β_d, β_d)` priors, coin weights collapsed out.
//!
//! * [`BetaBernoulli`] — the model spec (dimensionality + β vector).
//! * [`ClusterStats`] — a cluster's sufficient statistics with a cached
//!   log-predictive table (`bias + Σ_{d: x_d=1} diff[d]`) — the Layer-3
//!   hot path; caches invalidate on count or hyperparameter change.
//! * [`alpha`] — the concentration conditional (Eq. 6) and its slice-
//!   sampling update.
//! * [`hyper`] — the `β_d` griddy-Gibbs update from pooled sufficient
//!   statistics (reduce step).

pub mod alpha;
pub mod hyper;

use crate::data::BinMat;
use crate::special::log_beta;

/// Log lookup table for symmetric-β scoring-cache rebuilds: `ln(x + β)`
/// and `ln(x + 2β)` indexed by integer count. Rebuilding a cluster's
/// predictive table is the per-datum hot cost of the Gibbs sweep (two
/// rebuilds per move, O(D) `ln` calls each); with a uniform β the
/// transcendentals become array lookups (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, PartialEq)]
pub struct LogLut {
    beta: f64,
    ln_xb: Vec<f64>,
    ln_n2b: Vec<f64>,
}

impl LogLut {
    /// Table of `ln(x + β)` for x in 0..=n_max.
    pub fn new(beta: f64, n_max: usize) -> LogLut {
        LogLut {
            beta,
            ln_xb: (0..=n_max).map(|x| (x as f64 + beta).ln()).collect(),
            ln_n2b: (0..=n_max).map(|x| (x as f64 + 2.0 * beta).ln()).collect(),
        }
    }

    /// Largest count the table covers.
    pub fn max_count(&self) -> usize {
        self.ln_xb.len().saturating_sub(1)
    }

    /// Grow the table to cover counts up to `n_max`, at least doubling
    /// the capacity so repeated one-step growth is amortized O(1)
    /// (instead of the old full-table rebuild per overflow).
    pub fn ensure(&mut self, n_max: usize) {
        let cur = self.ln_xb.len();
        if n_max < cur {
            return;
        }
        let target = (n_max + 1).max(cur.saturating_mul(2));
        self.ln_xb.reserve(target - cur);
        self.ln_n2b.reserve(target - cur);
        for x in cur..target {
            self.ln_xb.push((x as f64 + self.beta).ln());
            self.ln_n2b.push((x as f64 + 2.0 * self.beta).ln());
        }
    }

    /// Re-point the table at a new symmetric β, recomputing entries in
    /// place (reusing the allocation). A refresh to the *same* β — the
    /// common case when griddy Gibbs re-draws the same grid point every
    /// sweep — is free, so hyper refreshes no longer thrash the cache.
    pub fn retarget(&mut self, beta: f64) {
        if beta.to_bits() == self.beta.to_bits() {
            return;
        }
        self.beta = beta;
        for (x, slot) in self.ln_xb.iter_mut().enumerate() {
            *slot = (x as f64 + beta).ln();
        }
        for (x, slot) in self.ln_n2b.iter_mut().enumerate() {
            *slot = (x as f64 + 2.0 * beta).ln();
        }
    }

    #[inline]
    fn covers(&self, beta: f64, n: u64) -> bool {
        beta == self.beta && (n as usize) < self.ln_xb.len()
    }
}

/// Model spec: binary dimensionality and per-dimension symmetric Beta
/// hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct BetaBernoulli {
    /// data dimensionality D
    pub d: usize,
    /// per-dimension Beta(β_d, β_d) hyperparameters
    pub beta: Vec<f64>,
    /// fast-rebuild LUT; valid only while β is uniform across dims
    lut: Option<LogLut>,
}

impl BetaBernoulli {
    /// Symmetric spec: β_d = β for all d.
    pub fn symmetric(d: usize, beta: f64) -> Self {
        assert!(beta > 0.0);
        BetaBernoulli {
            d,
            beta: vec![beta; d],
            lut: None,
        }
    }

    /// Install (or refresh) the symmetric-β log LUT covering counts up
    /// to `n_max`. An existing table is retargeted/grown in place —
    /// allocation is reused, and a same-β refresh is free.
    pub fn build_lut(&mut self, n_max: usize) {
        let b0 = self.beta[0];
        if !self.beta.iter().all(|&b| b == b0) {
            self.lut = None;
            return;
        }
        match &mut self.lut {
            Some(lut) => {
                lut.retarget(b0);
                lut.ensure(n_max);
            }
            None => self.lut = Some(LogLut::new(b0, n_max)),
        }
    }

    /// Invalidate the LUT (β no longer uniform).
    pub fn drop_lut(&mut self) {
        self.lut = None;
    }

    /// Install freshly sampled per-dimension β values; returns whether
    /// anything actually changed (callers skip cache invalidation when
    /// the griddy update re-drew the incumbent grid points). If the new
    /// values are still uniform the LUT is retargeted rather than
    /// dropped.
    pub fn update_betas(&mut self, new_beta: &[f64], n_max: usize) -> bool {
        assert_eq!(new_beta.len(), self.d);
        let changed = self
            .beta
            .iter()
            .zip(new_beta)
            .any(|(a, b)| a.to_bits() != b.to_bits());
        if !changed {
            return false;
        }
        self.beta.copy_from_slice(new_beta);
        self.build_lut(n_max);
        true
    }

    /// Log predictive of a fresh (empty) cluster for ANY datum: with a
    /// symmetric Beta(β_d, β_d) prior the predictive coin is 1/2 per dim,
    /// so the score is a constant −D·ln 2 regardless of x or β.
    pub fn empty_cluster_loglik(&self) -> f64 {
        -(self.d as f64) * std::f64::consts::LN_2
    }
}

/// Sufficient statistics for one cluster: datum count `n` and per-dim
/// one-counts, plus the cached scoring table.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    n: u64,
    ones: Vec<u32>,
    /// cache: bias = Σ_d log p̂0_d ; diff[d] = log p̂1_d − log p̂0_d
    cache_bias: f64,
    cache_diff: Vec<f64>,
    cache_valid: bool,
    /// ln(n), maintained incrementally (perf: the Gibbs hot loop reads
    /// it once per cluster per datum — see EXPERIMENTS.md §Perf)
    log_n: f64,
}

impl ClusterStats {
    /// Stats of an empty cluster over `d` dims.
    pub fn empty(d: usize) -> Self {
        ClusterStats {
            n: 0,
            ones: vec![0; d],
            cache_bias: 0.0,
            cache_diff: vec![0.0; d],
            cache_valid: false,
            log_n: f64::NEG_INFINITY,
        }
    }

    /// Member count n_j.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// ln(n) without a transcendental call on the hot path.
    #[inline]
    pub fn log_n(&self) -> f64 {
        self.log_n
    }

    /// Per-dimension one-counts c_jd.
    pub fn ones(&self) -> &[u32] {
        &self.ones
    }

    /// Whether the cluster has no members.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Add datum (row `r` of `data`) to the cluster.
    pub fn add(&mut self, data: &BinMat, r: usize) {
        self.n += 1;
        self.log_n = (self.n as f64).ln();
        data.for_each_one(r, |d| self.ones[d] += 1);
        self.cache_valid = false;
    }

    /// Remove datum from the cluster (must have been added).
    pub fn remove(&mut self, data: &BinMat, r: usize) {
        debug_assert!(self.n > 0, "remove from empty cluster");
        self.n -= 1;
        self.log_n = if self.n == 0 {
            f64::NEG_INFINITY
        } else {
            (self.n as f64).ln()
        };
        data.for_each_one(r, |d| {
            debug_assert!(self.ones[d] > 0, "one-count underflow at dim {d}");
            self.ones[d] -= 1;
        });
        self.cache_valid = false;
    }

    /// Overwrite this cluster's statistics with a copy of `other`'s,
    /// reusing the existing allocations (unlike `clone`, which allocates
    /// fresh count vectors). The split–merge kernel scores each merge
    /// proposal's union marginal on a persistent scratch through this,
    /// keeping the move layer allocation-free after warm-up. The cached
    /// scoring table is NOT copied — it invalidates, to be rebuilt
    /// lazily on first score.
    ///
    /// # Panics
    ///
    /// Panics if the two stats have different dimensionality.
    pub fn copy_from(&mut self, other: &ClusterStats) {
        assert_eq!(self.ones.len(), other.ones.len(), "dims mismatch");
        self.n = other.n;
        self.log_n = other.log_n;
        self.ones.copy_from_slice(&other.ones);
        self.cache_valid = false;
    }

    /// Merge another cluster's statistics into this one (shuffle moves).
    pub fn absorb(&mut self, other: &ClusterStats) {
        assert_eq!(self.ones.len(), other.ones.len());
        self.n += other.n;
        self.log_n = (self.n as f64).ln();
        for (a, b) in self.ones.iter_mut().zip(&other.ones) {
            *a += *b;
        }
        self.cache_valid = false;
    }

    /// Rebuild the cached log-predictive table for the current counts and
    /// hyperparameters. O(D); called lazily from [`Self::score`]. With a
    /// uniform β the `ln` calls become LUT lookups:
    /// `diff[d] = ln(c_d+β) − ln(n−c_d+β)`,
    /// `bias = Σ_d ln(n−c_d+β) − D·ln(n+2β)`.
    fn rebuild_cache(&mut self, model: &BetaBernoulli) {
        if let Some(lut) = &model.lut {
            if lut.covers(model.beta[0], self.n) {
                let n = self.n as usize;
                let ln_xb = &lut.ln_xb;
                let mut bias = 0.0;
                for d in 0..model.d {
                    let c = self.ones[d] as usize;
                    let l1 = ln_xb[c];
                    let l0 = ln_xb[n - c];
                    bias += l0;
                    self.cache_diff[d] = l1 - l0;
                }
                self.cache_bias = bias - model.d as f64 * lut.ln_n2b[n];
                self.cache_valid = true;
                return;
            }
        }
        let nf = self.n as f64;
        let mut bias = 0.0;
        for d in 0..model.d {
            let b = model.beta[d];
            let denom = nf + 2.0 * b;
            let p1 = (self.ones[d] as f64 + b) / denom;
            let p0 = (nf - self.ones[d] as f64 + b) / denom;
            let l1 = p1.ln();
            let l0 = p0.ln();
            bias += l0;
            self.cache_diff[d] = l1 - l0;
        }
        self.cache_bias = bias;
        self.cache_valid = true;
    }

    /// Explicitly invalidate the cache (hyperparameters changed).
    pub fn invalidate_cache(&mut self) {
        self.cache_valid = false;
    }

    /// The cached predictive table `(bias, diff)` for the current counts
    /// and hyperparameters, rebuilding it first if stale. This is what
    /// the batched sweep path copies into its packed `[D, J]` columns,
    /// so batched and scalar scoring read the *same* table bits.
    pub fn cached_table(&mut self, model: &BetaBernoulli) -> (f64, &[f64]) {
        if !self.cache_valid {
            self.rebuild_cache(model);
        }
        (self.cache_bias, &self.cache_diff)
    }

    /// Log predictive likelihood of row `r` under this cluster
    /// (collapsed): `Σ_d log p̂(x_d)`. Uses the cached table — O(#ones)
    /// after an O(D) rebuild.
    pub fn score(&mut self, model: &BetaBernoulli, data: &BinMat, r: usize) -> f64 {
        if !self.cache_valid {
            self.rebuild_cache(model);
        }
        let mut s = self.cache_bias;
        let diff = &self.cache_diff;
        data.for_each_one(r, |d| s += diff[d]);
        s
    }

    /// Score from a pre-decoded ones-index list (the Gibbs hot loop
    /// decodes each datum's bits once and scores all local clusters from
    /// the same list — see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn score_ones(&mut self, model: &BetaBernoulli, ones_idx: &[u32]) -> f64 {
        if !self.cache_valid {
            self.rebuild_cache(model);
        }
        let diff = &self.cache_diff;
        let mut s = self.cache_bias;
        for &d in ones_idx {
            s += diff[d as usize];
        }
        s
    }

    /// Uncached reference scoring (tests + failure injection).
    pub fn score_uncached(&self, model: &BetaBernoulli, data: &BinMat, r: usize) -> f64 {
        let nf = self.n as f64;
        let mut s = 0.0;
        for d in 0..model.d {
            let b = model.beta[d];
            let denom = nf + 2.0 * b;
            let p = if data.get(r, d) {
                (self.ones[d] as f64 + b) / denom
            } else {
                (nf - self.ones[d] as f64 + b) / denom
            };
            s += p.ln();
        }
        s
    }

    /// Collapsed log marginal likelihood of the whole cluster:
    /// `Σ_d [ln B(c_d+β_d, n−c_d+β_d) − ln B(β_d, β_d)]`.
    pub fn log_marginal(&self, model: &BetaBernoulli) -> f64 {
        let nf = self.n as f64;
        let mut s = 0.0;
        for d in 0..model.d {
            let b = model.beta[d];
            let c = self.ones[d] as f64;
            s += log_beta(c + b, nf - c + b) - log_beta(b, b);
        }
        s
    }

    /// Predictive Bernoulli parameters p̂_1 per dim (f32, for the PJRT
    /// artifact weight matrices).
    pub fn predictive_p1(&self, model: &BetaBernoulli, out: &mut [f32]) {
        assert_eq!(out.len(), model.d);
        let nf = self.n as f64;
        for d in 0..model.d {
            let b = model.beta[d];
            out[d] = ((self.ones[d] as f64 + b) / (nf + 2.0 * b)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> BinMat {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.4 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    #[test]
    fn add_remove_roundtrip_restores_stats() {
        let data = rand_data(10, 33, 1);
        let model = BetaBernoulli::symmetric(33, 0.5);
        let mut c = ClusterStats::empty(33);
        for r in 0..10 {
            c.add(&data, r);
        }
        let before_n = c.n();
        let before_ones = c.ones().to_vec();
        let before_score = c.score(&model, &data, 0);
        c.add(&data, 3);
        c.remove(&data, 3);
        assert_eq!(c.n(), before_n);
        assert_eq!(c.ones(), &before_ones[..]);
        assert!((c.score(&model, &data, 0) - before_score).abs() < 1e-12);
    }

    #[test]
    fn cached_score_matches_uncached() {
        let data = rand_data(20, 65, 2);
        let model = BetaBernoulli::symmetric(65, 0.3);
        let mut c = ClusterStats::empty(65);
        for r in 0..12 {
            c.add(&data, r);
        }
        for r in 0..20 {
            let cached = c.score(&model, &data, r);
            let plain = c.score_uncached(&model, &data, r);
            assert!(
                (cached - plain).abs() < 1e-10,
                "row {r}: {cached} vs {plain}"
            );
        }
    }

    #[test]
    fn empty_cluster_score_is_neg_d_ln2() {
        let data = rand_data(3, 17, 3);
        let model = BetaBernoulli::symmetric(17, 0.7);
        let mut c = ClusterStats::empty(17);
        let want = model.empty_cluster_loglik();
        for r in 0..3 {
            assert!((c.score(&model, &data, r) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn cache_invalidates_on_hyper_change() {
        let data = rand_data(8, 9, 4);
        let mut model = BetaBernoulli::symmetric(9, 0.5);
        let mut c = ClusterStats::empty(9);
        for r in 0..8 {
            c.add(&data, r);
        }
        let s_before = c.score(&model, &data, 0);
        model.beta = vec![2.0; 9];
        c.invalidate_cache();
        let s_after = c.score(&model, &data, 0);
        assert!((s_after - c.score_uncached(&model, &data, 0)).abs() < 1e-10);
        assert!((s_before - s_after).abs() > 1e-6, "score must respond to β");
    }

    #[test]
    fn log_marginal_matches_sequential_predictives() {
        // chain rule: log m(x_1..x_n) = Σ_i log p(x_i | x_<i)
        let data = rand_data(6, 21, 5);
        let model = BetaBernoulli::symmetric(21, 0.4);
        let mut c = ClusterStats::empty(21);
        let mut chain = 0.0;
        for r in 0..6 {
            chain += c.score(&model, &data, r);
            c.add(&data, r);
        }
        let marginal = c.log_marginal(&model);
        assert!(
            (chain - marginal).abs() < 1e-8,
            "chain {chain} vs marginal {marginal}"
        );
    }

    #[test]
    fn copy_from_duplicates_stats_and_invalidates_cache() {
        let data = rand_data(12, 15, 9);
        let model = BetaBernoulli::symmetric(15, 0.5);
        let mut src = ClusterStats::empty(15);
        for r in 0..7 {
            src.add(&data, r);
        }
        let mut dst = ClusterStats::empty(15);
        for r in 7..12 {
            dst.add(&data, r);
        }
        let _ = dst.score(&model, &data, 0); // warm dst's cache with stale stats
        dst.copy_from(&src);
        assert_eq!(dst.n(), src.n());
        assert_eq!(dst.ones(), src.ones());
        assert_eq!(dst.log_n().to_bits(), src.log_n().to_bits());
        // the cache was invalidated: scores come from the copied stats
        for r in 0..12 {
            let got = dst.score(&model, &data, r);
            let want = src.score_uncached(&model, &data, r);
            assert!((got - want).abs() < 1e-10, "row {r}: {got} vs {want}");
        }
    }

    #[test]
    fn absorb_equals_adding_all_rows() {
        let data = rand_data(10, 15, 6);
        let mut a = ClusterStats::empty(15);
        let mut b = ClusterStats::empty(15);
        for r in 0..5 {
            a.add(&data, r);
        }
        for r in 5..10 {
            b.add(&data, r);
        }
        a.absorb(&b);
        let mut all = ClusterStats::empty(15);
        for r in 0..10 {
            all.add(&data, r);
        }
        assert_eq!(a.n(), all.n());
        assert_eq!(a.ones(), all.ones());
    }

    #[test]
    fn lut_grows_geometrically_and_retargets() {
        let mut lut = LogLut::new(0.5, 10);
        assert_eq!(lut.max_count(), 10);
        lut.ensure(11); // one past the end: must at least double
        assert!(lut.max_count() >= 21, "got {}", lut.max_count());
        let before = lut.max_count();
        lut.ensure(5); // already covered: no-op
        assert_eq!(lut.max_count(), before);
        assert!(lut.covers(0.5, before as u64));
        assert!(!lut.covers(0.5, before as u64 + 1));
        // retarget to a new β recomputes entries in place
        lut.retarget(2.0);
        assert!(lut.covers(2.0, 3));
        assert!(!lut.covers(0.5, 3));
        let fresh = LogLut::new(2.0, lut.max_count());
        assert_eq!(lut.ln_xb, fresh.ln_xb);
        assert_eq!(lut.ln_n2b, fresh.ln_n2b);
    }

    #[test]
    fn lut_backed_score_correct_after_growth() {
        let data = rand_data(30, 9, 8);
        let mut model = BetaBernoulli::symmetric(9, 0.5);
        model.build_lut(5); // deliberately too small for 30 rows
        let mut c = ClusterStats::empty(9);
        for r in 0..30 {
            c.add(&data, r);
        }
        // count 30 exceeds the table: must fall back to the slow path
        let slow = c.score(&model, &data, 0);
        assert!((slow - c.score_uncached(&model, &data, 0)).abs() < 1e-10);
        // grow, invalidate, rescore through the LUT: same number
        model.build_lut(31);
        c.invalidate_cache();
        let fast = c.score(&model, &data, 0);
        assert!((fast - slow).abs() < 1e-10, "{fast} vs {slow}");
    }

    #[test]
    fn update_betas_reports_change_and_keeps_symmetric_lut() {
        let mut model = BetaBernoulli::symmetric(4, 0.5);
        model.build_lut(16);
        // same values: no change, LUT untouched
        assert!(!model.update_betas(&[0.5; 4], 16));
        assert!(model.lut.is_some());
        // new symmetric values: change reported, LUT retargeted not dropped
        assert!(model.update_betas(&[0.25; 4], 16));
        let lut = model.lut.as_ref().expect("symmetric refresh keeps LUT");
        assert!(lut.covers(0.25, 10));
        // asymmetric values: LUT dropped
        assert!(model.update_betas(&[0.25, 0.5, 0.25, 0.25], 16));
        assert!(model.lut.is_none());
    }

    #[test]
    fn predictive_p1_in_unit_interval() {
        let data = rand_data(30, 12, 7);
        let model = BetaBernoulli::symmetric(12, 0.1);
        let mut c = ClusterStats::empty(12);
        for r in 0..30 {
            c.add(&data, r);
        }
        let mut p = vec![0.0f32; 12];
        c.predictive_p1(&model, &mut p);
        assert!(p.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}
