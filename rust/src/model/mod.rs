//! Likelihood layer: the [`ComponentModel`] trait that makes the sampler
//! stack generic over the observation model, its three collapsed
//! implementations, and the per-cluster sufficient statistics.
//!
//! * [`ComponentModel`] — sufficient-stat cache rebuild, collapsed log
//!   marginal, per-datum log predictive, and packed-table export,
//!   abstracted over the likelihood. The kernel, shard and coordinator
//!   layers only talk to this surface (through [`Model`]), so the μ
//!   modes, overlap schedule and transition kernels are untouched by
//!   construction when a new likelihood is added.
//! * [`BetaBernoulli`] — the paper's observation model (§6):
//!   product-Bernoulli components with per-dimension `Beta(β_d, β_d)`
//!   priors, coin weights collapsed out.
//! * [`DiagGaussian`] — collapsed diagonal Gaussian with a shared
//!   Normal–Inverse-Gamma prior per dimension (Student-t predictives).
//! * [`Categorical`] — Dirichlet–multinomial over per-dimension finite
//!   alphabets, scored through the one-hot bit-sparse path so scalar
//!   and batched scoring stay bit-identical by construction.
//! * [`Model`] — enum dispatcher over the three (concrete access for
//!   owners that need Bernoulli-specific surface: the β griddy update
//!   and the PJRT weight export).
//! * [`ModelSpec`] — a `Copy` model selector + hyperparameters for
//!   configs, CLI parsing (`--model`) and checkpoint tagging.
//! * [`ClusterStats`] — a cluster's sufficient statistics (count,
//!   one-counts, first/second moments) with a cached log-predictive
//!   table — the Layer-3 hot path; caches invalidate on count or
//!   hyperparameter change.
//! * [`alpha`] — the concentration conditional (Eq. 6) and its slice-
//!   sampling update.
//! * [`hyper`] — the `β_d` griddy-Gibbs update from pooled sufficient
//!   statistics (reduce step; Bernoulli only).

pub mod alpha;
pub mod hyper;

use crate::data::{BinMat, DataRef};
use crate::special::{lgamma, lgamma_ratio, log_beta};

/// Log lookup table for symmetric-β scoring-cache rebuilds: `ln(x + β)`
/// and `ln(x + 2β)` indexed by integer count. Rebuilding a cluster's
/// predictive table is the per-datum hot cost of the Gibbs sweep (two
/// rebuilds per move, O(D) `ln` calls each); with a uniform β the
/// transcendentals become array lookups (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, PartialEq)]
pub struct LogLut {
    beta: f64,
    ln_xb: Vec<f64>,
    ln_n2b: Vec<f64>,
}

impl LogLut {
    /// Table of `ln(x + β)` for x in 0..=n_max.
    pub fn new(beta: f64, n_max: usize) -> LogLut {
        LogLut {
            beta,
            ln_xb: (0..=n_max).map(|x| (x as f64 + beta).ln()).collect(),
            ln_n2b: (0..=n_max).map(|x| (x as f64 + 2.0 * beta).ln()).collect(),
        }
    }

    /// Largest count the table covers.
    pub fn max_count(&self) -> usize {
        self.ln_xb.len().saturating_sub(1)
    }

    /// Grow the table to cover counts up to `n_max`, at least doubling
    /// the capacity so repeated one-step growth is amortized O(1)
    /// (instead of the old full-table rebuild per overflow).
    pub fn ensure(&mut self, n_max: usize) {
        let cur = self.ln_xb.len();
        if n_max < cur {
            return;
        }
        let target = (n_max + 1).max(cur.saturating_mul(2));
        self.ln_xb.reserve(target - cur);
        self.ln_n2b.reserve(target - cur);
        for x in cur..target {
            self.ln_xb.push((x as f64 + self.beta).ln());
            self.ln_n2b.push((x as f64 + 2.0 * self.beta).ln());
        }
    }

    /// Re-point the table at a new symmetric β, recomputing entries in
    /// place (reusing the allocation). A refresh to the *same* β — the
    /// common case when griddy Gibbs re-draws the same grid point every
    /// sweep — is free, so hyper refreshes no longer thrash the cache.
    pub fn retarget(&mut self, beta: f64) {
        if beta.to_bits() == self.beta.to_bits() {
            return;
        }
        self.beta = beta;
        for (x, slot) in self.ln_xb.iter_mut().enumerate() {
            *slot = (x as f64 + beta).ln();
        }
        for (x, slot) in self.ln_n2b.iter_mut().enumerate() {
            *slot = (x as f64 + 2.0 * beta).ln();
        }
    }

    #[inline]
    fn covers(&self, beta: f64, n: u64) -> bool {
        beta == self.beta && (n as usize) < self.ln_xb.len()
    }
}

/// A collapsed component likelihood: everything the sampler stack needs
/// to score data against clusters without knowing the observation model.
///
/// Implementations own the prior hyperparameters and the closed-form
/// collapsed math; [`ClusterStats`] owns the per-cluster sufficient
/// statistics and the cached table the hot paths read. The contract
/// between them is [`ComponentModel::rebuild_cache`], which writes a
/// `(bias, aux, diff)` triple into the stats such that
///
/// * **bit data** (Bernoulli native, categorical one-hot) scores as
///   `bias + Σ_{set bits s} diff[s]`, and
/// * **real data** scores as
///   `bias − aux · Σ_d ln1p((x_d − diff[d])² · diff[D+d])`
///   (a product of Student-t densities: `diff` holds a location plane
///   then an inverse-scale plane).
///
/// The batched packed-table scorer copies the same triple into its
/// `[table_rows, J]` columns, so scalar and batched scoring read the
/// same table bits by construction.
pub trait ComponentModel {
    /// Short CLI / checkpoint name of the likelihood.
    fn name(&self) -> &'static str;

    /// Width of the per-cluster sufficient-statistic vectors (`D` for
    /// Bernoulli and Gaussian, one-hot `W = Σ V_d` for categorical).
    /// [`ClusterStats::empty`] must be built with this width.
    fn stat_dims(&self) -> usize;

    /// Rows per cluster column in the packed scoring table (`D`
    /// Bernoulli, `W` categorical, `2D` Gaussian). Matches
    /// [`DataRef::table_rows`] for the corresponding data kind.
    fn table_rows(&self) -> usize;

    /// Check that a dataset is the right kind and shape for this model.
    fn validate_data(&self, data: DataRef<'_>) -> Result<(), String>;

    /// Recompute the cached scoring table (`bias`, `aux`, `diff`) from
    /// the stats' current counts/moments and the prior. O(stat_dims);
    /// called lazily from [`ClusterStats::score`].
    fn rebuild_cache(&self, stats: &mut ClusterStats);

    /// Log predictive of a fresh (empty) cluster for row `r`: the prior
    /// predictive density. Constant in `x` for Bernoulli (−D·ln 2) and
    /// categorical (−Σ_d ln V_d); x-dependent for the Gaussian.
    fn log_pred_empty(&self, data: DataRef<'_>, r: usize) -> f64;

    /// Collapsed log marginal likelihood of all data in the cluster.
    fn log_marginal(&self, stats: &ClusterStats) -> f64;

    /// Cache-free reference scoring of row `r` against the cluster
    /// (tests + failure injection; must agree with the cached path).
    fn score_uncached(&self, stats: &ClusterStats, data: DataRef<'_>, r: usize) -> f64;

    /// Flat hyperparameter vector for checkpointing (shape is
    /// model-specific; see `Model::restore_hyper`).
    fn hyper_vec(&self) -> Vec<f64>;
}

/// The paper's model spec: binary dimensionality and per-dimension
/// symmetric Beta hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct BetaBernoulli {
    /// data dimensionality D
    pub d: usize,
    /// per-dimension Beta(β_d, β_d) hyperparameters
    pub beta: Vec<f64>,
    /// fast-rebuild LUT; valid only while β is uniform across dims
    lut: Option<LogLut>,
}

impl BetaBernoulli {
    /// Symmetric spec: β_d = β for all d.
    pub fn symmetric(d: usize, beta: f64) -> Self {
        assert!(beta > 0.0);
        BetaBernoulli {
            d,
            beta: vec![beta; d],
            lut: None,
        }
    }

    /// Install (or refresh) the symmetric-β log LUT covering counts up
    /// to `n_max`. An existing table is retargeted/grown in place —
    /// allocation is reused, and a same-β refresh is free.
    pub fn build_lut(&mut self, n_max: usize) {
        let b0 = self.beta[0];
        if !self.beta.iter().all(|&b| b == b0) {
            self.lut = None;
            return;
        }
        match &mut self.lut {
            Some(lut) => {
                lut.retarget(b0);
                lut.ensure(n_max);
            }
            None => self.lut = Some(LogLut::new(b0, n_max)),
        }
    }

    /// Invalidate the LUT (β no longer uniform).
    pub fn drop_lut(&mut self) {
        self.lut = None;
    }

    /// Install freshly sampled per-dimension β values; returns whether
    /// anything actually changed (callers skip cache invalidation when
    /// the griddy update re-drew the incumbent grid points). If the new
    /// values are still uniform the LUT is retargeted rather than
    /// dropped.
    pub fn update_betas(&mut self, new_beta: &[f64], n_max: usize) -> bool {
        assert_eq!(new_beta.len(), self.d);
        let changed = self
            .beta
            .iter()
            .zip(new_beta)
            .any(|(a, b)| a.to_bits() != b.to_bits());
        if !changed {
            return false;
        }
        self.beta.copy_from_slice(new_beta);
        self.build_lut(n_max);
        true
    }

    /// Log predictive of a fresh (empty) cluster for ANY datum: with a
    /// symmetric Beta(β_d, β_d) prior the predictive coin is 1/2 per dim,
    /// so the score is a constant −D·ln 2 regardless of x or β.
    pub fn empty_cluster_loglik(&self) -> f64 {
        -(self.d as f64) * std::f64::consts::LN_2
    }
}

impl ComponentModel for BetaBernoulli {
    fn name(&self) -> &'static str {
        "bernoulli"
    }

    fn stat_dims(&self) -> usize {
        self.d
    }

    fn table_rows(&self) -> usize {
        self.d
    }

    fn validate_data(&self, data: DataRef<'_>) -> Result<(), String> {
        match data {
            DataRef::Binary(m) if m.dims() == self.d => Ok(()),
            DataRef::Binary(m) => Err(format!(
                "bernoulli model has D={} but binary data has D={}",
                self.d,
                m.dims()
            )),
            other => Err(format!(
                "bernoulli model needs binary data, got {}",
                other.kind_name()
            )),
        }
    }

    /// `diff[d] = ln(c_d+β) − ln(n−c_d+β)`,
    /// `bias = Σ_d ln(n−c_d+β) − D·ln(n+2β)`; with a uniform β the `ln`
    /// calls become LUT lookups.
    fn rebuild_cache(&self, stats: &mut ClusterStats) {
        if stats.cache_diff.len() != self.d {
            stats.cache_diff.resize(self.d, 0.0);
        }
        if let Some(lut) = &self.lut {
            if lut.covers(self.beta[0], stats.n) {
                let n = stats.n as usize;
                let ln_xb = &lut.ln_xb;
                let mut bias = 0.0;
                for d in 0..self.d {
                    let c = stats.ones[d] as usize;
                    let l1 = ln_xb[c];
                    let l0 = ln_xb[n - c];
                    bias += l0;
                    stats.cache_diff[d] = l1 - l0;
                }
                stats.cache_bias = bias - self.d as f64 * lut.ln_n2b[n];
                stats.cache_aux = 0.0;
                stats.cache_valid = true;
                return;
            }
        }
        let nf = stats.n as f64;
        let mut bias = 0.0;
        for d in 0..self.d {
            let b = self.beta[d];
            let denom = nf + 2.0 * b;
            let p1 = (stats.ones[d] as f64 + b) / denom;
            let p0 = (nf - stats.ones[d] as f64 + b) / denom;
            let l1 = p1.ln();
            let l0 = p0.ln();
            bias += l0;
            stats.cache_diff[d] = l1 - l0;
        }
        stats.cache_bias = bias;
        stats.cache_aux = 0.0;
        stats.cache_valid = true;
    }

    fn log_pred_empty(&self, _data: DataRef<'_>, _r: usize) -> f64 {
        self.empty_cluster_loglik()
    }

    /// `Σ_d [ln B(c_d+β_d, n−c_d+β_d) − ln B(β_d, β_d)]`.
    fn log_marginal(&self, stats: &ClusterStats) -> f64 {
        let nf = stats.n as f64;
        let mut s = 0.0;
        for d in 0..self.d {
            let b = self.beta[d];
            let c = stats.ones[d] as f64;
            s += log_beta(c + b, nf - c + b) - log_beta(b, b);
        }
        s
    }

    fn score_uncached(&self, stats: &ClusterStats, data: DataRef<'_>, r: usize) -> f64 {
        let m = data.bits().expect("bernoulli scoring needs bit data");
        let nf = stats.n as f64;
        let mut s = 0.0;
        for d in 0..self.d {
            let b = self.beta[d];
            let denom = nf + 2.0 * b;
            let p = if m.get(r, d) {
                (stats.ones[d] as f64 + b) / denom
            } else {
                (nf - stats.ones[d] as f64 + b) / denom
            };
            s += p.ln();
        }
        s
    }

    fn hyper_vec(&self) -> Vec<f64> {
        self.beta.clone()
    }
}

/// Collapsed diagonal Gaussian: per dimension an independent
/// Normal–Inverse-Gamma prior `μ ~ N(m0, σ²/κ0)`, `σ² ~ IG(a0, b0)`
/// (the diagonal slice of a Normal–Inverse-Wishart), shared across
/// dimensions. Posterior predictives are Student-t; scoring uses the
/// cached `(bias, aux, diff)` triple with `diff` holding a location
/// plane `m_n` then an inverse-scale plane
/// `κ_n / (2 b_n (κ_n+1))`, and `aux = a_n + ½` (the t exponent), so
/// `log p(x) = bias − aux · Σ_d ln1p((x_d − m_{n,d})² · inv_d)`.
///
/// Closed forms (Murphy 2007, "Conjugate Bayesian analysis of the
/// Gaussian distribution", §3–4):
/// `κ_n = κ0+n`, `a_n = a0+n/2`, `m_n = (κ0 m0 + Σx)/κ_n`,
/// `b_n = b0 + ½Σx² + ½κ0 m0² − ½κ_n m_n²`.
#[derive(Debug, Clone, PartialEq)]
pub struct DiagGaussian {
    /// data dimensionality D
    pub d: usize,
    /// prior pseudo-count κ0 on the mean
    pub kappa0: f64,
    /// prior mean m0 (shared across dims)
    pub m0: f64,
    /// Inverse-Gamma shape a0
    pub a0: f64,
    /// Inverse-Gamma rate b0
    pub b0: f64,
    // precomputed empty-cluster (prior predictive) table pieces, so the
    // n = 0 cache rebuild and log_pred_empty share the exact same bits
    bias_empty: f64,
    inv_empty: f64,
    aux_empty: f64,
}

impl DiagGaussian {
    /// Build the model; hyperparameters must be strictly positive
    /// (except `m0`, which is any finite location).
    pub fn new(d: usize, kappa0: f64, m0: f64, a0: f64, b0: f64) -> DiagGaussian {
        assert!(kappa0 > 0.0 && a0 > 0.0 && b0 > 0.0, "NIG hypers must be > 0");
        assert!(m0.is_finite());
        let c0 = lgamma(a0 + 0.5)
            - lgamma(a0)
            - 0.5 * (2.0 * std::f64::consts::PI * b0 * (kappa0 + 1.0) / kappa0).ln();
        DiagGaussian {
            d,
            kappa0,
            m0,
            a0,
            b0,
            bias_empty: d as f64 * c0,
            inv_empty: kappa0 / (2.0 * b0 * (kappa0 + 1.0)),
            aux_empty: a0 + 0.5,
        }
    }

    /// Posterior `(m_n, b_n)` for one dimension from its moments.
    #[inline]
    fn posterior_dim(&self, kn: f64, s1: f64, s2: f64) -> (f64, f64) {
        let mn = (self.kappa0 * self.m0 + s1) / kn;
        let bn = self.b0 + 0.5 * (s2 + self.kappa0 * self.m0 * self.m0 - kn * mn * mn);
        (mn, bn)
    }
}

impl ComponentModel for DiagGaussian {
    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn stat_dims(&self) -> usize {
        self.d
    }

    fn table_rows(&self) -> usize {
        2 * self.d
    }

    fn validate_data(&self, data: DataRef<'_>) -> Result<(), String> {
        match data {
            DataRef::Real(m) if m.dims() == self.d => Ok(()),
            DataRef::Real(m) => Err(format!(
                "gaussian model has D={} but real data has D={}",
                self.d,
                m.dims()
            )),
            other => Err(format!(
                "gaussian model needs real data, got {}",
                other.kind_name()
            )),
        }
    }

    fn rebuild_cache(&self, stats: &mut ClusterStats) {
        let d = self.d;
        if stats.cache_diff.len() != 2 * d {
            stats.cache_diff.resize(2 * d, 0.0);
        }
        if stats.n == 0 {
            // prior predictive, bit-identical to log_pred_empty's pieces
            // (the general path below would reconstruct b0 with rounding)
            for i in 0..d {
                stats.cache_diff[i] = self.m0;
                stats.cache_diff[d + i] = self.inv_empty;
            }
            stats.cache_bias = self.bias_empty;
            stats.cache_aux = self.aux_empty;
            stats.cache_valid = true;
            return;
        }
        let n = stats.n as f64;
        let kn = self.kappa0 + n;
        let an = self.a0 + 0.5 * n;
        let lg_t = lgamma(an + 0.5) - lgamma(an);
        let half_log_2pi_ratio = 0.5 * (2.0 * std::f64::consts::PI * (kn + 1.0) / kn).ln();
        let mut bias = 0.0;
        for i in 0..d {
            let (mn, bn) = self.posterior_dim(kn, stats.sum_at(i), stats.sumsq_at(i));
            debug_assert!(bn > 0.0, "posterior scale b_n must stay positive");
            bias += lg_t - half_log_2pi_ratio - 0.5 * bn.ln();
            stats.cache_diff[i] = mn;
            stats.cache_diff[d + i] = kn / (2.0 * bn * (kn + 1.0));
        }
        stats.cache_bias = bias;
        stats.cache_aux = an + 0.5;
        stats.cache_valid = true;
    }

    fn log_pred_empty(&self, data: DataRef<'_>, r: usize) -> f64 {
        let m = data.real().expect("gaussian scoring needs real data");
        let row = m.row(r);
        let mut acc = 0.0;
        for &x in row {
            let t = x - self.m0;
            acc += (t * t * self.inv_empty).ln_1p();
        }
        self.bias_empty - self.aux_empty * acc
    }

    /// Per dimension: `−(n/2)ln 2π + ½(ln κ0 − ln κ_n) + lnΓ(a_n) −
    /// lnΓ(a0) + a0 ln b0 − a_n ln b_{n,d}`.
    fn log_marginal(&self, stats: &ClusterStats) -> f64 {
        if stats.n == 0 {
            return 0.0;
        }
        let n = stats.n as f64;
        let kn = self.kappa0 + n;
        let an = self.a0 + 0.5 * n;
        let base = -0.5 * n * (2.0 * std::f64::consts::PI).ln()
            + 0.5 * (self.kappa0.ln() - kn.ln())
            + lgamma(an)
            - lgamma(self.a0)
            + self.a0 * self.b0.ln();
        let mut s = 0.0;
        for i in 0..self.d {
            let (_, bn) = self.posterior_dim(kn, stats.sum_at(i), stats.sumsq_at(i));
            s += base - an * bn.ln();
        }
        s
    }

    fn score_uncached(&self, stats: &ClusterStats, data: DataRef<'_>, r: usize) -> f64 {
        let m = data.real().expect("gaussian scoring needs real data");
        let row = m.row(r);
        let n = stats.n as f64;
        let kn = self.kappa0 + n;
        let an = self.a0 + 0.5 * n;
        let lg_t = lgamma(an + 0.5) - lgamma(an);
        let mut s = 0.0;
        for i in 0..self.d {
            let (mn, bn) = self.posterior_dim(kn, stats.sum_at(i), stats.sumsq_at(i));
            let c0 = lg_t - 0.5 * (2.0 * std::f64::consts::PI * bn * (kn + 1.0) / kn).ln();
            let t = row[i] - mn;
            let inv = kn / (2.0 * bn * (kn + 1.0));
            s += c0 - (an + 0.5) * (t * t * inv).ln_1p();
        }
        s
    }

    fn hyper_vec(&self) -> Vec<f64> {
        vec![self.kappa0, self.m0, self.a0, self.b0]
    }
}

/// Dirichlet–multinomial categorical likelihood: dimension `d` takes a
/// value in `0..V_d` with a symmetric `Dirichlet(γ·1)` prior on each
/// dimension's category probabilities, collapsed out. Data arrive as a
/// one-hot [`crate::data::CatMat`], so the sufficient statistic is the
/// per-one-hot-column count vector (width `W = Σ V_d`) and the cached
/// table rides the bit-sparse scoring path unchanged:
/// `diff[(d,v)] = ln(c_{d,v}+γ)`, `bias = −Σ_d ln(n + V_d γ)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Categorical {
    /// symmetric Dirichlet concentration γ
    pub gamma: f64,
    cards: Vec<u32>,
    /// prefix sums of `cards` (len D+1)
    offsets: Vec<u32>,
    /// −Σ_d ln V_d: the (constant) prior predictive of any datum
    empty_loglik: f64,
}

impl Categorical {
    /// Build from per-dimension cardinalities and the Dirichlet γ.
    pub fn new(cards: &[u32], gamma: f64) -> Categorical {
        assert!(gamma > 0.0, "Dirichlet concentration must be > 0");
        assert!(!cards.is_empty());
        assert!(cards.iter().all(|&v| v >= 2), "cardinalities must be >= 2");
        let mut offsets = Vec::with_capacity(cards.len() + 1);
        let mut acc = 0u32;
        for &v in cards {
            offsets.push(acc);
            acc += v;
        }
        offsets.push(acc);
        let empty_loglik = -cards.iter().map(|&v| (v as f64).ln()).sum::<f64>();
        Categorical {
            gamma,
            cards: cards.to_vec(),
            offsets,
            empty_loglik,
        }
    }

    /// Per-dimension cardinalities V_d.
    pub fn cards(&self) -> &[u32] {
        &self.cards
    }

    /// Total one-hot width W = Σ V_d.
    pub fn width(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }
}

impl ComponentModel for Categorical {
    fn name(&self) -> &'static str {
        "categorical"
    }

    fn stat_dims(&self) -> usize {
        self.width()
    }

    fn table_rows(&self) -> usize {
        self.width()
    }

    fn validate_data(&self, data: DataRef<'_>) -> Result<(), String> {
        match data {
            DataRef::Categorical(m) if m.cards() == &self.cards[..] => Ok(()),
            DataRef::Categorical(m) => Err(format!(
                "categorical model has cards {:?} but data has {:?}",
                self.cards,
                m.cards()
            )),
            other => Err(format!(
                "categorical model needs categorical data, got {}",
                other.kind_name()
            )),
        }
    }

    fn rebuild_cache(&self, stats: &mut ClusterStats) {
        let w = self.width();
        if stats.cache_diff.len() != w {
            stats.cache_diff.resize(w, 0.0);
        }
        let n = stats.n as f64;
        let mut bias = 0.0;
        for &v in &self.cards {
            bias -= (n + v as f64 * self.gamma).ln();
        }
        for (slot, &c) in stats.cache_diff.iter_mut().zip(&stats.ones) {
            *slot = (c as f64 + self.gamma).ln();
        }
        stats.cache_bias = bias;
        stats.cache_aux = 0.0;
        stats.cache_valid = true;
    }

    fn log_pred_empty(&self, _data: DataRef<'_>, _r: usize) -> f64 {
        self.empty_loglik
    }

    /// Per dimension: `Σ_v [lnΓ(c_v+γ) − lnΓ(γ)] − [lnΓ(n+V γ) −
    /// lnΓ(V γ)]`, via the stable rising-factorial `lgamma_ratio`.
    fn log_marginal(&self, stats: &ClusterStats) -> f64 {
        let mut s = 0.0;
        for (dim, &v) in self.cards.iter().enumerate() {
            s -= lgamma_ratio(v as f64 * self.gamma, stats.n);
            let lo = self.offsets[dim] as usize;
            let hi = self.offsets[dim + 1] as usize;
            for &c in &stats.ones[lo..hi] {
                s += lgamma_ratio(self.gamma, u64::from(c));
            }
        }
        s
    }

    fn score_uncached(&self, stats: &ClusterStats, data: DataRef<'_>, r: usize) -> f64 {
        let m = match data {
            DataRef::Categorical(m) => m,
            other => panic!("categorical needs categorical data, got {}", other.kind_name()),
        };
        let n = stats.n as f64;
        let mut s = 0.0;
        for (dim, &v) in self.cards.iter().enumerate() {
            let code = m.get(r, dim);
            let c = stats.ones[(self.offsets[dim] + code) as usize] as f64;
            s += (c + self.gamma).ln() - (n + v as f64 * self.gamma).ln();
        }
        s
    }

    fn hyper_vec(&self) -> Vec<f64> {
        let mut h = Vec::with_capacity(1 + self.cards.len());
        h.push(self.gamma);
        h.extend(self.cards.iter().map(|&v| f64::from(v)));
        h
    }
}

/// Enum dispatcher over the three component likelihoods. The sampler,
/// shard and coordinator layers hold a `Model` and call the
/// [`ComponentModel`] surface through these inherent forwards (no trait
/// import needed at call sites); owners that need Bernoulli-specific
/// surface (β griddy update, LUT management, PJRT weight export) go
/// through [`Model::as_bernoulli`] / [`Model::as_bernoulli_mut`].
#[derive(Debug, Clone, PartialEq)]
pub enum Model {
    /// Beta–Bernoulli (the paper's §6 binary model).
    Bernoulli(BetaBernoulli),
    /// Collapsed diagonal Gaussian (Normal–Inverse-Gamma per dim).
    Gaussian(DiagGaussian),
    /// Dirichlet–multinomial categorical.
    Categorical(Categorical),
}

macro_rules! model_dispatch {
    ($self:expr, $m:ident => $body:expr) => {
        match $self {
            Model::Bernoulli($m) => $body,
            Model::Gaussian($m) => $body,
            Model::Categorical($m) => $body,
        }
    };
}

impl Model {
    /// Symmetric Beta–Bernoulli constructor (the overwhelmingly common
    /// call in tests and the Bernoulli pipeline).
    pub fn bernoulli(d: usize, beta: f64) -> Model {
        Model::Bernoulli(BetaBernoulli::symmetric(d, beta))
    }

    /// Short likelihood name (see [`ComponentModel::name`]).
    pub fn name(&self) -> &'static str {
        model_dispatch!(self, m => m.name())
    }

    /// Sufficient-statistic width (see [`ComponentModel::stat_dims`]).
    pub fn stat_dims(&self) -> usize {
        model_dispatch!(self, m => m.stat_dims())
    }

    /// Packed-table rows per cluster (see [`ComponentModel::table_rows`]).
    pub fn table_rows(&self) -> usize {
        model_dispatch!(self, m => m.table_rows())
    }

    /// Data-kind/shape check (see [`ComponentModel::validate_data`]).
    pub fn validate_data(&self, data: DataRef<'_>) -> Result<(), String> {
        model_dispatch!(self, m => m.validate_data(data))
    }

    /// Rebuild a stats cache (see [`ComponentModel::rebuild_cache`]).
    pub fn rebuild_cache(&self, stats: &mut ClusterStats) {
        model_dispatch!(self, m => m.rebuild_cache(stats))
    }

    /// Fresh-cluster log predictive (see
    /// [`ComponentModel::log_pred_empty`]).
    #[inline]
    pub fn log_pred_empty(&self, data: DataRef<'_>, r: usize) -> f64 {
        model_dispatch!(self, m => m.log_pred_empty(data, r))
    }

    /// Collapsed cluster log marginal (see
    /// [`ComponentModel::log_marginal`]).
    pub fn log_marginal(&self, stats: &ClusterStats) -> f64 {
        model_dispatch!(self, m => m.log_marginal(stats))
    }

    /// Cache-free reference score (see
    /// [`ComponentModel::score_uncached`]).
    pub fn score_uncached(&self, stats: &ClusterStats, data: DataRef<'_>, r: usize) -> f64 {
        model_dispatch!(self, m => m.score_uncached(stats, data, r))
    }

    /// Flat hyperparameter vector (see [`ComponentModel::hyper_vec`]).
    pub fn hyper_vec(&self) -> Vec<f64> {
        model_dispatch!(self, m => m.hyper_vec())
    }

    /// The Bernoulli instantiation, for owners on the Bernoulli-only
    /// paths (β griddy update, PJRT export).
    ///
    /// # Panics
    ///
    /// Panics if the model is not Bernoulli — those paths must be gated
    /// by the caller (`if let Model::Bernoulli(..)`) or by config.
    pub fn as_bernoulli(&self) -> &BetaBernoulli {
        match self {
            Model::Bernoulli(bb) => bb,
            other => panic!("expected bernoulli model, got {}", other.name()),
        }
    }

    /// Mutable [`Model::as_bernoulli`].
    pub fn as_bernoulli_mut(&mut self) -> &mut BetaBernoulli {
        match self {
            Model::Bernoulli(bb) => bb,
            other => panic!("expected bernoulli model, got {}", other.name()),
        }
    }

    /// Install/refresh the symmetric-β LUT on the Bernoulli
    /// instantiation; a no-op for the other likelihoods (their cache
    /// rebuilds have no per-count transcendental table).
    pub fn build_lut(&mut self, n_max: usize) {
        if let Model::Bernoulli(bb) = self {
            bb.build_lut(n_max);
        }
    }

    /// Restore hyperparameters from a checkpoint's flat vector.
    ///
    /// * Bernoulli: `hyper` is the sampled per-dim β (length D) — it is
    ///   installed and the LUT rebuilt to cover `n_max`.
    /// * Gaussian: hypers are fixed, not sampled; `hyper` must be the
    ///   bit-equal `[κ0, m0, a0, b0]` the run was configured with.
    /// * Categorical: `hyper` must equal `[γ, V_0..V_{D-1}]`.
    pub fn restore_hyper(&mut self, hyper: &[f64], n_max: usize) -> Result<(), String> {
        match self {
            Model::Bernoulli(bb) => {
                if hyper.len() != bb.d {
                    return Err(format!(
                        "checkpoint β has {} dims, model has {}",
                        hyper.len(),
                        bb.d
                    ));
                }
                if hyper.iter().any(|&b| b.is_nan() || b <= 0.0) {
                    return Err("checkpoint β values must be > 0".into());
                }
                bb.beta.copy_from_slice(hyper);
                bb.build_lut(n_max);
                Ok(())
            }
            Model::Gaussian(g) => {
                let want = [g.kappa0, g.m0, g.a0, g.b0];
                if hyper.len() != 4 {
                    return Err(format!(
                        "checkpoint gaussian hypers have {} entries, want 4",
                        hyper.len()
                    ));
                }
                if hyper.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!(
                        "checkpoint gaussian hypers {hyper:?} != configured {want:?}"
                    ));
                }
                Ok(())
            }
            Model::Categorical(c) => {
                let want = c.hyper_vec();
                if hyper.len() != want.len()
                    || hyper.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    return Err(format!(
                        "checkpoint categorical hypers {hyper:?} != configured {want:?}"
                    ));
                }
                Ok(())
            }
        }
    }
}

/// `Copy` model selector + hyperparameters: what configs carry and what
/// the CLI `--model` flag parses into. Turned into a concrete [`Model`]
/// against a dataset by [`ModelSpec::build`] (which is where data-kind
/// mismatches are rejected).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ModelSpec {
    /// Beta–Bernoulli on binary data; β comes from the config's
    /// `init_beta` (it is sampled by the griddy-Gibbs update).
    Bernoulli,
    /// Collapsed diagonal Gaussian on real data with fixed NIG hypers.
    Gaussian {
        /// prior mean pseudo-count κ0
        kappa0: f64,
        /// prior mean m0
        m0: f64,
        /// Inverse-Gamma shape a0
        a0: f64,
        /// Inverse-Gamma rate b0
        b0: f64,
    },
    /// Dirichlet–multinomial on categorical data (cards come from the
    /// dataset) with fixed symmetric concentration γ.
    Categorical {
        /// symmetric Dirichlet concentration γ
        gamma: f64,
    },
}

impl Default for ModelSpec {
    fn default() -> Self {
        ModelSpec::Bernoulli
    }
}

impl ModelSpec {
    /// Gaussian hypers used when the CLI flag gives none.
    pub const DEFAULT_GAUSSIAN: ModelSpec = ModelSpec::Gaussian {
        kappa0: 1.0,
        m0: 0.0,
        a0: 1.0,
        b0: 1.0,
    };

    /// Categorical γ used when the CLI flag gives none.
    pub const DEFAULT_CATEGORICAL: ModelSpec = ModelSpec::Categorical { gamma: 0.5 };

    /// Short name (CLI value, log banners).
    pub fn name(self) -> &'static str {
        match self {
            ModelSpec::Bernoulli => "bernoulli",
            ModelSpec::Gaussian { .. } => "gaussian",
            ModelSpec::Categorical { .. } => "categorical",
        }
    }

    /// Checkpoint model tag (CCCKPT3 wire format).
    pub fn tag(self) -> u64 {
        match self {
            ModelSpec::Bernoulli => 0,
            ModelSpec::Gaussian { .. } => 1,
            ModelSpec::Categorical { .. } => 2,
        }
    }

    /// Parse a CLI `--model` value: `bernoulli`,
    /// `gaussian[:κ0,m0,a0,b0]`, or `categorical[:γ]`.
    pub fn parse(s: &str) -> Result<ModelSpec, String> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "bernoulli" => match args {
                None => Ok(ModelSpec::Bernoulli),
                Some(_) => Err("bernoulli takes no :args (β comes from --beta)".into()),
            },
            "gaussian" => match args {
                None => Ok(Self::DEFAULT_GAUSSIAN),
                Some(a) => {
                    let mut vals = Vec::new();
                    for t in a.split(',') {
                        let v: f64 = t
                            .trim()
                            .parse()
                            .map_err(|e| format!("bad gaussian hyper {t:?}: {e}"))?;
                        vals.push(v);
                    }
                    if vals.len() != 4 {
                        return Err(format!(
                            "gaussian wants 4 hypers κ0,m0,a0,b0 — got {}",
                            vals.len()
                        ));
                    }
                    if [vals[0], vals[2], vals[3]].iter().any(|v| !v.is_finite() || *v <= 0.0) {
                        return Err("gaussian κ0, a0, b0 must be > 0".into());
                    }
                    Ok(ModelSpec::Gaussian {
                        kappa0: vals[0],
                        m0: vals[1],
                        a0: vals[2],
                        b0: vals[3],
                    })
                }
            },
            "categorical" => match args {
                None => Ok(Self::DEFAULT_CATEGORICAL),
                Some(a) => {
                    let gamma: f64 = a
                        .trim()
                        .parse()
                        .map_err(|e| format!("bad categorical γ {a:?}: {e}"))?;
                    if !gamma.is_finite() || gamma <= 0.0 {
                        return Err("categorical γ must be > 0".into());
                    }
                    Ok(ModelSpec::Categorical { gamma })
                }
            },
            other => Err(format!(
                "unknown model {other:?} (want bernoulli | gaussian[:κ0,m0,a0,b0] | categorical[:γ])"
            )),
        }
    }

    /// Instantiate against a dataset, rejecting data-kind mismatches.
    /// `init_beta` seeds the Bernoulli β (ignored by the other models).
    pub fn build(self, data: DataRef<'_>, init_beta: f64) -> Result<Model, String> {
        let model = match self {
            ModelSpec::Bernoulli => match data {
                DataRef::Binary(m) => Model::bernoulli(m.dims(), init_beta),
                other => {
                    return Err(format!(
                        "--model bernoulli needs binary data, got {}",
                        other.kind_name()
                    ))
                }
            },
            ModelSpec::Gaussian { kappa0, m0, a0, b0 } => match data {
                DataRef::Real(m) => {
                    Model::Gaussian(DiagGaussian::new(m.dims(), kappa0, m0, a0, b0))
                }
                other => {
                    return Err(format!(
                        "--model gaussian needs real data, got {}",
                        other.kind_name()
                    ))
                }
            },
            ModelSpec::Categorical { gamma } => match data {
                DataRef::Categorical(m) => Model::Categorical(Categorical::new(m.cards(), gamma)),
                other => {
                    return Err(format!(
                        "--model categorical needs categorical data, got {}",
                        other.kind_name()
                    ))
                }
            },
        };
        model.validate_data(data)?;
        Ok(model)
    }
}

/// Sufficient statistics for one cluster, plus the cached scoring table.
///
/// The count fields serve all likelihoods: `n` always, `ones` for the
/// bit-backed models (Bernoulli one-counts, categorical one-hot counts),
/// `sum`/`sumsq` first/second moments for the Gaussian. The moment
/// vectors are sized lazily on the first real-data add (bit-only runs
/// never allocate them) and snapped to exact zeros whenever `n` returns
/// to 0, so floating-point removal drift cannot accumulate across an
/// empty cluster's reuse.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    n: u64,
    ones: Vec<u32>,
    /// per-dim Σ x_d (real data only; empty until first real add)
    sum: Vec<f64>,
    /// per-dim Σ x_d² (real data only; empty until first real add)
    sumsq: Vec<f64>,
    /// cache: bit models — bias = Σ_d log p̂0_d, diff[d] = log p̂1_d −
    /// log p̂0_d; Gaussian — bias = Σ_d c0_d, diff = [m_n | inv] planes
    cache_bias: f64,
    cache_diff: Vec<f64>,
    /// cache: Student-t exponent a_n + ½ (Gaussian; 0 for bit models)
    cache_aux: f64,
    cache_valid: bool,
    /// ln(n), maintained incrementally (perf: the Gibbs hot loop reads
    /// it once per cluster per datum — see EXPERIMENTS.md §Perf)
    log_n: f64,
}

impl ClusterStats {
    /// Stats of an empty cluster over `d` sufficient-statistic dims
    /// (the model's [`ComponentModel::stat_dims`], equivalently the
    /// data's [`DataRef::dims`]).
    pub fn empty(d: usize) -> Self {
        ClusterStats {
            n: 0,
            ones: vec![0; d],
            sum: Vec::new(),
            sumsq: Vec::new(),
            cache_bias: 0.0,
            cache_diff: vec![0.0; d],
            cache_aux: 0.0,
            cache_valid: false,
            log_n: f64::NEG_INFINITY,
        }
    }

    /// Member count n_j.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// ln(n) without a transcendental call on the hot path.
    #[inline]
    pub fn log_n(&self) -> f64 {
        self.log_n
    }

    /// Per-dimension one-counts c_jd (bit-backed models).
    pub fn ones(&self) -> &[u32] {
        &self.ones
    }

    /// Per-dimension first moments Σ x_d (empty slice until real data
    /// has been added).
    pub fn sum(&self) -> &[f64] {
        &self.sum
    }

    /// Per-dimension second moments Σ x_d² (empty slice until real data
    /// has been added).
    pub fn sumsq(&self) -> &[f64] {
        &self.sumsq
    }

    #[inline]
    fn sum_at(&self, i: usize) -> f64 {
        self.sum.get(i).copied().unwrap_or(0.0)
    }

    #[inline]
    fn sumsq_at(&self, i: usize) -> f64 {
        self.sumsq.get(i).copied().unwrap_or(0.0)
    }

    /// Whether the cluster has no members.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Add datum (row `r` of `data`) to the cluster.
    pub fn add<'a>(&mut self, data: impl Into<DataRef<'a>>, r: usize) {
        let data = data.into();
        self.n += 1;
        self.log_n = (self.n as f64).ln();
        match data.bits() {
            Some(bits) => bits.for_each_one(r, |d| self.ones[d] += 1),
            None => {
                let row = data.real().expect("non-bit data must be real").row(r);
                if self.sum.is_empty() {
                    self.sum = vec![0.0; row.len()];
                    self.sumsq = vec![0.0; row.len()];
                }
                for (d, &x) in row.iter().enumerate() {
                    self.sum[d] += x;
                    self.sumsq[d] += x * x;
                }
            }
        }
        self.cache_valid = false;
    }

    /// Remove datum from the cluster (must have been added).
    pub fn remove<'a>(&mut self, data: impl Into<DataRef<'a>>, r: usize) {
        let data = data.into();
        debug_assert!(self.n > 0, "remove from empty cluster");
        self.n -= 1;
        self.log_n = if self.n == 0 {
            f64::NEG_INFINITY
        } else {
            (self.n as f64).ln()
        };
        match data.bits() {
            Some(bits) => bits.for_each_one(r, |d| {
                debug_assert!(self.ones[d] > 0, "one-count underflow at dim {d}");
                self.ones[d] -= 1;
            }),
            None => {
                let row = data.real().expect("non-bit data must be real").row(r);
                for (d, &x) in row.iter().enumerate() {
                    self.sum[d] -= x;
                    self.sumsq[d] -= x * x;
                }
                if self.n == 0 {
                    // snap accumulated rounding to the exact empty state
                    self.sum.iter_mut().for_each(|v| *v = 0.0);
                    self.sumsq.iter_mut().for_each(|v| *v = 0.0);
                }
            }
        }
        self.cache_valid = false;
    }

    /// Overwrite this cluster's statistics with a copy of `other`'s,
    /// reusing the existing allocations (unlike `clone`, which allocates
    /// fresh count vectors). The split–merge kernel scores each merge
    /// proposal's union marginal on a persistent scratch through this,
    /// keeping the move layer allocation-free after warm-up. The cached
    /// scoring table is NOT copied — it invalidates, to be rebuilt
    /// lazily on first score.
    ///
    /// # Panics
    ///
    /// Panics if the two stats have different dimensionality.
    pub fn copy_from(&mut self, other: &ClusterStats) {
        assert_eq!(self.ones.len(), other.ones.len(), "dims mismatch");
        self.n = other.n;
        self.log_n = other.log_n;
        self.ones.copy_from_slice(&other.ones);
        if other.sum.is_empty() {
            self.sum.iter_mut().for_each(|v| *v = 0.0);
            self.sumsq.iter_mut().for_each(|v| *v = 0.0);
        } else {
            self.sum.resize(other.sum.len(), 0.0);
            self.sumsq.resize(other.sumsq.len(), 0.0);
            self.sum.copy_from_slice(&other.sum);
            self.sumsq.copy_from_slice(&other.sumsq);
        }
        self.cache_valid = false;
    }

    /// Merge another cluster's statistics into this one (shuffle moves).
    pub fn absorb(&mut self, other: &ClusterStats) {
        assert_eq!(self.ones.len(), other.ones.len());
        self.n += other.n;
        self.log_n = (self.n as f64).ln();
        for (a, b) in self.ones.iter_mut().zip(&other.ones) {
            *a += *b;
        }
        if !other.sum.is_empty() {
            if self.sum.is_empty() {
                self.sum = vec![0.0; other.sum.len()];
                self.sumsq = vec![0.0; other.sumsq.len()];
            }
            for (a, b) in self.sum.iter_mut().zip(&other.sum) {
                *a += *b;
            }
            for (a, b) in self.sumsq.iter_mut().zip(&other.sumsq) {
                *a += *b;
            }
        }
        self.cache_valid = false;
    }

    /// Explicitly invalidate the cache (hyperparameters changed).
    pub fn invalidate_cache(&mut self) {
        self.cache_valid = false;
    }

    /// The cached predictive table `(bias, aux, diff)` for the current
    /// counts and hyperparameters, rebuilding it first if stale. This is
    /// what the batched sweep path copies into its packed
    /// `[table_rows, J]` columns, so batched and scalar scoring read the
    /// *same* table bits.
    pub fn cached_table(&mut self, model: &Model) -> (f64, f64, &[f64]) {
        if !self.cache_valid {
            model.rebuild_cache(self);
        }
        (self.cache_bias, self.cache_aux, &self.cache_diff)
    }

    /// Log predictive likelihood of row `r` under this cluster
    /// (collapsed). Uses the cached table — for bit data O(#set bits)
    /// after an O(D) rebuild, for real data O(D).
    pub fn score<'a>(&mut self, model: &Model, data: impl Into<DataRef<'a>>, r: usize) -> f64 {
        let data = data.into();
        if !self.cache_valid {
            model.rebuild_cache(self);
        }
        match data.bits() {
            Some(bits) => {
                let mut s = self.cache_bias;
                let diff = &self.cache_diff;
                bits.for_each_one(r, |d| s += diff[d]);
                s
            }
            None => {
                let row = data.real().expect("non-bit data must be real").row(r);
                self.score_real_cached(row)
            }
        }
    }

    /// Score from a pre-decoded ones-index list (the Gibbs hot loop
    /// decodes each datum's bits once and scores all local clusters from
    /// the same list — see EXPERIMENTS.md §Perf). Bit-backed models only.
    #[inline]
    pub fn score_ones(&mut self, model: &Model, ones_idx: &[u32]) -> f64 {
        if !self.cache_valid {
            model.rebuild_cache(self);
        }
        let diff = &self.cache_diff;
        let mut s = self.cache_bias;
        for &d in ones_idx {
            s += diff[d as usize];
        }
        s
    }

    /// Score a pre-fetched real row (the Gaussian analogue of
    /// [`Self::score_ones`]: the hot loop fetches the row slice once and
    /// scores all local clusters from it).
    #[inline]
    pub fn score_real(&mut self, model: &Model, row: &[f64]) -> f64 {
        if !self.cache_valid {
            model.rebuild_cache(self);
        }
        self.score_real_cached(row)
    }

    /// Real-data evaluation of the (valid) cached table:
    /// `bias − aux · Σ_d ln1p((x_d − m_{n,d})² · inv_d)`, accumulated
    /// in d-ascending order (the batched path must match this order to
    /// stay bit-identical).
    #[inline]
    fn score_real_cached(&self, row: &[f64]) -> f64 {
        debug_assert!(self.cache_valid);
        let d = row.len();
        debug_assert_eq!(self.cache_diff.len(), 2 * d);
        let (mn, inv) = self.cache_diff.split_at(d);
        let mut acc = 0.0;
        for i in 0..d {
            let t = row[i] - mn[i];
            acc += (t * t * inv[i]).ln_1p();
        }
        self.cache_bias - self.cache_aux * acc
    }

    /// Uncached reference scoring (tests + failure injection).
    pub fn score_uncached<'a>(
        &self,
        model: &Model,
        data: impl Into<DataRef<'a>>,
        r: usize,
    ) -> f64 {
        model.score_uncached(self, data.into(), r)
    }

    /// Collapsed log marginal likelihood of the whole cluster.
    pub fn log_marginal(&self, model: &Model) -> f64 {
        model.log_marginal(self)
    }

    /// Predictive Bernoulli parameters p̂_1 per dim (f32, for the PJRT
    /// artifact weight matrices; Bernoulli-only export path).
    pub fn predictive_p1(&self, model: &BetaBernoulli, out: &mut [f32]) {
        assert_eq!(out.len(), model.d);
        let nf = self.n as f64;
        for d in 0..model.d {
            let b = model.beta[d];
            out[d] = ((self.ones[d] as f64 + b) / (nf + 2.0 * b)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CatMat, RealMat};
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> BinMat {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = BinMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                if rng.next_f64() < 0.4 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    fn rand_real(n: usize, d: usize, seed: u64) -> RealMat {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = RealMat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                m.set(r, c, (rng.next_f64() - 0.5) * 4.0);
            }
        }
        m
    }

    fn rand_cat(n: usize, cards: &[u32], seed: u64) -> CatMat {
        let mut rng = Pcg64::seed_from(seed);
        let d = cards.len();
        let mut codes = Vec::with_capacity(n * d);
        for _ in 0..n {
            for &v in cards {
                codes.push((rng.next_f64() * v as f64) as u32 % v);
            }
        }
        CatMat::from_codes(n, cards, &codes)
    }

    #[test]
    fn add_remove_roundtrip_restores_stats() {
        let data = rand_data(10, 33, 1);
        let model = Model::bernoulli(33, 0.5);
        let mut c = ClusterStats::empty(33);
        for r in 0..10 {
            c.add(&data, r);
        }
        let before_n = c.n();
        let before_ones = c.ones().to_vec();
        let before_score = c.score(&model, &data, 0);
        c.add(&data, 3);
        c.remove(&data, 3);
        assert_eq!(c.n(), before_n);
        assert_eq!(c.ones(), &before_ones[..]);
        assert!((c.score(&model, &data, 0) - before_score).abs() < 1e-12);
    }

    #[test]
    fn cached_score_matches_uncached() {
        let data = rand_data(20, 65, 2);
        let model = Model::bernoulli(65, 0.3);
        let mut c = ClusterStats::empty(65);
        for r in 0..12 {
            c.add(&data, r);
        }
        for r in 0..20 {
            let cached = c.score(&model, &data, r);
            let plain = c.score_uncached(&model, &data, r);
            assert!(
                (cached - plain).abs() < 1e-10,
                "row {r}: {cached} vs {plain}"
            );
        }
    }

    #[test]
    fn empty_cluster_score_is_neg_d_ln2() {
        let data = rand_data(3, 17, 3);
        let model = Model::bernoulli(17, 0.7);
        let mut c = ClusterStats::empty(17);
        let want = model.as_bernoulli().empty_cluster_loglik();
        for r in 0..3 {
            assert!((c.score(&model, &data, r) - want).abs() < 1e-12);
            assert_eq!(model.log_pred_empty((&data).into(), r), want);
        }
    }

    #[test]
    fn cache_invalidates_on_hyper_change() {
        let data = rand_data(8, 9, 4);
        let mut model = Model::bernoulli(9, 0.5);
        let mut c = ClusterStats::empty(9);
        for r in 0..8 {
            c.add(&data, r);
        }
        let s_before = c.score(&model, &data, 0);
        model.as_bernoulli_mut().beta = vec![2.0; 9];
        c.invalidate_cache();
        let s_after = c.score(&model, &data, 0);
        assert!((s_after - c.score_uncached(&model, &data, 0)).abs() < 1e-10);
        assert!((s_before - s_after).abs() > 1e-6, "score must respond to β");
    }

    #[test]
    fn log_marginal_matches_sequential_predictives() {
        // chain rule: log m(x_1..x_n) = Σ_i log p(x_i | x_<i)
        let data = rand_data(6, 21, 5);
        let model = Model::bernoulli(21, 0.4);
        let mut c = ClusterStats::empty(21);
        let mut chain = 0.0;
        for r in 0..6 {
            chain += c.score(&model, &data, r);
            c.add(&data, r);
        }
        let marginal = c.log_marginal(&model);
        assert!(
            (chain - marginal).abs() < 1e-8,
            "chain {chain} vs marginal {marginal}"
        );
    }

    #[test]
    fn copy_from_duplicates_stats_and_invalidates_cache() {
        let data = rand_data(12, 15, 9);
        let model = Model::bernoulli(15, 0.5);
        let mut src = ClusterStats::empty(15);
        for r in 0..7 {
            src.add(&data, r);
        }
        let mut dst = ClusterStats::empty(15);
        for r in 7..12 {
            dst.add(&data, r);
        }
        let _ = dst.score(&model, &data, 0); // warm dst's cache with stale stats
        dst.copy_from(&src);
        assert_eq!(dst.n(), src.n());
        assert_eq!(dst.ones(), src.ones());
        assert_eq!(dst.log_n().to_bits(), src.log_n().to_bits());
        // the cache was invalidated: scores come from the copied stats
        for r in 0..12 {
            let got = dst.score(&model, &data, r);
            let want = src.score_uncached(&model, &data, r);
            assert!((got - want).abs() < 1e-10, "row {r}: {got} vs {want}");
        }
    }

    #[test]
    fn absorb_equals_adding_all_rows() {
        let data = rand_data(10, 15, 6);
        let mut a = ClusterStats::empty(15);
        let mut b = ClusterStats::empty(15);
        for r in 0..5 {
            a.add(&data, r);
        }
        for r in 5..10 {
            b.add(&data, r);
        }
        a.absorb(&b);
        let mut all = ClusterStats::empty(15);
        for r in 0..10 {
            all.add(&data, r);
        }
        assert_eq!(a.n(), all.n());
        assert_eq!(a.ones(), all.ones());
    }

    #[test]
    fn lut_grows_geometrically_and_retargets() {
        let mut lut = LogLut::new(0.5, 10);
        assert_eq!(lut.max_count(), 10);
        lut.ensure(11); // one past the end: must at least double
        assert!(lut.max_count() >= 21, "got {}", lut.max_count());
        let before = lut.max_count();
        lut.ensure(5); // already covered: no-op
        assert_eq!(lut.max_count(), before);
        assert!(lut.covers(0.5, before as u64));
        assert!(!lut.covers(0.5, before as u64 + 1));
        // retarget to a new β recomputes entries in place
        lut.retarget(2.0);
        assert!(lut.covers(2.0, 3));
        assert!(!lut.covers(0.5, 3));
        let fresh = LogLut::new(2.0, lut.max_count());
        assert_eq!(lut.ln_xb, fresh.ln_xb);
        assert_eq!(lut.ln_n2b, fresh.ln_n2b);
    }

    #[test]
    fn lut_backed_score_correct_after_growth() {
        let data = rand_data(30, 9, 8);
        let mut model = Model::bernoulli(9, 0.5);
        model.build_lut(5); // deliberately too small for 30 rows
        let mut c = ClusterStats::empty(9);
        for r in 0..30 {
            c.add(&data, r);
        }
        // count 30 exceeds the table: must fall back to the slow path
        let slow = c.score(&model, &data, 0);
        assert!((slow - c.score_uncached(&model, &data, 0)).abs() < 1e-10);
        // grow, invalidate, rescore through the LUT: same number
        model.build_lut(31);
        c.invalidate_cache();
        let fast = c.score(&model, &data, 0);
        assert!((fast - slow).abs() < 1e-10, "{fast} vs {slow}");
    }

    #[test]
    fn update_betas_reports_change_and_keeps_symmetric_lut() {
        let mut model = BetaBernoulli::symmetric(4, 0.5);
        model.build_lut(16);
        // same values: no change, LUT untouched
        assert!(!model.update_betas(&[0.5; 4], 16));
        assert!(model.lut.is_some());
        // new symmetric values: change reported, LUT retargeted not dropped
        assert!(model.update_betas(&[0.25; 4], 16));
        let lut = model.lut.as_ref().expect("symmetric refresh keeps LUT");
        assert!(lut.covers(0.25, 10));
        // asymmetric values: LUT dropped
        assert!(model.update_betas(&[0.25, 0.5, 0.25, 0.25], 16));
        assert!(model.lut.is_none());
    }

    #[test]
    fn predictive_p1_in_unit_interval() {
        let data = rand_data(30, 12, 7);
        let model = BetaBernoulli::symmetric(12, 0.1);
        let mut c = ClusterStats::empty(12);
        for r in 0..30 {
            c.add(&data, r);
        }
        let mut p = vec![0.0f32; 12];
        c.predictive_p1(&model, &mut p);
        assert!(p.iter().all(|&x| x > 0.0 && x < 1.0));
    }

    // ---- collapsed diagonal Gaussian ----

    #[test]
    fn gaussian_cached_score_matches_uncached() {
        let data = rand_real(20, 5, 11);
        let model = Model::Gaussian(DiagGaussian::new(5, 1.5, 0.3, 2.0, 1.2));
        let mut c = ClusterStats::empty(5);
        for r in 0..12 {
            c.add(&data, r);
        }
        for r in 0..20 {
            let cached = c.score(&model, &data, r);
            let plain = c.score_uncached(&model, &data, r);
            assert!(
                (cached - plain).abs() < 1e-9 * plain.abs().max(1.0),
                "row {r}: {cached} vs {plain}"
            );
            // the pre-fetched-row path reads the same cache
            let row_path = c.score_real(&model, data.row(r));
            assert_eq!(row_path.to_bits(), cached.to_bits());
        }
    }

    #[test]
    fn gaussian_chain_rule_matches_marginal() {
        // chain rule: log m(x_1..x_n) = Σ_i log p(x_i | x_<i), with
        // Student-t predictives and the closed-form NIG marginal
        let data = rand_real(8, 3, 12);
        let model = Model::Gaussian(DiagGaussian::new(3, 0.8, -0.2, 1.5, 0.9));
        let mut c = ClusterStats::empty(3);
        let mut chain = 0.0;
        for r in 0..8 {
            chain += c.score(&model, &data, r);
            c.add(&data, r);
        }
        let marginal = c.log_marginal(&model);
        assert!(
            (chain - marginal).abs() < 1e-8 * marginal.abs().max(1.0),
            "chain {chain} vs marginal {marginal}"
        );
    }

    #[test]
    fn gaussian_empty_score_is_prior_predictive() {
        let data = rand_real(4, 6, 13);
        let model = Model::Gaussian(DiagGaussian::new(6, 2.0, 0.0, 3.0, 2.0));
        let mut c = ClusterStats::empty(6);
        for r in 0..4 {
            // the n = 0 cache rebuild shares the precomputed prior
            // pieces with log_pred_empty, so the two paths are
            // bit-identical (kernels rely on this for the fresh-cluster
            // candidate score)
            let cached = c.score(&model, &data, r);
            let empty = model.log_pred_empty((&data).into(), r);
            assert_eq!(cached.to_bits(), empty.to_bits(), "row {r}");
        }
        assert_eq!(c.log_marginal(&model), 0.0);
    }

    #[test]
    fn gaussian_add_remove_roundtrip_and_exact_empty() {
        let data = rand_real(9, 4, 14);
        let model = Model::Gaussian(DiagGaussian::new(4, 1.0, 0.5, 2.5, 1.0));
        let mut c = ClusterStats::empty(4);
        for r in 0..8 {
            c.add(&data, r);
        }
        let before = c.score(&model, &data, 8);
        c.add(&data, 3);
        c.remove(&data, 3);
        let after = c.score(&model, &data, 8);
        assert!((before - after).abs() < 1e-9, "{before} vs {after}");
        for r in 0..8 {
            c.remove(&data, r);
        }
        assert!(c.is_empty());
        // moments snap to exact zeros at n = 0 (no removal drift)
        assert!(c.sum().iter().all(|&v| v == 0.0));
        assert!(c.sumsq().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gaussian_marginal_prefers_tight_cluster() {
        let model = Model::Gaussian(DiagGaussian::new(1, 1.0, 0.0, 1.0, 1.0));
        let tight = RealMat::from_dense(2, 1, vec![0.4, 0.4]);
        let far = RealMat::from_dense(2, 1, vec![-3.0, 3.0]);
        let mut a = ClusterStats::empty(1);
        a.add(&tight, 0);
        a.add(&tight, 1);
        let mut b = ClusterStats::empty(1);
        b.add(&far, 0);
        b.add(&far, 1);
        assert!(a.log_marginal(&model) > b.log_marginal(&model));
    }

    #[test]
    fn gaussian_copy_from_and_absorb_carry_moments() {
        let data = rand_real(10, 3, 15);
        let model = Model::Gaussian(DiagGaussian::new(3, 1.0, 0.0, 2.0, 1.0));
        let mut a = ClusterStats::empty(3);
        let mut b = ClusterStats::empty(3);
        for r in 0..5 {
            a.add(&data, r);
        }
        for r in 5..10 {
            b.add(&data, r);
        }
        a.absorb(&b);
        let mut all = ClusterStats::empty(3);
        for r in 0..10 {
            all.add(&data, r);
        }
        assert_eq!(a.n(), all.n());
        for i in 0..3 {
            assert!((a.sum()[i] - all.sum()[i]).abs() < 1e-12);
            assert!((a.sumsq()[i] - all.sumsq()[i]).abs() < 1e-12);
        }
        let mut dst = ClusterStats::empty(3);
        dst.copy_from(&all);
        let got = dst.score(&model, &data, 2);
        let want = all.score_uncached(&model, &data, 2);
        assert!((got - want).abs() < 1e-9);
    }

    // ---- Dirichlet–multinomial categorical ----

    #[test]
    fn categorical_cached_score_matches_uncached() {
        let cards = [3u32, 2, 4];
        let data = rand_cat(18, &cards, 21);
        let model = Model::Categorical(Categorical::new(&cards, 0.7));
        let mut c = ClusterStats::empty(model.stat_dims());
        for r in 0..10 {
            c.add(&data, r);
        }
        for r in 0..18 {
            let cached = c.score(&model, &data, r);
            let plain = c.score_uncached(&model, &data, r);
            assert!(
                (cached - plain).abs() < 1e-12,
                "row {r}: {cached} vs {plain}"
            );
        }
    }

    #[test]
    fn categorical_chain_rule_matches_marginal() {
        let cards = [4u32, 3];
        let data = rand_cat(9, &cards, 22);
        let model = Model::Categorical(Categorical::new(&cards, 0.5));
        let mut c = ClusterStats::empty(model.stat_dims());
        let mut chain = 0.0;
        for r in 0..9 {
            chain += c.score(&model, &data, r);
            c.add(&data, r);
        }
        let marginal = c.log_marginal(&model);
        assert!(
            (chain - marginal).abs() < 1e-9,
            "chain {chain} vs marginal {marginal}"
        );
    }

    #[test]
    fn categorical_empty_score_is_neg_sum_log_cards() {
        let cards = [3u32, 5];
        let data = rand_cat(3, &cards, 23);
        let model = Model::Categorical(Categorical::new(&cards, 1.3));
        let want = -(3.0f64.ln() + 5.0f64.ln());
        let mut c = ClusterStats::empty(model.stat_dims());
        for r in 0..3 {
            assert_eq!(model.log_pred_empty((&data).into(), r), want);
            assert!((c.score(&model, &data, r) - want).abs() < 1e-12, "row {r}");
        }
        assert_eq!(c.log_marginal(&model), 0.0);
    }

    // ---- Model / ModelSpec plumbing ----

    #[test]
    fn model_widths_match_data_widths() {
        let bb = Model::bernoulli(7, 0.5);
        assert_eq!((bb.stat_dims(), bb.table_rows()), (7, 7));
        let g = Model::Gaussian(DiagGaussian::new(3, 1.0, 0.0, 1.0, 1.0));
        assert_eq!((g.stat_dims(), g.table_rows()), (3, 6));
        let cat = Model::Categorical(Categorical::new(&[3, 2], 0.5));
        assert_eq!((cat.stat_dims(), cat.table_rows()), (5, 5));
        let r = RealMat::zeros(2, 3);
        let dr: DataRef = (&r).into();
        assert_eq!(dr.table_rows(), g.table_rows());
    }

    #[test]
    fn modelspec_parse_accepts_and_rejects() {
        assert_eq!(ModelSpec::parse("bernoulli").unwrap(), ModelSpec::Bernoulli);
        assert_eq!(ModelSpec::parse("gaussian").unwrap(), ModelSpec::DEFAULT_GAUSSIAN);
        assert_eq!(
            ModelSpec::parse("gaussian:2,0.5,3,1.5").unwrap(),
            ModelSpec::Gaussian {
                kappa0: 2.0,
                m0: 0.5,
                a0: 3.0,
                b0: 1.5
            }
        );
        assert_eq!(
            ModelSpec::parse("categorical:0.25").unwrap(),
            ModelSpec::Categorical { gamma: 0.25 }
        );
        for bad in [
            "foo",
            "bernoulli:0.5",
            "gaussian:1,2",
            "gaussian:1,2,3,x",
            "gaussian:-1,0,1,1",
            "categorical:-0.5",
            "categorical:zero",
        ] {
            assert!(ModelSpec::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn modelspec_build_rejects_kind_mismatch() {
        let bits = BinMat::zeros(4, 6);
        let real = RealMat::zeros(4, 3);
        let cat = rand_cat(4, &[3, 2], 31);
        assert!(ModelSpec::Bernoulli.build((&bits).into(), 0.5).is_ok());
        assert!(ModelSpec::Bernoulli.build((&real).into(), 0.5).is_err());
        assert!(ModelSpec::DEFAULT_GAUSSIAN.build((&real).into(), 0.5).is_ok());
        assert!(ModelSpec::DEFAULT_GAUSSIAN.build((&cat).into(), 0.5).is_err());
        let m = ModelSpec::DEFAULT_CATEGORICAL.build((&cat).into(), 0.5).unwrap();
        assert_eq!(m.stat_dims(), 5); // cards picked up from the data
        assert!(ModelSpec::DEFAULT_CATEGORICAL.build((&bits).into(), 0.5).is_err());
        assert_eq!(ModelSpec::Bernoulli.tag(), 0);
        assert_eq!(ModelSpec::DEFAULT_GAUSSIAN.tag(), 1);
        assert_eq!(ModelSpec::DEFAULT_CATEGORICAL.tag(), 2);
    }

    #[test]
    fn restore_hyper_restores_or_rejects() {
        let mut bb = Model::bernoulli(3, 0.5);
        bb.restore_hyper(&[0.2, 0.3, 0.4], 16).unwrap();
        assert_eq!(bb.as_bernoulli().beta, vec![0.2, 0.3, 0.4]);
        assert!(bb.restore_hyper(&[0.2, 0.3], 16).is_err());
        assert!(bb.restore_hyper(&[0.2, -1.0, 0.4], 16).is_err());

        let mut g = Model::Gaussian(DiagGaussian::new(2, 1.0, 0.0, 2.0, 1.5));
        assert!(g.restore_hyper(&[1.0, 0.0, 2.0, 1.5], 16).is_ok());
        assert!(g.restore_hyper(&[1.0, 0.0, 2.0, 1.6], 16).is_err());
        assert!(g.restore_hyper(&[1.0, 0.0, 2.0], 16).is_err());

        let mut cat = Model::Categorical(Categorical::new(&[3, 2], 0.5));
        assert!(cat.restore_hyper(&[0.5, 3.0, 2.0], 16).is_ok());
        assert!(cat.restore_hyper(&[0.7, 3.0, 2.0], 16).is_err());
        assert!(cat.restore_hyper(&[0.5, 3.0, 4.0], 16).is_err());
    }
}




