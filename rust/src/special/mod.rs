//! Special functions for the collapsed Dirichlet-process math: `lgamma`
//! (Lanczos), `digamma`, `log_beta`, stable `logsumexp` / `log_add_exp`.
//!
//! Everything here is built from scratch (no libm-extras in the offline
//! crate universe) and unit-tested against high-precision reference
//! values. Accuracies are ~1e-12 relative — far beyond what MCMC needs.

/// The Lanczos series itself, valid for x ≥ 0.5 only. Both `lgamma`
/// branches call this directly, so the reflection path never re-enters
/// `lgamma` (no recursion, no re-checked assert).
fn lanczos_core(x: f64) -> f64 {
    debug_assert!(x >= 0.5);
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Lanczos approximation (g = 7, n = 9) of `ln Γ(x)` for x > 0.
///
/// Reference: Numerical Recipes / Godfrey coefficients. Relative error
/// < 1e-13 over the tested range; reflection handles 0 < x < 0.5
/// (for x < 0.5 the reflected argument 1−x is ≥ 0.5, so the series is
/// evaluated once — the reflection never recurses).
pub fn lgamma(x: f64) -> f64 {
    assert!(x > 0.0, "lgamma requires x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - lanczos_core(1.0 - x);
    }
    lanczos_core(x)
}

/// `ln Γ(x+n) - ln Γ(x)` — the rising-factorial log, computed stably.
/// For small integer `n` this is a plain product (exact and faster);
/// used in CRP predictive terms where `n` is a count delta.
pub fn lgamma_ratio(x: f64, n: u64) -> f64 {
    if n <= 16 {
        let mut acc = 0.0;
        for i in 0..n {
            acc += (x + i as f64).ln();
        }
        acc
    } else {
        lgamma(x + n as f64) - lgamma(x)
    }
}

/// Digamma ψ(x) = d/dx ln Γ(x), for x > 0.
///
/// Recurrence up to x ≥ 6, then the asymptotic series. Abs error < 1e-11.
pub fn digamma(x: f64) -> f64 {
    assert!(x > 0.0, "digamma requires x > 0, got {x}");
    let mut x = x;
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    // ψ(x) ≈ ln x − 1/(2x) − Σ B_2n / (2n x^{2n})
    result + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2
                    * (1.0 / 120.0
                        - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0 - inv2 * (1.0 / 132.0)))))
}

/// `ln B(a, b) = lnΓ(a) + lnΓ(b) − lnΓ(a+b)`.
pub fn log_beta(a: f64, b: f64) -> f64 {
    lgamma(a) + lgamma(b) - lgamma(a + b)
}

/// Numerically stable `ln Σ exp(x_i)`. Returns −∞ for an empty slice.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m; // empty, all -inf, or a +inf/NaN dominates
    }
    let s: f64 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// `ln(e^a + e^b)` without materializing a slice.
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// In-place exp-normalize of log-weights; returns the log-normalizer.
/// After the call `xs` holds a probability vector.
pub fn exp_normalize(xs: &mut [f64]) -> f64 {
    let z = logsumexp(xs);
    if !z.is_finite() {
        // degenerate: uniform fallback keeps samplers alive
        let u = 1.0 / xs.len().max(1) as f64;
        for x in xs.iter_mut() {
            *x = u;
        }
        return z;
    }
    for x in xs.iter_mut() {
        *x = (*x - z).exp();
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from libm lgamma (cross-checked against mpmath).
    const LGAMMA_REF: &[(f64, f64)] = &[
        (0.1, 2.2527126517342055),
        (0.5, 0.5723649429247004),
        (1.0, 0.0),
        (1.5, -0.12078223763524543),
        (2.0, 0.0),
        (3.7, 1.4280723266653883),
        (10.0, 12.801827480081467),
        (100.5, 361.4355404677776),
        (1e6, 12815504.569147611),
    ];

    #[test]
    fn lgamma_matches_reference() {
        for &(x, want) in LGAMMA_REF {
            let got = lgamma(x);
            let tol = 1e-11 * want.abs().max(1.0);
            assert!(
                (got - want).abs() < tol,
                "lgamma({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn lgamma_recurrence() {
        // Γ(x+1) = x Γ(x)  ⇒  lgamma(x+1) − lgamma(x) = ln x
        for &x in &[0.3, 1.7, 5.0, 42.5, 1234.0] {
            let lhs = lgamma(x + 1.0) - lgamma(x);
            assert!((lhs - x.ln()).abs() < 1e-10, "recurrence fails at {x}");
        }
    }

    #[test]
    fn lgamma_ratio_matches_direct() {
        for &(x, n) in &[(0.5, 3u64), (2.0, 16), (7.3, 17), (0.01, 40)] {
            let want = lgamma(x + n as f64) - lgamma(x);
            let got = lgamma_ratio(x, n);
            assert!((got - want).abs() < 1e-9, "ratio({x},{n})");
        }
    }

    #[test]
    fn lgamma_tiny_x_matches_asymptotic() {
        // ln Γ(x) → −ln x − γx + O(x²) as x → 0⁺; the reflection branch
        // must reproduce this without blowing up (the new likelihoods'
        // log_marginal hits this region with small pseudo-counts)
        const EULER_GAMMA: f64 = 0.5772156649015329;
        for &x in &[1e-4, 1e-6, 1e-8, 1e-10] {
            let want = -x.ln() - EULER_GAMMA * x;
            let got = lgamma(x);
            assert!(
                (got - want).abs() < 1e-7 * want.abs(),
                "lgamma({x}) = {got}, asymptotic {want}"
            );
        }
        // and the recurrence lgamma(x+1) − lgamma(x) = ln x still holds
        // at the bottom of the range
        let x = 1e-8;
        assert!((lgamma(x + 1.0) - lgamma(x) - x.ln()).abs() < 1e-9);
    }

    #[test]
    fn lgamma_half_is_half_log_pi() {
        // x = 0.5 is the branch point between reflection and the direct
        // series; Γ(1/2) = √π exactly
        let want = 0.5 * std::f64::consts::PI.ln();
        assert!((lgamma(0.5) - want).abs() < 1e-14);
        // approaching from just below must agree with just above
        let below = lgamma(0.5 - 1e-12);
        let above = lgamma(0.5 + 1e-12);
        assert!((below - above).abs() < 1e-9, "branch mismatch at 0.5");
    }

    #[test]
    fn lgamma_ratio_boundary_cases() {
        // n = 0: lnΓ(x) − lnΓ(x) = 0 identically, even for tiny x where
        // lgamma itself is huge
        assert_eq!(lgamma_ratio(3.7, 0), 0.0);
        assert_eq!(lgamma_ratio(1e-9, 0), 0.0);
        // n = 16 is the last product-path value, n = 17 the first
        // lgamma-difference value; the two paths must agree across the
        // crossover and satisfy the rising-factorial recurrence
        for &x in &[1e-3, 0.5, 1.0, 7.3, 250.0] {
            let r16 = lgamma_ratio(x, 16);
            let r17 = lgamma_ratio(x, 17);
            assert!(
                (r17 - r16 - (x + 16.0).ln()).abs() < 1e-9 * r17.abs().max(1.0),
                "crossover recurrence at x={x}"
            );
            let direct16 = lgamma(x + 16.0) - lgamma(x);
            assert!(
                (r16 - direct16).abs() < 1e-9 * direct16.abs().max(1.0),
                "product path vs lgamma difference at x={x}"
            );
        }
    }

    #[test]
    fn digamma_matches_reference() {
        let refs = [
            (0.5, -1.9635100260214235),
            (1.0, -0.5772156649015329),
            (2.0, 0.4227843350984671),
            (10.0, 2.2517525890667211),
            (100.0, 4.6001618527380874),
        ];
        for (x, want) in refs {
            assert!((digamma(x) - want).abs() < 1e-11, "digamma({x})");
        }
    }

    #[test]
    fn digamma_recurrence() {
        // ψ(x+1) = ψ(x) + 1/x
        for &x in &[0.2, 1.0, 3.5, 77.0] {
            assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-11);
        }
    }

    #[test]
    fn log_beta_symmetry_and_value() {
        assert!((log_beta(2.0, 3.0) - (1.0f64 / 12.0).ln()).abs() < 1e-12);
        assert!((log_beta(0.7, 4.2) - log_beta(4.2, 0.7)).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_stability() {
        assert!((logsumexp(&[0.0, 0.0]) - 2.0f64.ln()).abs() < 1e-12);
        // huge offsets don't overflow
        let z = logsumexp(&[1000.0, 1000.0 + (3.0f64).ln()]);
        assert!((z - (1000.0 + (4.0f64).ln())).abs() < 1e-9);
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
        assert_eq!(logsumexp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }

    #[test]
    fn log_add_exp_matches_logsumexp() {
        for &(a, b) in &[(0.0, 0.0), (-700.0, 700.0), (3.0, -1.0)] {
            assert!((log_add_exp(a, b) - logsumexp(&[a, b])).abs() < 1e-12);
        }
        assert_eq!(log_add_exp(f64::NEG_INFINITY, 5.0), 5.0);
    }

    #[test]
    fn exp_normalize_sums_to_one() {
        let mut xs = vec![-1000.0, -1001.0, -999.5];
        exp_normalize(&mut xs);
        let s: f64 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(xs.iter().all(|&p| p >= 0.0));
    }
}
