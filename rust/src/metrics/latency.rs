//! Log-bucketed latency histogram for the serving layer's
//! `--serve-trace` output (p50/p99 per query kind, DESIGN.md §13).
//!
//! Latencies are recorded in microseconds into power-of-two buckets
//! (bucket `i` covers `[2^(i-1), 2^i)` µs, bucket 0 covers `< 1` µs),
//! so `record` is O(1), the whole histogram is a fixed 64-slot array
//! (no allocation on the serve hot path), and quantiles are answered
//! as the covering bucket's upper bound — a ≤ 2× overestimate, which
//! is the right bias for a latency SLO line.

use std::time::Duration;

/// Fixed-size log₂-bucketed microsecond histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: [0u64; 64],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    /// Record one observed latency.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let idx = if us == 0 {
            0
        } else {
            64 - us.leading_zeros() as usize
        };
        self.buckets[idx.min(63)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in microseconds (0.0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Largest recorded latency in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) in microseconds: the upper
    /// bound of the bucket holding the ⌈q·count⌉-th observation,
    /// clamped to the observed maximum. Returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let upper = if idx == 0 { 1u64 } else { 1u64 << idx };
                return (upper.min(self.max_us.max(1))) as f64;
            }
        }
        self.max_us as f64
    }

    /// Merge another histogram into this one (per-connection books are
    /// folded into the server-wide book at trace-emission time).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.max_us(), 0);
    }

    #[test]
    fn quantiles_are_monotone_and_bound_the_data() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 2, 3, 5, 9, 17, 33, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        // bucket upper bounds overestimate by at most 2x, and are
        // clamped to the observed max
        assert!(p99 <= h.max_us() as f64);
        assert!(p50 >= 5.0 && p50 <= 18.0, "p50 {p50}");
    }

    #[test]
    fn single_value_quantile_hits_its_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(700));
        // 700µs lands in (512, 1024]; upper bound clamped to max 700
        assert_eq!(h.quantile(0.5), 700.0);
        assert_eq!(h.quantile(1.0), 700.0);
        assert_eq!(h.max_us(), 700);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(2000));
        b.record(Duration::from_micros(30));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_us(), 2000);
        assert!(a.mean_us() > 0.0);
    }

    #[test]
    fn zero_duration_is_recorded() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(0));
        assert_eq!(h.count(), 1);
        // empty-bucket upper bound is 1µs but clamped to max(1)
        assert_eq!(h.quantile(1.0), 1.0);
    }
}
