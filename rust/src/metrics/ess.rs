//! Effective sample size via autocorrelation with Geyer's initial
//! positive sequence truncation — the Fig. 2a metric ("effective number
//! of samples per MCMC iteration").

use crate::util::mean;

/// Autocovariance at lag `k` (biased normalization, standard for ESS).
fn autocov(xs: &[f64], m: f64, k: usize) -> f64 {
    let n = xs.len();
    let mut acc = 0.0;
    for i in 0..n - k {
        acc += (xs[i] - m) * (xs[i + k] - m);
    }
    acc / n as f64
}

/// ESS of a scalar chain: `n / (1 + 2 Σ ρ_t)`, truncating the sum at the
/// first non-positive *pair* of autocorrelations (Geyer 1992). Returns
/// `n` for white noise, ~0 for a frozen chain.
pub fn effective_sample_size(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    let m = mean(xs);
    let c0 = autocov(xs, m, 0);
    if c0 <= 1e-300 {
        // constant chain: no information at all
        return 1.0;
    }
    let mut rho_sum = 0.0;
    let max_lag = n / 2;
    let mut t = 1;
    while t + 1 < max_lag {
        let pair = (autocov(xs, m, t) + autocov(xs, m, t + 1)) / c0;
        if pair <= 0.0 {
            break;
        }
        rho_sum += pair;
        t += 2;
    }
    let ess = n as f64 / (1.0 + 2.0 * rho_sum);
    ess.clamp(1.0, n as f64)
}

/// ESS per iteration — the Fig. 2a y-axis.
pub fn ess_per_iteration(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    effective_sample_size(xs) / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{normal, Pcg64};

    #[test]
    fn white_noise_ess_near_n() {
        let mut rng = Pcg64::seed_from(1);
        let xs: Vec<f64> = (0..4000).map(|_| normal(&mut rng)).collect();
        let ess = effective_sample_size(&xs);
        assert!(ess > 2500.0, "white-noise ESS {ess} of 4000");
    }

    #[test]
    fn ar1_ess_matches_closed_form() {
        // AR(1) with coefficient φ has ESS/n = (1-φ)/(1+φ)
        let phi: f64 = 0.8;
        let mut rng = Pcg64::seed_from(2);
        let n = 60_000;
        let mut xs = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x = phi * x + (1.0 - phi * phi).sqrt() * normal(&mut rng);
            xs.push(x);
        }
        let want = n as f64 * (1.0 - phi) / (1.0 + phi);
        let got = effective_sample_size(&xs);
        assert!(
            (got - want).abs() < 0.25 * want,
            "AR(1) ESS {got}, closed form {want}"
        );
    }

    #[test]
    fn frozen_chain_ess_is_minimal() {
        let xs = vec![3.0; 1000];
        assert_eq!(effective_sample_size(&xs), 1.0);
    }

    #[test]
    fn short_chains_dont_panic() {
        assert_eq!(effective_sample_size(&[]), 0.0);
        assert_eq!(effective_sample_size(&[1.0, 2.0]), 2.0);
    }

    #[test]
    fn ess_per_iteration_bounded() {
        let mut rng = Pcg64::seed_from(3);
        let xs: Vec<f64> = (0..1000).map(|_| normal(&mut rng)).collect();
        let e = ess_per_iteration(&xs);
        assert!(e > 0.0 && e <= 1.0);
    }
}
