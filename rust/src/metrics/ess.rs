//! Effective sample size via autocorrelation with Geyer's initial
//! positive sequence truncation — the Fig. 2a metric ("effective number
//! of samples per MCMC iteration").

use crate::util::mean;

/// Autocovariance at lag `k` (biased normalization, standard for ESS).
fn autocov(xs: &[f64], m: f64, k: usize) -> f64 {
    let n = xs.len();
    let mut acc = 0.0;
    for i in 0..n - k {
        acc += (xs[i] - m) * (xs[i + k] - m);
    }
    acc / n as f64
}

/// ESS of a scalar chain: `n / (1 + 2 Σ ρ_t)`, truncating the sum at the
/// first non-positive *pair* of autocorrelations (Geyer 1992). Returns
/// `n` for white noise, ~0 for a frozen chain.
pub fn effective_sample_size(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    let m = mean(xs);
    let c0 = autocov(xs, m, 0);
    if c0 <= 1e-300 {
        // constant chain: no information at all
        return 1.0;
    }
    let mut rho_sum = 0.0;
    // Geyer pairs (ρ_t + ρ_{t+1}) for odd t; the last admissible pair may
    // end exactly at lag n/2, so the bound is inclusive — `<` here would
    // silently drop the final pair whenever n/2 is even
    let max_lag = n / 2;
    let mut t = 1;
    while t + 1 <= max_lag {
        let pair = (autocov(xs, m, t) + autocov(xs, m, t + 1)) / c0;
        if pair <= 0.0 {
            break;
        }
        rho_sum += pair;
        t += 2;
    }
    let ess = n as f64 / (1.0 + 2.0 * rho_sum);
    ess.clamp(1.0, n as f64)
}

/// ESS per iteration — the Fig. 2a y-axis.
pub fn ess_per_iteration(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    effective_sample_size(xs) / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{normal, Pcg64};

    #[test]
    fn white_noise_ess_near_n() {
        let mut rng = Pcg64::seed_from(1);
        let xs: Vec<f64> = (0..4000).map(|_| normal(&mut rng)).collect();
        let ess = effective_sample_size(&xs);
        assert!(ess > 2500.0, "white-noise ESS {ess} of 4000");
    }

    #[test]
    fn ar1_ess_matches_closed_form() {
        // AR(1) with coefficient φ has ESS/n = (1-φ)/(1+φ)
        let phi: f64 = 0.8;
        let mut rng = Pcg64::seed_from(2);
        let n = 60_000;
        let mut xs = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x = phi * x + (1.0 - phi * phi).sqrt() * normal(&mut rng);
            xs.push(x);
        }
        let want = n as f64 * (1.0 - phi) / (1.0 + phi);
        let got = effective_sample_size(&xs);
        assert!(
            (got - want).abs() < 0.25 * want,
            "AR(1) ESS {got}, closed form {want}"
        );
    }

    #[test]
    fn frozen_chain_ess_is_minimal() {
        let xs = vec![3.0; 1000];
        assert_eq!(effective_sample_size(&xs), 1.0);
    }

    #[test]
    fn short_chains_dont_panic() {
        assert_eq!(effective_sample_size(&[]), 0.0);
        assert_eq!(effective_sample_size(&[1.0, 2.0]), 2.0);
    }

    #[test]
    fn ar1_ess_matches_closed_form_even_and_odd_n() {
        // the truncation bound is parity-sensitive (the final Geyer pair
        // lands exactly on lag n/2 only when n/2 is even), so the AR(1)
        // closed form ESS/n = (1-φ)/(1+φ) is pinned at an even and an
        // odd chain length
        let phi: f64 = 0.6;
        for n in [40_000usize, 40_001] {
            let mut rng = Pcg64::seed_from(7 + n as u64);
            let mut xs = Vec::with_capacity(n);
            let mut x = 0.0;
            for _ in 0..n {
                x = phi * x + (1.0 - phi * phi).sqrt() * normal(&mut rng);
                xs.push(x);
            }
            let want = n as f64 * (1.0 - phi) / (1.0 + phi);
            let got = effective_sample_size(&xs);
            assert!(
                (got - want).abs() < 0.25 * want,
                "AR(1) ESS {got} at n={n}, closed form {want}"
            );
        }
    }

    /// Independent slow reference for Geyer's initial-positive-sequence
    /// ESS, written from the definition: sum pairs Γ_k = ρ_{2k-1} + ρ_{2k}
    /// while positive, with the last admissible pair ending at lag
    /// ⌊n/2⌋ inclusive. Randomized equality against the production code
    /// pins the truncation bound (the pre-fix `<` bound diverges from
    /// this on chains whose positive sequence reaches the boundary).
    fn reference_ess(xs: &[f64]) -> f64 {
        let n = xs.len();
        if n < 4 {
            return n as f64;
        }
        let m = mean(xs);
        let c0 = autocov(xs, m, 0);
        if c0 <= 1e-300 {
            return 1.0;
        }
        let mut rho_sum = 0.0;
        for t in (1..).step_by(2) {
            if t + 1 > n / 2 {
                break;
            }
            let pair = (autocov(xs, m, t) + autocov(xs, m, t + 1)) / c0;
            if pair <= 0.0 {
                break;
            }
            rho_sum += pair;
        }
        (n as f64 / (1.0 + 2.0 * rho_sum)).clamp(1.0, n as f64)
    }

    #[test]
    fn ess_matches_independent_reference_on_short_chains() {
        // short, strongly-correlated chains are exactly where the
        // positive sequence runs into the lag-n/2 boundary, so the
        // truncation bound is load-bearing here
        let phi = 0.95;
        for n in [8usize, 9, 12, 16, 17, 24, 32, 33, 64] {
            for seed in 0..20u64 {
                let mut rng = Pcg64::seed_from(100 + seed);
                let mut xs = Vec::with_capacity(n);
                let mut x = 0.0;
                for _ in 0..n {
                    x = phi * x + (1.0 - phi * phi).sqrt() * normal(&mut rng);
                    xs.push(x);
                }
                let got = effective_sample_size(&xs);
                let want = reference_ess(&xs);
                assert!(
                    (got - want).abs() < 1e-12 * want.abs().max(1.0),
                    "ESS {got} vs reference {want} at n={n} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn autocov_at_half_length_matches_hand_computed() {
        // biased normalization (divide by n, not n-k) at the deepest lag
        // the Geyer loop can reach, k = n/2, for both parities of n
        let xs = [1.0, 2.0, 3.0, 4.0];
        let m = mean(&xs); // 2.5
        // Σ_{i<2} (x_i-m)(x_{i+2}-m) / 4 = ((-1.5)(0.5) + (-0.5)(1.5)) / 4
        assert!((autocov(&xs, m, 2) - (-0.375)).abs() < 1e-15);
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        let my = mean(&ys); // 3.0
        // Σ_{i<3} (y_i-m)(y_{i+2}-m) / 5 = (0 + (-1)(1) + 0) / 5
        assert!((autocov(&ys, my, 2) - (-0.2)).abs() < 1e-15);
    }

    #[test]
    fn ess_per_iteration_bounded() {
        let mut rng = Pcg64::seed_from(3);
        let xs: Vec<f64> = (0..1000).map(|_| normal(&mut rng)).collect();
        let e = ess_per_iteration(&xs);
        assert!(e > 0.0 && e <= 1.0);
    }
}
