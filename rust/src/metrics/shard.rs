//! Per-supercluster trace recording: one row per (round, shard) with
//! the series that make the non-uniform μ modes observable — μ_k, data
//! occupancy, cluster count, measured map-step seconds, and (under
//! `--overlap on`) measured idle / barrier-wait wall-clock against the
//! real concurrent map window. This is the
//! sink behind `repro run --shard-trace out.csv`; the rows come from
//! [`crate::coordinator::Coordinator::shard_stats`].

use crate::data::io::CsvWriter;
use std::path::Path;

/// One (round, shard) record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardTraceRow {
    /// global round index
    pub round: u64,
    /// supercluster index k
    pub shard: u64,
    /// μ_k after the round's granularity update
    pub mu: f64,
    /// data rows resident on the shard after the round
    pub rows: u64,
    /// live clusters on the shard after the round
    pub clusters: u64,
    /// measured map-step compute seconds for the shard this round
    pub map_seconds: f64,
    /// measured sweep throughput for the shard this round
    /// (rows × sweeps run (base + bonus) / map seconds; 0 when
    /// unmeasurable)
    pub rows_per_s: f64,
    /// residual idle seconds this round. Under `--overlap on` this is
    /// **measured** wall-clock (final completion drained → map window
    /// closed, on the real concurrent host timeline); with overlap off
    /// it is reconstructed from durations (critical path − map seconds)
    pub idle_s: f64,
    /// the wait the shard would have had with no bonus sweeps — the
    /// bulk-synchronous barrier tax. Measured (base completion → window
    /// close) under `--overlap on`; equals `idle_s` with overlap off
    pub barrier_wait_s: f64,
    /// work-stealing bonus sweeps granted this round (0 with
    /// `--overlap off`)
    pub bonus_sweeps: u64,
    /// supervised retries consumed this round (0 with `--supervise off`)
    pub retries: u64,
    /// watchdog timeouts fired on this shard's attempts this round
    pub watchdog_fires: u64,
    /// 1 when the shard ran this round quarantined/degraded (sweep
    /// skipped, assignments frozen), else 0
    pub quarantined: u64,
}

/// A full per-shard run trace (K rows appended per round).
#[derive(Debug, Clone, Default)]
pub struct ShardTrace {
    /// all recorded rows, in push order
    pub rows: Vec<ShardTraceRow>,
    /// run label for downstream tooling
    pub label: String,
}

impl ShardTrace {
    /// Empty trace with a run label.
    pub fn new(label: &str) -> Self {
        ShardTrace {
            rows: Vec::new(),
            label: label.to_string(),
        }
    }

    /// Append one (round, shard) record.
    pub fn push(&mut self, row: ShardTraceRow) {
        self.rows.push(row);
    }

    /// Max/mean data-occupancy ratio for one round (1.0 = perfectly
    /// balanced shards) — the load-balance statistic the adaptive μ mode
    /// steers. `None` when the round is absent or holds no data.
    pub fn imbalance(&self, round: u64) -> Option<f64> {
        let occ: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| r.round == round)
            .map(|r| r.rows as f64)
            .collect();
        if occ.is_empty() {
            return None;
        }
        let mean = occ.iter().sum::<f64>() / occ.len() as f64;
        if mean <= 0.0 {
            return None;
        }
        let max = occ.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Some(max / mean)
    }

    /// Write the trace as CSV (one row per (round, shard)).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "round",
                "shard",
                "mu",
                "rows",
                "clusters",
                "map_seconds",
                "rows_per_s",
                "idle_s",
                "barrier_wait_s",
                "bonus_sweeps",
                "retries",
                "watchdog_fires",
                "quarantined",
            ],
        )?;
        for r in &self.rows {
            w.row(&[
                r.round as f64,
                r.shard as f64,
                r.mu,
                r.rows as f64,
                r.clusters as f64,
                r.map_seconds,
                r.rows_per_s,
                r.idle_s,
                r.barrier_wait_s,
                r.bonus_sweeps as f64,
                r.retries as f64,
                r.watchdog_fires as f64,
                r.quarantined as f64,
            ])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, shard: u64, mu: f64, rows: u64) -> ShardTraceRow {
        ShardTraceRow {
            round,
            shard,
            mu,
            rows,
            clusters: 2,
            map_seconds: 0.01,
            rows_per_s: 1000.0,
            idle_s: 0.002,
            barrier_wait_s: 0.003,
            bonus_sweeps: 1,
            retries: 0,
            watchdog_fires: 0,
            quarantined: 0,
        }
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        let mut t = ShardTrace::new("test");
        t.push(row(0, 0, 0.5, 30));
        t.push(row(0, 1, 0.5, 10));
        let got = t.imbalance(0).unwrap();
        assert!((got - 1.5).abs() < 1e-12, "{got}");
        assert_eq!(t.imbalance(7), None);
        let mut empty_round = ShardTrace::new("z");
        empty_round.push(row(1, 0, 1.0, 0));
        assert_eq!(empty_round.imbalance(1), None);
    }

    #[test]
    fn csv_emission_includes_all_series() {
        let mut t = ShardTrace::new("emit");
        t.push(row(0, 0, 0.25, 100));
        t.push(row(0, 1, 0.75, 300));
        let dir = std::env::temp_dir().join("cc_shard_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("mu"));
        assert!(text.contains("map_seconds"));
        assert!(text.contains("rows_per_s"));
        assert!(text.contains("idle_s"));
        assert!(text.contains("barrier_wait_s"));
        assert!(text.contains("bonus_sweeps"));
        assert!(text.contains("retries"));
        assert!(text.contains("watchdog_fires"));
        assert!(text.contains("quarantined"));
        assert!(text.contains("0.75"));
    }
}
