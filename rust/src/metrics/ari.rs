//! Adjusted Rand index between two labelings — quantifies latent-
//! structure recovery against the synthetic generator's ground truth
//! (supports the Fig. 6/7 "latent structure" series).

use std::collections::HashMap;

/// Adjusted Rand index in [-1, 1]; 1 = identical partitions, ~0 = chance.
pub fn adjusted_rand_index(a: &[u32], b: &[u32]) -> f64 {
    assert_eq!(a.len(), b.len(), "labelings must align");
    let n = a.len();
    if n <= 1 {
        return 1.0;
    }
    // contingency table
    let mut table: HashMap<(u32, u32), u64> = HashMap::new();
    let mut rows: HashMap<u32, u64> = HashMap::new();
    let mut cols: HashMap<u32, u64> = HashMap::new();
    for i in 0..n {
        *table.entry((a[i], b[i])).or_default() += 1;
        *rows.entry(a[i]).or_default() += 1;
        *cols.entry(b[i]).or_default() += 1;
    }
    let c2 = |x: u64| (x * x.saturating_sub(1)) as f64 / 2.0;
    let sum_ij: f64 = table.values().map(|&v| c2(v)).sum();
    let sum_a: f64 = rows.values().map(|&v| c2(v)).sum();
    let sum_b: f64 = cols.values().map(|&v| c2(v)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // both partitions trivial (all-singletons or all-one)
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn identical_partitions_score_one() {
        let z = [0u32, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&z, &z) - 1.0).abs() < 1e-12);
        // label permutation is still perfect
        let relabeled = [5u32, 5, 9, 9, 7, 7];
        assert!((adjusted_rand_index(&z, &relabeled) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_partitions_score_near_zero() {
        let mut rng = Pcg64::seed_from(1);
        let n = 5000;
        let a: Vec<u32> = (0..n).map(|_| rng.next_below(10) as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.next_below(10) as u32).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.02, "chance ARI {ari}");
    }

    #[test]
    fn partial_agreement_in_between() {
        // b merges two of a's clusters
        let a = [0u32, 0, 1, 1, 2, 2, 3, 3];
        let b = [0u32, 0, 0, 0, 1, 1, 2, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.3 && ari < 1.0, "merge ARI {ari}");
    }

    #[test]
    fn trivial_inputs() {
        assert_eq!(adjusted_rand_index(&[], &[]), 1.0);
        assert_eq!(adjusted_rand_index(&[1], &[7]), 1.0);
    }

    // ---- pinned against hand-computed contingency tables ----

    #[test]
    fn pinned_straddling_split() {
        // a = 000|111, b = 00|11|22. Contingency table:
        //        b=0 b=1 b=2 | rows
        //   a=0:  2   1   0  |  3
        //   a=1:  0   1   2  |  3
        //   cols: 2   2   2  |  n=6
        // sum_ij = C(2,2)+C(2,2) = 2;  sum_a = 2*C(3,2) = 6;
        // sum_b = 3*C(2,2) = 3;  total = C(6,2) = 15
        // expected = 6*3/15 = 1.2;  max = (6+3)/2 = 4.5
        // ARI = (2 - 1.2) / (4.5 - 1.2) = 0.8/3.3 = 8/33
        let a = [0u32, 0, 0, 1, 1, 1];
        let b = [0u32, 0, 1, 1, 2, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!((ari - 8.0 / 33.0).abs() < 1e-12, "got {ari}, want 8/33");
    }

    #[test]
    fn pinned_crossed_pairs_are_negative() {
        // a = 00|11, b = 0101: every table cell is 1, so sum_ij = 0.
        // sum_a = sum_b = 2, total = C(4,2) = 6, expected = 2*2/6 = 2/3,
        // max = 2.  ARI = (0 - 2/3)/(2 - 2/3) = -1/2 — below-chance
        // agreement is negative by construction of the adjustment.
        let a = [0u32, 0, 1, 1];
        let b = [0u32, 1, 0, 1];
        let ari = adjusted_rand_index(&a, &b);
        assert!((ari + 0.5).abs() < 1e-12, "got {ari}, want -1/2");
    }

    #[test]
    fn pinned_singletons_vs_lump_is_zero() {
        // a all-singletons (sum_a = 0), b one lump: sum_ij = 0 and
        // expected = 0, so ARI = 0/((0 + C(4,2))/2) = 0 exactly — the
        // two degenerate partitions carry no shared information.
        let a = [0u32, 1, 2, 3];
        let b = [5u32, 5, 5, 5];
        assert_eq!(adjusted_rand_index(&a, &b), 0.0);
    }

    #[test]
    fn symmetric_in_its_arguments() {
        let mut rng = Pcg64::seed_from(77);
        for _ in 0..20 {
            let n = 64;
            let a: Vec<u32> = (0..n).map(|_| rng.next_below(5) as u32).collect();
            let b: Vec<u32> = (0..n).map(|_| rng.next_below(7) as u32).collect();
            let ab = adjusted_rand_index(&a, &b);
            let ba = adjusted_rand_index(&b, &a);
            assert!((ab - ba).abs() < 1e-12, "asymmetric: {ab} vs {ba}");
        }
    }
}
