//! Adjusted Rand index between two labelings — quantifies latent-
//! structure recovery against the synthetic generator's ground truth
//! (supports the Fig. 6/7 "latent structure" series).

use std::collections::HashMap;

/// Adjusted Rand index in [-1, 1]; 1 = identical partitions, ~0 = chance.
pub fn adjusted_rand_index(a: &[u32], b: &[u32]) -> f64 {
    assert_eq!(a.len(), b.len(), "labelings must align");
    let n = a.len();
    if n <= 1 {
        return 1.0;
    }
    // contingency table
    let mut table: HashMap<(u32, u32), u64> = HashMap::new();
    let mut rows: HashMap<u32, u64> = HashMap::new();
    let mut cols: HashMap<u32, u64> = HashMap::new();
    for i in 0..n {
        *table.entry((a[i], b[i])).or_default() += 1;
        *rows.entry(a[i]).or_default() += 1;
        *cols.entry(b[i]).or_default() += 1;
    }
    let c2 = |x: u64| (x * x.saturating_sub(1)) as f64 / 2.0;
    let sum_ij: f64 = table.values().map(|&v| c2(v)).sum();
    let sum_a: f64 = rows.values().map(|&v| c2(v)).sum();
    let sum_b: f64 = cols.values().map(|&v| c2(v)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // both partitions trivial (all-singletons or all-one)
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn identical_partitions_score_one() {
        let z = [0u32, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&z, &z) - 1.0).abs() < 1e-12);
        // label permutation is still perfect
        let relabeled = [5u32, 5, 9, 9, 7, 7];
        assert!((adjusted_rand_index(&z, &relabeled) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_partitions_score_near_zero() {
        let mut rng = Pcg64::seed_from(1);
        let n = 5000;
        let a: Vec<u32> = (0..n).map(|_| rng.next_below(10) as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.next_below(10) as u32).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.02, "chance ARI {ari}");
    }

    #[test]
    fn partial_agreement_in_between() {
        // b merges two of a's clusters
        let a = [0u32, 0, 1, 1, 2, 2, 3, 3];
        let b = [0u32, 0, 0, 0, 1, 1, 2, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.3 && ari < 1.0, "merge ARI {ari}");
    }

    #[test]
    fn trivial_inputs() {
        assert_eq!(adjusted_rand_index(&[], &[]), 1.0);
        assert_eq!(adjusted_rand_index(&[1], &[7]), 1.0);
    }
}
