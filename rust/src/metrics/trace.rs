//! MCMC trace recording: one row per global iteration with the metrics
//! every figure needs (modeled/measured wall-clock, predictive log-lik,
//! cluster count, α, comm bytes), plus CSV/JSON emitters.

use crate::data::io::CsvWriter;
use crate::util::json::Json;
use std::path::Path;

/// One global-iteration record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRow {
    /// global iteration (sweep or round) index
    pub iter: u64,
    /// modeled distributed wall-clock, cumulative seconds
    pub modeled_time_s: f64,
    /// measured single-host wall-clock, cumulative seconds
    pub measured_time_s: f64,
    /// mean test-set predictive log-likelihood per datum
    pub predictive_loglik: f64,
    /// total live clusters
    pub num_clusters: u64,
    /// concentration α after the iteration
    pub alpha: f64,
    /// bytes moved this round by map/reduce/shuffle
    pub bytes: u64,
}

/// A full run trace.
#[derive(Debug, Clone, Default)]
pub struct McmcTrace {
    /// recorded rows in iteration order
    pub rows: Vec<TraceRow>,
    /// run label for downstream tooling
    pub label: String,
}

impl McmcTrace {
    /// Empty trace with a run label.
    pub fn new(label: &str) -> Self {
        McmcTrace {
            rows: Vec::new(),
            label: label.to_string(),
        }
    }

    /// Append one iteration record.
    pub fn push(&mut self, row: TraceRow) {
        self.rows.push(row);
    }

    /// Last recorded predictive log-likelihood.
    pub fn final_loglik(&self) -> Option<f64> {
        self.rows.last().map(|r| r.predictive_loglik)
    }

    /// Last recorded cluster count.
    pub fn final_clusters(&self) -> Option<u64> {
        self.rows.last().map(|r| r.num_clusters)
    }

    /// Modeled time to first reach a predictive log-lik threshold — the
    /// speedup/saturation statistic of Figs. 6–8.
    pub fn time_to_loglik(&self, threshold: f64) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.predictive_loglik >= threshold)
            .map(|r| r.modeled_time_s)
    }

    /// Series of (modeled_time, loglik) for plotting.
    pub fn loglik_series(&self) -> Vec<(f64, f64)> {
        self.rows
            .iter()
            .map(|r| (r.modeled_time_s, r.predictive_loglik))
            .collect()
    }

    /// Write the trace as CSV (one row per iteration).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "iter",
                "modeled_time_s",
                "measured_time_s",
                "predictive_loglik",
                "num_clusters",
                "alpha",
                "bytes",
            ],
        )?;
        for r in &self.rows {
            w.row(&[
                r.iter as f64,
                r.modeled_time_s,
                r.measured_time_s,
                r.predictive_loglik,
                r.num_clusters as f64,
                r.alpha,
                r.bytes as f64,
            ])?;
        }
        Ok(())
    }

    /// The trace as a JSON object (label + per-series arrays).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("label", Json::str(&self.label));
        obj.set(
            "iters",
            Json::arr_nums(&self.rows.iter().map(|r| r.iter as f64).collect::<Vec<_>>()),
        );
        obj.set(
            "modeled_time_s",
            Json::arr_nums(&self.rows.iter().map(|r| r.modeled_time_s).collect::<Vec<_>>()),
        );
        obj.set(
            "predictive_loglik",
            Json::arr_nums(
                &self
                    .rows
                    .iter()
                    .map(|r| r.predictive_loglik)
                    .collect::<Vec<_>>(),
            ),
        );
        obj.set(
            "num_clusters",
            Json::arr_nums(
                &self
                    .rows
                    .iter()
                    .map(|r| r.num_clusters as f64)
                    .collect::<Vec<_>>(),
            ),
        );
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(iter: u64, t: f64, ll: f64) -> TraceRow {
        TraceRow {
            iter,
            modeled_time_s: t,
            measured_time_s: t * 0.5,
            predictive_loglik: ll,
            num_clusters: 10 + iter,
            alpha: 1.0,
            bytes: 100,
        }
    }

    #[test]
    fn time_to_threshold() {
        let mut t = McmcTrace::new("test");
        t.push(row(0, 1.0, -10.0));
        t.push(row(1, 2.0, -5.0));
        t.push(row(2, 3.0, -2.0));
        assert_eq!(t.time_to_loglik(-5.0), Some(2.0));
        assert_eq!(t.time_to_loglik(-1.0), None);
        assert_eq!(t.final_loglik(), Some(-2.0));
        assert_eq!(t.final_clusters(), Some(12));
    }

    #[test]
    fn csv_and_json_emission() {
        let mut t = McmcTrace::new("emit");
        t.push(row(0, 1.0, -3.0));
        let dir = std::env::temp_dir().join("cc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("predictive_loglik"));
        assert!(text.contains("-3"));
        let j = t.to_json().to_string();
        assert!(j.contains("\"label\":\"emit\""));
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str().unwrap(), "emit");
    }
}
