//! Evaluation metrics: effective sample size (Fig. 2a), adjusted Rand
//! index for latent-structure recovery, and MCMC trace recording with
//! CSV/JSON emission for the figure benches.

pub mod ari;
pub mod ess;
pub mod trace;

pub use ari::adjusted_rand_index;
pub use ess::effective_sample_size;
pub use trace::{McmcTrace, TraceRow};
