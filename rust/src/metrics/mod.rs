//! Evaluation metrics: effective sample size (Fig. 2a), adjusted Rand
//! index for latent-structure recovery, MCMC trace recording with
//! CSV/JSON emission for the figure benches, the per-supercluster
//! trace (μ_k, occupancy, map time) that makes the non-uniform
//! [`crate::coordinator::MuMode`]s observable, and the log-bucketed
//! latency histogram behind the serving layer's `--serve-trace`
//! p50/p99 output.

pub mod ari;
pub mod ess;
pub mod latency;
pub mod shard;
pub mod trace;

pub use ari::adjusted_rand_index;
pub use ess::effective_sample_size;
pub use latency::LatencyHistogram;
pub use shard::{ShardTrace, ShardTraceRow};
pub use trace::{McmcTrace, TraceRow};
