//! The paper's auxiliary-variable representation (§3): nesting partitions
//! in the Dirichlet process.
//!
//! `DP(α, H)` is generated in stages: `γ ~ Dir(αμ)`, `G_k ~ DP(αμ_k, H)`
//! independently, `G = Σ_k γ_k G_k` — and `G ~ DP(α, H)` again. With the
//! sticks marginalized this yields the **two-stage Chinese restaurant
//! process**: a datum first picks a restaurant (supercluster) by
//! Dirichlet-multinomial popularity, then a table within it by local CRP
//! popularity with concentration `αμ_k`.
//!
//! This module implements:
//! * prior simulators for the standard CRP and the two-stage CRP — the
//!   marginal-equivalence test (two-stage ⇒ CRP(α)) is the paper's
//!   central theorem, checked numerically in `rust/tests/`;
//! * the joint priors of Eq. 4 (Dirichlet-multinomial × K local CRPs)
//!   and Eq. 5 (their cancellation), checked equal term-by-term;
//! * the cluster→supercluster shuffle kernel;
//! * the μ granularity updates behind
//!   [`crate::coordinator::MuMode`]: the exact conditional-Dirichlet
//!   Gibbs draw given supercluster occupancies
//!   ([`sample_mu_given_occupancy`]) and the load-balancing
//!   Metropolis–Hastings retarget ([`adaptive_mu_step`]) — see
//!   DESIGN.md §6 for the invariance argument.
//!
//! ## A note on Eq. 7
//!
//! The paper states the shuffle conditional as
//! `Pr(s_j = k | ·) = μ_k (αμ_k + J_{k∖j}) / (α + Σ_{k'} J_{k'∖j})`.
//! However, from the paper's own Eq. 5 the joint depends on `{s_j}` only
//! through `Π_k μ_k^{J_k}`, so the exact Gibbs conditional is simply
//! `Pr(s_j = k | ·) ∝ μ_k` — conditioned on the partition, supercluster
//! labels are i.i.d. categorical(μ). (A direct two-datum generative
//! calculation confirms this; see `eq7_vs_exact` tests and DESIGN.md.)
//! We implement **both**: [`ShuffleKernel::Exact`] (default; provably
//! leaves Eq. 5 invariant) and [`ShuffleKernel::PaperEq7`] (as printed,
//! kept for ablation/comparison).

use crate::rng::{categorical, categorical_log, dirichlet, Pcg64};
use crate::special::{lgamma, logsumexp};

/// Per-component concentration of the symmetric Dirichlet prior on μ,
/// `μ ~ Dir(ξ/K, …, ξ/K)` (paper §4). We fix `ξ = K`, i.e. the uniform
/// prior `Dir(1, …, 1)`: it is the least-informative choice on the
/// simplex and keeps the conditional posterior shapes `1 + J_k` strictly
/// above one, so μ draws never collapse onto a face numerically.
pub const MU_PRIOR_XI_PER_K: f64 = 1.0;

/// Numeric floor applied to μ components by [`floor_and_renormalize`]
/// (then renormalized). The floor only guards `ln μ_k` and `θ = αμ_k`
/// against exact zeros from extreme underflow on the Gibbs/refresh
/// paths; the adaptive MH step never repairs its proposals (repairing
/// while evaluating the un-repaired density would break detailed
/// balance — degenerate draws are counted as rejections instead).
pub const MU_FLOOR: f64 = 1e-9;

/// Controller gain of the adaptive μ retarget: how hard an overloaded
/// supercluster's μ is shrunk per unit of excess data share.
const ADAPT_GAIN: f64 = 4.0;

/// Pseudo-count mass of the Dirichlet proposal used by the adaptive MH
/// step (larger = smaller, more-often-accepted moves).
const ADAPT_CONCENTRATION: f64 = 100.0;

/// Additive offset on the adaptive proposal shapes. At `1.0` every
/// proposal shape is ≥ 1, so the Dirichlet proposal density is bounded
/// near the simplex faces and draws with vanishing components are
/// astronomically rare (a shape-≥1 normalized-Gamma component is
/// bounded below by ~1e-17 in f64).
const ADAPT_JITTER: f64 = 1.0;

/// Clamp every component to [`MU_FLOOR`] and renormalize to the simplex.
pub fn floor_and_renormalize(mu: &mut [f64]) {
    let mut total = 0.0;
    for m in mu.iter_mut() {
        // non-finite components (NaN/±inf) are repaired to the floor too
        if !m.is_finite() || *m < MU_FLOOR {
            *m = MU_FLOOR;
        }
        total += *m;
    }
    for m in mu.iter_mut() {
        *m /= total;
    }
}

/// Shapes of the conditional Dirichlet posterior for μ given the current
/// supercluster occupancies: from Eq. 5 the joint depends on μ only
/// through `Π_k μ_k^{J_k}`, so with the `Dir(ξ/K)` prior the exact Gibbs
/// conditional is `μ | J ~ Dir(ξ/K + J_1, …, ξ/K + J_K)`.
pub fn mu_posterior_shapes(j_counts: &[u64]) -> Vec<f64> {
    j_counts
        .iter()
        .map(|&j| MU_PRIOR_XI_PER_K + j as f64)
        .collect()
}

/// Gibbs draw of μ from its conditional given per-supercluster cluster
/// counts (`MuMode::SizeProportional`): `μ ~ Dir(ξ/K + J_k)`. Exactness:
/// this is a standard Gibbs update on the extended space (partition, s,
/// μ); the partition marginal — the true DPM posterior — is untouched
/// (DESIGN.md §6).
pub fn sample_mu_given_occupancy(rng: &mut Pcg64, j_counts: &[u64]) -> Vec<f64> {
    let mut mu = dirichlet(rng, &mu_posterior_shapes(j_counts));
    floor_and_renormalize(&mut mu);
    mu
}

/// Log density of `Dir(shapes)` at `x`. `x` must lie strictly inside
/// the simplex (every component positive) — callers guard this; no
/// clamping happens here, so MH ratios built from this density are
/// exact.
pub fn log_dirichlet(x: &[f64], shapes: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), shapes.len());
    debug_assert!(x.iter().all(|&v| v > 0.0));
    let a0: f64 = shapes.iter().sum();
    let mut lp = lgamma(a0);
    for (&xi, &ai) in x.iter().zip(shapes) {
        lp -= lgamma(ai);
        lp += (ai - 1.0) * xi.ln();
    }
    lp
}

/// Mean of the adaptive retargeting proposal: shrink μ multiplicatively
/// on every supercluster whose share of the data exceeds the occupancy
/// ceiling `target_occupancy / K` (`target_occupancy` is the allowed
/// per-shard data share as a multiple of the uniform share; `1.0` =
/// strict equalization), then renormalize — under-loaded shards absorb
/// the freed mass. With no data or K = 1 the mean is the current μ.
pub fn adaptive_proposal_mean(
    mu: &[f64],
    row_counts: &[u64],
    target_occupancy: f64,
) -> Vec<f64> {
    let k = mu.len();
    let n: u64 = row_counts.iter().sum();
    if n == 0 || k < 2 {
        return mu.to_vec();
    }
    let cap = target_occupancy.max(MU_FLOOR) / k as f64;
    let mut m: Vec<f64> = mu
        .iter()
        .zip(row_counts)
        .map(|(&mu_k, &nk)| {
            let over = (nk as f64 / n as f64 - cap).max(0.0);
            mu_k * (-ADAPT_GAIN * over * k as f64).exp()
        })
        .collect();
    floor_and_renormalize(&mut m);
    m
}

/// One Metropolis–Hastings retarget of μ (`MuMode::Adaptive`): propose
/// `μ* ~ Dir(κ·m + δ)` around the load-balancing mean
/// [`adaptive_proposal_mean`] and accept under the extended target
/// `Dir(μ; ξ/K) · Π_k μ_k^{J_k}` with the exact reverse-proposal
/// correction. The occupancies (`row_counts`, `j_counts`) are part of
/// the *conditioned-on* state, so the state-dependent proposal is plain
/// MH on the μ conditional — the chain stays exact for the true DPM
/// posterior no matter how aggressive the retarget is (DESIGN.md §6).
///
/// The proposal draw is used **raw**: a degenerate draw (any component
/// non-finite or ≤ 0, possible only through extreme underflow) is
/// counted as a rejection rather than repaired, because repairing the
/// draw while evaluating the un-repaired proposal density would break
/// detailed balance.
///
/// Returns `true` when the proposal was accepted (μ updated in place).
pub fn adaptive_mu_step(
    rng: &mut Pcg64,
    mu: &mut Vec<f64>,
    row_counts: &[u64],
    j_counts: &[u64],
    target_occupancy: f64,
) -> bool {
    let k = mu.len();
    if k < 2 {
        return false;
    }
    debug_assert_eq!(row_counts.len(), k);
    debug_assert_eq!(j_counts.len(), k);
    let fwd_mean = adaptive_proposal_mean(mu, row_counts, target_occupancy);
    let fwd_shapes: Vec<f64> = fwd_mean
        .iter()
        .map(|&m| ADAPT_CONCENTRATION * m + ADAPT_JITTER)
        .collect();
    let prop = dirichlet(rng, &fwd_shapes);
    if prop.iter().any(|&p| !p.is_finite() || p <= 0.0) {
        return false; // degenerate draw: reject, never repair (see doc)
    }
    let rev_mean = adaptive_proposal_mean(&prop, row_counts, target_occupancy);
    let rev_shapes: Vec<f64> = rev_mean
        .iter()
        .map(|&m| ADAPT_CONCENTRATION * m + ADAPT_JITTER)
        .collect();
    // target ratio under the extended target Dir(μ; ξ/K) · Π_k μ_k^{J_k}:
    // each component contributes (ξ/K − 1 + J_k)·(ln μ*_k − ln μ_k).
    // (With the default ξ/K = 1 the prior term vanishes, but the ratio
    // stays correct if MU_PRIOR_XI_PER_K is ever retuned.)
    let mut log_acc = 0.0;
    for kk in 0..k {
        log_acc += (MU_PRIOR_XI_PER_K - 1.0 + j_counts[kk] as f64)
            * (prop[kk].ln() - mu[kk].ln());
    }
    log_acc += log_dirichlet(mu, &rev_shapes) - log_dirichlet(&prop, &fwd_shapes);
    if log_acc >= 0.0 || rng.next_f64() < log_acc.exp() {
        *mu = prop;
        true
    } else {
        false
    }
}

/// Which shuffle conditional to use for `s_j` updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleKernel {
    /// `Pr(s_j=k) ∝ μ_k` — exact Gibbs under Eq. 5 (default).
    Exact,
    /// The conditional exactly as printed in the paper's Eq. 7.
    PaperEq7,
}

/// A sampled partition with supercluster structure.
#[derive(Debug, Clone)]
pub struct NestedPartition {
    /// cluster id per datum (dense, 0-based)
    pub z: Vec<u32>,
    /// supercluster id per cluster
    pub s: Vec<u32>,
    /// number of superclusters K the partition was drawn with
    pub num_superclusters: usize,
}

impl NestedPartition {
    /// Total clusters across all superclusters.
    pub fn num_clusters(&self) -> usize {
        self.s.len()
    }

    /// cluster sizes n_j
    pub fn cluster_sizes(&self) -> Vec<u64> {
        let mut sizes = vec![0u64; self.s.len()];
        for &z in &self.z {
            sizes[z as usize] += 1;
        }
        sizes
    }

    /// clusters per supercluster J_k
    pub fn clusters_per_super(&self) -> Vec<u64> {
        let mut j = vec![0u64; self.num_superclusters];
        for &s in &self.s {
            j[s as usize] += 1;
        }
        j
    }

    /// data per supercluster #_k
    pub fn data_per_super(&self) -> Vec<u64> {
        let sizes = self.cluster_sizes();
        let mut out = vec![0u64; self.num_superclusters];
        for (jj, &s) in self.s.iter().enumerate() {
            out[s as usize] += sizes[jj];
        }
        out
    }
}

/// Simulate a standard CRP(α) partition of `n` data.
pub fn crp_prior(rng: &mut Pcg64, n: usize, alpha: f64) -> Vec<u32> {
    let mut z = Vec::with_capacity(n);
    let mut sizes: Vec<f64> = Vec::new();
    for i in 0..n {
        let mut w = sizes.clone();
        w.push(alpha);
        let pick = categorical(rng, &w);
        if pick == sizes.len() {
            sizes.push(1.0);
        } else {
            sizes[pick] += 1.0;
        }
        let _ = i;
        z.push(pick as u32);
    }
    z
}

/// Simulate the two-stage CRP (§3): restaurant by Dirichlet-multinomial
/// popularity, then table by local CRP(αμ_k). Returns the nested
/// partition with globally-unique cluster ids.
pub fn two_stage_crp_prior(
    rng: &mut Pcg64,
    n: usize,
    alpha: f64,
    mu: &[f64],
) -> NestedPartition {
    let k = mu.len();
    assert!(k >= 1);
    let mut z: Vec<u32> = Vec::with_capacity(n);
    let mut s: Vec<u32> = Vec::new(); // supercluster of each cluster
    let mut cluster_sizes: Vec<f64> = Vec::new();
    let mut data_per_super = vec![0.0f64; k];

    for _ in 0..n {
        // stage 1: restaurant choice ∝ αμ_k + #_k
        let w: Vec<f64> = (0..k)
            .map(|kk| alpha * mu[kk] + data_per_super[kk])
            .collect();
        let pick_k = categorical(rng, &w);

        // stage 2: table within restaurant — extant ∝ n_j, new ∝ αμ_k
        let mut table_ids: Vec<usize> = Vec::new();
        let mut table_w: Vec<f64> = Vec::new();
        for (j, &sj) in s.iter().enumerate() {
            if sj as usize == pick_k {
                table_ids.push(j);
                table_w.push(cluster_sizes[j]);
            }
        }
        table_ids.push(usize::MAX);
        table_w.push(alpha * mu[pick_k]);
        let t = categorical(rng, &table_w);
        let cluster = if table_ids[t] == usize::MAX {
            s.push(pick_k as u32);
            cluster_sizes.push(1.0);
            s.len() - 1
        } else {
            cluster_sizes[table_ids[t]] += 1.0;
            table_ids[t]
        };
        data_per_super[pick_k] += 1.0;
        z.push(cluster as u32);
    }

    NestedPartition {
        z,
        s,
        num_superclusters: k,
    }
}

/// Log prior of Eq. 4: the Dirichlet-multinomial over superclusters times
/// K independent local CRPs (full EPPF, including the Π Γ(n_j) factors).
pub fn log_prior_eq4(p: &NestedPartition, alpha: f64, mu: &[f64]) -> f64 {
    let n: u64 = p.z.len() as u64;
    let sizes = p.cluster_sizes();
    let data_k = p.data_per_super();
    let mut lp = lgamma(alpha) - lgamma(n as f64 + alpha);
    // Dirichlet-multinomial over data→supercluster counts
    for (kk, &nk) in data_k.iter().enumerate() {
        let am = alpha * mu[kk];
        lp += lgamma(nk as f64 + am) - lgamma(am);
    }
    // K independent CRP EPPFs with concentration αμ_k
    for (kk, &nk) in data_k.iter().enumerate() {
        let am = alpha * mu[kk];
        let jk = p.s.iter().filter(|&&s| s as usize == kk).count() as f64;
        lp += jk * am.ln() + lgamma(am) - lgamma(am + nk as f64);
    }
    for (j, &nj) in sizes.iter().enumerate() {
        debug_assert!(nj > 0, "cluster {j} empty");
        lp += lgamma(nj as f64); // Γ(n_j)
    }
    lp
}

/// Log prior of Eq. 5: the cancelled form
/// `Γ(α)/Γ(N+α) · α^{ΣJ_k} · Π_k μ_k^{J_k} · Π_j Γ(n_j)`.
pub fn log_prior_eq5(p: &NestedPartition, alpha: f64, mu: &[f64]) -> f64 {
    let n = p.z.len() as f64;
    let jk = p.clusters_per_super();
    let total_j: u64 = jk.iter().sum();
    let mut lp = lgamma(alpha) - lgamma(n + alpha) + total_j as f64 * alpha.ln();
    for (kk, &j) in jk.iter().enumerate() {
        lp += j as f64 * mu[kk].ln();
    }
    for &nj in &p.cluster_sizes() {
        lp += lgamma(nj as f64);
    }
    lp
}

/// Log conditional `ln Pr(s_j = k | rest)` for each k under the chosen
/// kernel. `j_minus[k]` = number of extant clusters in supercluster k
/// *excluding* cluster j.
pub fn shuffle_log_conditional(
    kernel: ShuffleKernel,
    alpha: f64,
    mu: &[f64],
    j_minus: &[u64],
) -> Vec<f64> {
    match kernel {
        ShuffleKernel::Exact => {
            let mut lw: Vec<f64> = mu.iter().map(|&m| m.ln()).collect();
            let z = logsumexp(&lw);
            lw.iter_mut().for_each(|x| *x -= z);
            lw
        }
        ShuffleKernel::PaperEq7 => {
            let total: f64 = alpha + j_minus.iter().sum::<u64>() as f64;
            let mut lw: Vec<f64> = mu
                .iter()
                .zip(j_minus)
                .map(|(&m, &j)| (m * (alpha * m + j as f64) / total).ln())
                .collect();
            let z = logsumexp(&lw);
            lw.iter_mut().for_each(|x| *x -= z);
            lw
        }
    }
}

/// Sample a new supercluster for one cluster.
pub fn sample_shuffle(
    rng: &mut Pcg64,
    kernel: ShuffleKernel,
    alpha: f64,
    mu: &[f64],
    j_minus: &[u64],
) -> usize {
    let lw = shuffle_log_conditional(kernel, alpha, mu, j_minus);
    categorical_log(rng, &lw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    fn uniform_mu(k: usize) -> Vec<f64> {
        vec![1.0 / k as f64; k]
    }

    #[test]
    fn eq4_equals_eq5_on_random_partitions() {
        // the paper's cancellation (Eq. 4 ≡ Eq. 5), term-for-term, on
        // random two-stage draws with non-uniform μ
        let mut rng = Pcg64::seed_from(1);
        let mu = vec![0.5, 0.3, 0.2];
        for trial in 0..50 {
            let alpha = 0.5 + 3.0 * rng.next_f64();
            let p = two_stage_crp_prior(&mut rng, 60, alpha, &mu);
            let a = log_prior_eq4(&p, alpha, &mu);
            let b = log_prior_eq5(&p, alpha, &mu);
            assert!(
                (a - b).abs() < 1e-8,
                "trial {trial}: eq4 {a} != eq5 {b}"
            );
        }
    }

    #[test]
    fn two_stage_marginal_matches_crp_cluster_count() {
        // E[J] under CRP(α) = Σ_i α/(α+i-1); the two-stage construction
        // must reproduce it for any K (the paper's central claim)
        let n = 200;
        let alpha = 3.0;
        let want: f64 = (0..n).map(|i| alpha / (alpha + i as f64)).sum();
        for k in [1usize, 4, 10] {
            let mu = uniform_mu(k);
            let mut rng = Pcg64::seed_from(42 + k as u64);
            let trials = 3000;
            let js: Vec<f64> = (0..trials)
                .map(|_| two_stage_crp_prior(&mut rng, n, alpha, &mu).num_clusters() as f64)
                .collect();
            let got = mean(&js);
            assert!(
                (got - want).abs() < 0.15 * want,
                "K={k}: E[J] {got} vs CRP {want}"
            );
        }
    }

    #[test]
    fn two_stage_matches_crp_partition_distribution_small_n() {
        // exact distribution check on n=3: P(all same cluster), P(all
        // separate) under CRP(α) vs two-stage with K=2
        let alpha = 1.5;
        let n = 3;
        // CRP: P(all same) = 1/(1+α) · 2/(2+α) ; P(all sep) = α/(1+α) · α/(2+α)
        let p_same = (1.0 / (1.0 + alpha)) * (2.0 / (2.0 + alpha));
        let p_sep = (alpha / (1.0 + alpha)) * (alpha / (2.0 + alpha));
        let mu = uniform_mu(2);
        let mut rng = Pcg64::seed_from(9);
        let trials = 60_000;
        let (mut same, mut sep) = (0u64, 0u64);
        for _ in 0..trials {
            let p = two_stage_crp_prior(&mut rng, n, alpha, &mu);
            match p.num_clusters() {
                1 => same += 1,
                3 => sep += 1,
                _ => {}
            }
        }
        let got_same = same as f64 / trials as f64;
        let got_sep = sep as f64 / trials as f64;
        assert!((got_same - p_same).abs() < 0.01, "same {got_same} vs {p_same}");
        assert!((got_sep - p_sep).abs() < 0.01, "sep {got_sep} vs {p_sep}");
    }

    #[test]
    fn exact_kernel_is_iid_mu_and_invariant_for_eq5() {
        // moving cluster j anywhere under Exact leaves eq5 changed by
        // exactly ln μ_k − ln μ_k0 — i.e. the conditional IS ∝ μ_k
        let mut rng = Pcg64::seed_from(3);
        let mu = vec![0.6, 0.3, 0.1];
        let alpha = 2.0;
        let mut p = two_stage_crp_prior(&mut rng, 40, alpha, &mu);
        if p.num_clusters() == 0 {
            return;
        }
        let j = 0usize;
        let mut lps = Vec::new();
        for k in 0..3 {
            p.s[j] = k as u32;
            lps.push(log_prior_eq5(&p, alpha, &mu));
        }
        // conditional from joint
        let z = logsumexp(&lps);
        let cond: Vec<f64> = lps.iter().map(|&x| (x - z).exp()).collect();
        for k in 0..3 {
            assert!(
                (cond[k] - mu[k]).abs() < 1e-9,
                "exact conditional {cond:?} != μ {mu:?}"
            );
        }
        // and the Exact kernel emits exactly ln μ
        let lw = shuffle_log_conditional(ShuffleKernel::Exact, alpha, &mu, &[5, 5, 5]);
        for k in 0..3 {
            assert!((lw[k] - mu[k].ln()).abs() < 1e-12);
        }
    }

    #[test]
    fn eq7_kernel_differs_and_prefers_populated_superclusters() {
        let mu = uniform_mu(2);
        let lw = shuffle_log_conditional(ShuffleKernel::PaperEq7, 1.0, &mu, &[10, 0]);
        assert!(lw[0] > lw[1], "Eq.7 should prefer the populated supercluster");
        let le = shuffle_log_conditional(ShuffleKernel::Exact, 1.0, &mu, &[10, 0]);
        assert!((le[0] - le[1]).abs() < 1e-12, "Exact is uniform under uniform μ");
    }

    #[test]
    fn shuffle_conditionals_normalize() {
        for kernel in [ShuffleKernel::Exact, ShuffleKernel::PaperEq7] {
            let lw = shuffle_log_conditional(kernel, 0.7, &[0.2, 0.5, 0.3], &[3, 1, 7]);
            let z = logsumexp(&lw);
            assert!(z.abs() < 1e-10, "{kernel:?} normalizer {z}");
        }
    }

    #[test]
    fn mu_conditional_matches_dirichlet_moments() {
        // μ | J ~ Dir(1 + J_k): check the posterior mean component-wise
        let j_counts = [4u64, 1, 0];
        let shapes = mu_posterior_shapes(&j_counts);
        assert_eq!(shapes, vec![5.0, 2.0, 1.0]);
        let a0: f64 = shapes.iter().sum();
        let mut rng = Pcg64::seed_from(11);
        let trials = 30_000;
        let mut acc = [0.0f64; 3];
        for _ in 0..trials {
            let mu = sample_mu_given_occupancy(&mut rng, &j_counts);
            assert!((mu.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(mu.iter().all(|&m| m > 0.0));
            for i in 0..3 {
                acc[i] += mu[i];
            }
        }
        for i in 0..3 {
            let got = acc[i] / trials as f64;
            let want = shapes[i] / a0;
            assert!((got - want).abs() < 0.01, "component {i}: {got} vs {want}");
        }
    }

    #[test]
    fn log_dirichlet_normalizes_on_a_grid() {
        // ∫ Dir(x; a) dx = 1 over the 2-simplex, checked by quadrature
        let shapes = [2.0, 3.5];
        let steps = 20_000;
        let mut total = 0.0;
        for i in 1..steps {
            let x = i as f64 / steps as f64;
            total += log_dirichlet(&[x, 1.0 - x], &shapes).exp() / steps as f64;
        }
        assert!((total - 1.0).abs() < 1e-3, "integral {total}");
    }

    #[test]
    fn adaptive_proposal_mean_shrinks_overloaded_shards() {
        let mu = [0.25, 0.25, 0.25, 0.25];
        // shard 0 holds 70% of the data; ceiling is 1/K = 25%
        let rows = [700u64, 100, 100, 100];
        let m = adaptive_proposal_mean(&mu, &rows, 1.0);
        assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(m[0] < mu[0], "overloaded shard must be shrunk: {m:?}");
        for kk in 1..4 {
            assert!(m[kk] > mu[kk], "freed mass must flow to shard {kk}: {m:?}");
        }
        // a lax ceiling (2× uniform) tolerates 50% on one shard
        let lax = adaptive_proposal_mean(&mu, &[500, 200, 200, 100], 2.0);
        for kk in 0..4 {
            assert!((lax[kk] - 0.25).abs() < 1e-12, "lax ceiling moved μ: {lax:?}");
        }
    }

    #[test]
    fn adaptive_proposal_mean_degenerate_inputs() {
        let mu = [0.6, 0.4];
        assert_eq!(adaptive_proposal_mean(&mu, &[0, 0], 1.0), vec![0.6, 0.4]);
        assert_eq!(adaptive_proposal_mean(&[1.0], &[10], 1.0), vec![1.0]);
    }

    #[test]
    fn adaptive_mu_step_preserves_the_conditional() {
        // with the state held fixed, repeated adaptive MH steps must leave
        // the exact μ conditional Dir(1 + J_k) invariant: run a long chain
        // and compare component means against the conditional's
        // balanced occupancy: the proposal mean reduces to the current μ
        // (a centered random walk), so the chain mixes fast enough for a
        // tight moment check; the balance-seeking direction is covered by
        // adaptive_proposal_mean_shrinks_overloaded_shards
        let j_counts = [6u64, 2, 0];
        let rows = [100u64, 100, 100];
        let shapes = mu_posterior_shapes(&j_counts);
        let a0: f64 = shapes.iter().sum();
        let mut rng = Pcg64::seed_from(21);
        let mut mu = vec![1.0 / 3.0; 3];
        // burn-in
        for _ in 0..500 {
            adaptive_mu_step(&mut rng, &mut mu, &rows, &j_counts, 1.0);
        }
        let trials = 40_000;
        let mut acc = [0.0f64; 3];
        let mut accepted = 0u64;
        for _ in 0..trials {
            if adaptive_mu_step(&mut rng, &mut mu, &rows, &j_counts, 1.0) {
                accepted += 1;
            }
            assert!((mu.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            for i in 0..3 {
                acc[i] += mu[i];
            }
        }
        assert!(accepted > trials / 20, "MH chain barely moves: {accepted}");
        for i in 0..3 {
            let got = acc[i] / trials as f64;
            let want = shapes[i] / a0;
            assert!(
                (got - want).abs() < 0.02,
                "component {i}: chain mean {got} vs conditional mean {want}"
            );
        }
    }

    #[test]
    fn adaptive_mu_step_is_a_noop_at_k1() {
        let mut rng = Pcg64::seed_from(31);
        let mut mu = vec![1.0];
        assert!(!adaptive_mu_step(&mut rng, &mut mu, &[50], &[3], 1.0));
        assert_eq!(mu, vec![1.0]);
    }

    #[test]
    fn floor_and_renormalize_repairs_degenerate_vectors() {
        let mut mu = vec![0.0, f64::NAN, 2.0];
        floor_and_renormalize(&mut mu);
        assert!((mu.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(mu.iter().all(|&m| m > 0.0));
        assert!(mu[2] > mu[0]);
    }

    #[test]
    fn sample_shuffle_respects_mu() {
        let mut rng = Pcg64::seed_from(4);
        let mu = vec![0.8, 0.2];
        let mut counts = [0u64; 2];
        for _ in 0..20_000 {
            counts[sample_shuffle(&mut rng, ShuffleKernel::Exact, 1.0, &mu, &[0, 0])] += 1;
        }
        let p0 = counts[0] as f64 / 20_000.0;
        assert!((p0 - 0.8).abs() < 0.02, "p0 {p0}");
    }
}
