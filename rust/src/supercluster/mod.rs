//! The paper's auxiliary-variable representation (§3): nesting partitions
//! in the Dirichlet process.
//!
//! `DP(α, H)` is generated in stages: `γ ~ Dir(αμ)`, `G_k ~ DP(αμ_k, H)`
//! independently, `G = Σ_k γ_k G_k` — and `G ~ DP(α, H)` again. With the
//! sticks marginalized this yields the **two-stage Chinese restaurant
//! process**: a datum first picks a restaurant (supercluster) by
//! Dirichlet-multinomial popularity, then a table within it by local CRP
//! popularity with concentration `αμ_k`.
//!
//! This module implements:
//! * prior simulators for the standard CRP and the two-stage CRP — the
//!   marginal-equivalence test (two-stage ⇒ CRP(α)) is the paper's
//!   central theorem, checked numerically in `rust/tests/`;
//! * the joint priors of Eq. 4 (Dirichlet-multinomial × K local CRPs)
//!   and Eq. 5 (their cancellation), checked equal term-by-term;
//! * the cluster→supercluster shuffle kernel.
//!
//! ## A note on Eq. 7
//!
//! The paper states the shuffle conditional as
//! `Pr(s_j = k | ·) = μ_k (αμ_k + J_{k∖j}) / (α + Σ_{k'} J_{k'∖j})`.
//! However, from the paper's own Eq. 5 the joint depends on `{s_j}` only
//! through `Π_k μ_k^{J_k}`, so the exact Gibbs conditional is simply
//! `Pr(s_j = k | ·) ∝ μ_k` — conditioned on the partition, supercluster
//! labels are i.i.d. categorical(μ). (A direct two-datum generative
//! calculation confirms this; see `eq7_vs_exact` tests and DESIGN.md.)
//! We implement **both**: [`ShuffleKernel::Exact`] (default; provably
//! leaves Eq. 5 invariant) and [`ShuffleKernel::PaperEq7`] (as printed,
//! kept for ablation/comparison).

use crate::rng::{categorical, categorical_log, Pcg64};
use crate::special::{lgamma, logsumexp};

/// Which shuffle conditional to use for `s_j` updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleKernel {
    /// `Pr(s_j=k) ∝ μ_k` — exact Gibbs under Eq. 5 (default).
    Exact,
    /// The conditional exactly as printed in the paper's Eq. 7.
    PaperEq7,
}

/// A sampled partition with supercluster structure.
#[derive(Debug, Clone)]
pub struct NestedPartition {
    /// cluster id per datum (dense, 0-based)
    pub z: Vec<u32>,
    /// supercluster id per cluster
    pub s: Vec<u32>,
    pub num_superclusters: usize,
}

impl NestedPartition {
    pub fn num_clusters(&self) -> usize {
        self.s.len()
    }

    /// cluster sizes n_j
    pub fn cluster_sizes(&self) -> Vec<u64> {
        let mut sizes = vec![0u64; self.s.len()];
        for &z in &self.z {
            sizes[z as usize] += 1;
        }
        sizes
    }

    /// clusters per supercluster J_k
    pub fn clusters_per_super(&self) -> Vec<u64> {
        let mut j = vec![0u64; self.num_superclusters];
        for &s in &self.s {
            j[s as usize] += 1;
        }
        j
    }

    /// data per supercluster #_k
    pub fn data_per_super(&self) -> Vec<u64> {
        let sizes = self.cluster_sizes();
        let mut out = vec![0u64; self.num_superclusters];
        for (jj, &s) in self.s.iter().enumerate() {
            out[s as usize] += sizes[jj];
        }
        out
    }
}

/// Simulate a standard CRP(α) partition of `n` data.
pub fn crp_prior(rng: &mut Pcg64, n: usize, alpha: f64) -> Vec<u32> {
    let mut z = Vec::with_capacity(n);
    let mut sizes: Vec<f64> = Vec::new();
    for i in 0..n {
        let mut w = sizes.clone();
        w.push(alpha);
        let pick = categorical(rng, &w);
        if pick == sizes.len() {
            sizes.push(1.0);
        } else {
            sizes[pick] += 1.0;
        }
        let _ = i;
        z.push(pick as u32);
    }
    z
}

/// Simulate the two-stage CRP (§3): restaurant by Dirichlet-multinomial
/// popularity, then table by local CRP(αμ_k). Returns the nested
/// partition with globally-unique cluster ids.
pub fn two_stage_crp_prior(
    rng: &mut Pcg64,
    n: usize,
    alpha: f64,
    mu: &[f64],
) -> NestedPartition {
    let k = mu.len();
    assert!(k >= 1);
    let mut z: Vec<u32> = Vec::with_capacity(n);
    let mut s: Vec<u32> = Vec::new(); // supercluster of each cluster
    let mut cluster_sizes: Vec<f64> = Vec::new();
    let mut data_per_super = vec![0.0f64; k];

    for _ in 0..n {
        // stage 1: restaurant choice ∝ αμ_k + #_k
        let w: Vec<f64> = (0..k)
            .map(|kk| alpha * mu[kk] + data_per_super[kk])
            .collect();
        let pick_k = categorical(rng, &w);

        // stage 2: table within restaurant — extant ∝ n_j, new ∝ αμ_k
        let mut table_ids: Vec<usize> = Vec::new();
        let mut table_w: Vec<f64> = Vec::new();
        for (j, &sj) in s.iter().enumerate() {
            if sj as usize == pick_k {
                table_ids.push(j);
                table_w.push(cluster_sizes[j]);
            }
        }
        table_ids.push(usize::MAX);
        table_w.push(alpha * mu[pick_k]);
        let t = categorical(rng, &table_w);
        let cluster = if table_ids[t] == usize::MAX {
            s.push(pick_k as u32);
            cluster_sizes.push(1.0);
            s.len() - 1
        } else {
            cluster_sizes[table_ids[t]] += 1.0;
            table_ids[t]
        };
        data_per_super[pick_k] += 1.0;
        z.push(cluster as u32);
    }

    NestedPartition {
        z,
        s,
        num_superclusters: k,
    }
}

/// Log prior of Eq. 4: the Dirichlet-multinomial over superclusters times
/// K independent local CRPs (full EPPF, including the Π Γ(n_j) factors).
pub fn log_prior_eq4(p: &NestedPartition, alpha: f64, mu: &[f64]) -> f64 {
    let n: u64 = p.z.len() as u64;
    let sizes = p.cluster_sizes();
    let data_k = p.data_per_super();
    let mut lp = lgamma(alpha) - lgamma(n as f64 + alpha);
    // Dirichlet-multinomial over data→supercluster counts
    for (kk, &nk) in data_k.iter().enumerate() {
        let am = alpha * mu[kk];
        lp += lgamma(nk as f64 + am) - lgamma(am);
    }
    // K independent CRP EPPFs with concentration αμ_k
    for (kk, &nk) in data_k.iter().enumerate() {
        let am = alpha * mu[kk];
        let jk = p.s.iter().filter(|&&s| s as usize == kk).count() as f64;
        lp += jk * am.ln() + lgamma(am) - lgamma(am + nk as f64);
    }
    for (j, &nj) in sizes.iter().enumerate() {
        debug_assert!(nj > 0, "cluster {j} empty");
        lp += lgamma(nj as f64); // Γ(n_j)
    }
    lp
}

/// Log prior of Eq. 5: the cancelled form
/// `Γ(α)/Γ(N+α) · α^{ΣJ_k} · Π_k μ_k^{J_k} · Π_j Γ(n_j)`.
pub fn log_prior_eq5(p: &NestedPartition, alpha: f64, mu: &[f64]) -> f64 {
    let n = p.z.len() as f64;
    let jk = p.clusters_per_super();
    let total_j: u64 = jk.iter().sum();
    let mut lp = lgamma(alpha) - lgamma(n + alpha) + total_j as f64 * alpha.ln();
    for (kk, &j) in jk.iter().enumerate() {
        lp += j as f64 * mu[kk].ln();
    }
    for &nj in &p.cluster_sizes() {
        lp += lgamma(nj as f64);
    }
    lp
}

/// Log conditional `ln Pr(s_j = k | rest)` for each k under the chosen
/// kernel. `j_minus[k]` = number of extant clusters in supercluster k
/// *excluding* cluster j.
pub fn shuffle_log_conditional(
    kernel: ShuffleKernel,
    alpha: f64,
    mu: &[f64],
    j_minus: &[u64],
) -> Vec<f64> {
    match kernel {
        ShuffleKernel::Exact => {
            let mut lw: Vec<f64> = mu.iter().map(|&m| m.ln()).collect();
            let z = logsumexp(&lw);
            lw.iter_mut().for_each(|x| *x -= z);
            lw
        }
        ShuffleKernel::PaperEq7 => {
            let total: f64 = alpha + j_minus.iter().sum::<u64>() as f64;
            let mut lw: Vec<f64> = mu
                .iter()
                .zip(j_minus)
                .map(|(&m, &j)| (m * (alpha * m + j as f64) / total).ln())
                .collect();
            let z = logsumexp(&lw);
            lw.iter_mut().for_each(|x| *x -= z);
            lw
        }
    }
}

/// Sample a new supercluster for one cluster.
pub fn sample_shuffle(
    rng: &mut Pcg64,
    kernel: ShuffleKernel,
    alpha: f64,
    mu: &[f64],
    j_minus: &[u64],
) -> usize {
    let lw = shuffle_log_conditional(kernel, alpha, mu, j_minus);
    categorical_log(rng, &lw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    fn uniform_mu(k: usize) -> Vec<f64> {
        vec![1.0 / k as f64; k]
    }

    #[test]
    fn eq4_equals_eq5_on_random_partitions() {
        // the paper's cancellation (Eq. 4 ≡ Eq. 5), term-for-term, on
        // random two-stage draws with non-uniform μ
        let mut rng = Pcg64::seed_from(1);
        let mu = vec![0.5, 0.3, 0.2];
        for trial in 0..50 {
            let alpha = 0.5 + 3.0 * rng.next_f64();
            let p = two_stage_crp_prior(&mut rng, 60, alpha, &mu);
            let a = log_prior_eq4(&p, alpha, &mu);
            let b = log_prior_eq5(&p, alpha, &mu);
            assert!(
                (a - b).abs() < 1e-8,
                "trial {trial}: eq4 {a} != eq5 {b}"
            );
        }
    }

    #[test]
    fn two_stage_marginal_matches_crp_cluster_count() {
        // E[J] under CRP(α) = Σ_i α/(α+i-1); the two-stage construction
        // must reproduce it for any K (the paper's central claim)
        let n = 200;
        let alpha = 3.0;
        let want: f64 = (0..n).map(|i| alpha / (alpha + i as f64)).sum();
        for k in [1usize, 4, 10] {
            let mu = uniform_mu(k);
            let mut rng = Pcg64::seed_from(42 + k as u64);
            let trials = 3000;
            let js: Vec<f64> = (0..trials)
                .map(|_| two_stage_crp_prior(&mut rng, n, alpha, &mu).num_clusters() as f64)
                .collect();
            let got = mean(&js);
            assert!(
                (got - want).abs() < 0.15 * want,
                "K={k}: E[J] {got} vs CRP {want}"
            );
        }
    }

    #[test]
    fn two_stage_matches_crp_partition_distribution_small_n() {
        // exact distribution check on n=3: P(all same cluster), P(all
        // separate) under CRP(α) vs two-stage with K=2
        let alpha = 1.5;
        let n = 3;
        // CRP: P(all same) = 1/(1+α) · 2/(2+α) ; P(all sep) = α/(1+α) · α/(2+α)
        let p_same = (1.0 / (1.0 + alpha)) * (2.0 / (2.0 + alpha));
        let p_sep = (alpha / (1.0 + alpha)) * (alpha / (2.0 + alpha));
        let mu = uniform_mu(2);
        let mut rng = Pcg64::seed_from(9);
        let trials = 60_000;
        let (mut same, mut sep) = (0u64, 0u64);
        for _ in 0..trials {
            let p = two_stage_crp_prior(&mut rng, n, alpha, &mu);
            match p.num_clusters() {
                1 => same += 1,
                3 => sep += 1,
                _ => {}
            }
        }
        let got_same = same as f64 / trials as f64;
        let got_sep = sep as f64 / trials as f64;
        assert!((got_same - p_same).abs() < 0.01, "same {got_same} vs {p_same}");
        assert!((got_sep - p_sep).abs() < 0.01, "sep {got_sep} vs {p_sep}");
    }

    #[test]
    fn exact_kernel_is_iid_mu_and_invariant_for_eq5() {
        // moving cluster j anywhere under Exact leaves eq5 changed by
        // exactly ln μ_k − ln μ_k0 — i.e. the conditional IS ∝ μ_k
        let mut rng = Pcg64::seed_from(3);
        let mu = vec![0.6, 0.3, 0.1];
        let alpha = 2.0;
        let mut p = two_stage_crp_prior(&mut rng, 40, alpha, &mu);
        if p.num_clusters() == 0 {
            return;
        }
        let j = 0usize;
        let mut lps = Vec::new();
        for k in 0..3 {
            p.s[j] = k as u32;
            lps.push(log_prior_eq5(&p, alpha, &mu));
        }
        // conditional from joint
        let z = logsumexp(&lps);
        let cond: Vec<f64> = lps.iter().map(|&x| (x - z).exp()).collect();
        for k in 0..3 {
            assert!(
                (cond[k] - mu[k]).abs() < 1e-9,
                "exact conditional {cond:?} != μ {mu:?}"
            );
        }
        // and the Exact kernel emits exactly ln μ
        let lw = shuffle_log_conditional(ShuffleKernel::Exact, alpha, &mu, &[5, 5, 5]);
        for k in 0..3 {
            assert!((lw[k] - mu[k].ln()).abs() < 1e-12);
        }
    }

    #[test]
    fn eq7_kernel_differs_and_prefers_populated_superclusters() {
        let mu = uniform_mu(2);
        let lw = shuffle_log_conditional(ShuffleKernel::PaperEq7, 1.0, &mu, &[10, 0]);
        assert!(lw[0] > lw[1], "Eq.7 should prefer the populated supercluster");
        let le = shuffle_log_conditional(ShuffleKernel::Exact, 1.0, &mu, &[10, 0]);
        assert!((le[0] - le[1]).abs() < 1e-12, "Exact is uniform under uniform μ");
    }

    #[test]
    fn shuffle_conditionals_normalize() {
        for kernel in [ShuffleKernel::Exact, ShuffleKernel::PaperEq7] {
            let lw = shuffle_log_conditional(kernel, 0.7, &[0.2, 0.5, 0.3], &[3, 1, 7]);
            let z = logsumexp(&lw);
            assert!(z.abs() < 1e-10, "{kernel:?} normalizer {z}");
        }
    }

    #[test]
    fn sample_shuffle_respects_mu() {
        let mut rng = Pcg64::seed_from(4);
        let mu = vec![0.8, 0.2];
        let mut counts = [0u64; 2];
        for _ in 0..20_000 {
            counts[sample_shuffle(&mut rng, ShuffleKernel::Exact, 1.0, &mu, &[0, 0])] += 1;
        }
        let p0 = counts[0] as f64 / 20_000.0;
        assert!((p0 - 0.8).abs() < 0.02, "p0 {p0}");
    }
}
