//! Serial baseline: collapsed Gibbs sampling for the Dirichlet-process
//! mixture — Neal (2000) Algorithm 3. This is the "gold standard" chain
//! the paper parallelizes, the comparator for the speedup figures, and
//! the calibration sampler used for initialization (§5: "we perform a
//! small calibration run (on 1-10% of the data) using a serial
//! implementation").

pub mod gibbs;

pub use gibbs::{calibrate_alpha, SerialConfig, SerialGibbs};
