//! The serial DPM sampler: one [`Shard`] over the whole dataset, swept
//! by a pluggable [`TransitionKernel`] (Neal Alg. 3 collapsed Gibbs by
//! default; Walker slice or a Jain–Neal split–merge composite via
//! [`SerialConfig::kernel`]).
//!
//! Hyperparameters (α via Eq. 6 slice sampling, β_d via griddy Gibbs)
//! are updated once per sweep from the *caller's* RNG — the same
//! operators, in the same order, as the parallel coordinator's reduce
//! step. The kernel itself runs on the shard's private stream, split
//! from the caller's RNG at construction exactly like the coordinator
//! splits per-worker streams. Together these make the K=1 coordinator
//! and this sampler produce *identical* chains from the same master
//! seed (asserted in `rust/tests/k1_equivalence.rs`).
//!
//! [`TransitionKernel`]: crate::sampler::TransitionKernel

use crate::coordinator::{Checkpoint, MuMode};
use crate::data::{BinMat, DataRef};
use crate::model::alpha::{sample_alpha, GammaPrior};
use crate::model::hyper::{BetaGridConfig, BetaUpdater};
use crate::model::{Model, ModelSpec};
use crate::rng::Pcg64;
use crate::sampler::{KernelKind, ScoreMode, Shard, TableSet, TableSetBuilder};
use crate::special::{lgamma, logsumexp};
use crate::util::timer::PhaseTimer;
use std::path::Path;

/// Configuration for the serial sampler.
#[derive(Debug, Clone, Copy)]
pub struct SerialConfig {
    /// initial concentration α
    pub init_alpha: f64,
    /// Gamma prior driving the Eq. 6 α update
    pub alpha_prior: GammaPrior,
    /// grid for the griddy-Gibbs β_d update
    pub beta_grid: BetaGridConfig,
    /// initial symmetric β for all dims
    pub init_beta: f64,
    /// update α each sweep
    pub update_alpha: bool,
    /// update β_d each sweep
    pub update_beta: bool,
    /// per-sweep transition operator (paper §4: any standard DPM kernel)
    pub kernel: KernelKind,
    /// candidate-cluster scoring dispatch inside sweeps (`--scorer`)
    pub scoring: ScoreMode,
    /// component likelihood (`--model`); must match the data kind
    pub model: ModelSpec,
}

impl Default for SerialConfig {
    fn default() -> Self {
        SerialConfig {
            init_alpha: 1.0,
            alpha_prior: GammaPrior::default(),
            beta_grid: BetaGridConfig::default(),
            init_beta: 0.5,
            update_alpha: true,
            update_beta: false, // β updates are O(D·grid·J) — opt in
            kernel: KernelKind::CollapsedGibbs,
            scoring: ScoreMode::default(),
            model: ModelSpec::Bernoulli,
        }
    }
}

/// The paper's §5 initialization: "we perform a small calibration run
/// (on 1-10% of the data) using a serial implementation of MCMC
/// inference, and use this to choose the initial concentration
/// parameter α." Runs a short serial chain on a random subsample
/// (started from a generous α so cluster nucleation is not the
/// bottleneck) and returns the adapted concentration — "sufficient to
/// roughly estimate (within an order of magnitude) the correct number
/// of clusters".
pub fn calibrate_alpha(data: &BinMat, fraction: f64, sweeps: usize, rng: &mut Pcg64) -> f64 {
    let n = data.rows();
    let n_sub = ((n as f64 * fraction) as usize).clamp(50.min(n), n);
    let mut rows: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut rows);
    rows.truncate(n_sub);
    let sub = data.select_rows(&rows);
    let cfg = SerialConfig {
        // generous starting concentration: ~sqrt(n) initial clusters,
        // merged down by the Gibbs sweeps
        init_alpha: (n_sub as f64).sqrt(),
        update_alpha: true,
        update_beta: false,
        ..Default::default()
    };
    let mut g = SerialGibbs::init_from_prior(&sub, cfg, rng);
    for _ in 0..sweeps {
        g.sweep(rng);
    }
    g.alpha()
}

/// The serial sampler state: one shard + global hyperparameters.
pub struct SerialGibbs<'a> {
    data: DataRef<'a>,
    /// collapsed component likelihood (Beta–Bernoulli by default; see
    /// [`SerialConfig::model`])
    pub model: Model,
    /// current concentration α
    pub alpha: f64,
    cfg: SerialConfig,
    shard: Shard,
    beta_updater: BetaUpdater,
    /// per-phase wall-clock accounting
    pub timer: PhaseTimer,
    /// completed kernel sweeps (persisted by [`Self::save_checkpoint`],
    /// restored by [`Self::resume`])
    pub sweeps_done: u64,
    /// cumulative measured sweep compute seconds (persisted/restored by
    /// the checkpoint, so trace time axes stay monotone across resumes)
    pub measured_time_s: f64,
    /// persistent β-update scratch (no per-sweep hyper-vector clone)
    beta_scratch: Vec<f64>,
}

impl std::fmt::Debug for SerialGibbs<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SerialGibbs")
            .field("sweeps_done", &self.sweeps_done)
            .field("alpha", &self.alpha)
            .field("clusters", &self.num_clusters())
            .finish_non_exhaustive()
    }
}

impl<'a> SerialGibbs<'a> {
    /// Initialize by a sequential draw from the CRP prior (the paper's
    /// initialization). The shard's private kernel stream is
    /// `rng.split(0)` — the same derivation the coordinator uses for its
    /// worker 0, which is what makes K=1 equivalence exact.
    ///
    /// # Panics
    ///
    /// Panics if `cfg.model` does not match the data kind (the CLI
    /// validates with [`ModelSpec::build`] before constructing).
    pub fn init_from_prior(
        data: impl Into<DataRef<'a>>,
        cfg: SerialConfig,
        rng: &mut Pcg64,
    ) -> Self {
        let data = data.into();
        let mut model = cfg
            .model
            .build(data, cfg.init_beta)
            .unwrap_or_else(|e| panic!("SerialGibbs: {e}"));
        model.build_lut(data.rows() + 1); // symmetric-beta fast rebuilds
        let mut shard = Shard::init_from_prior(
            data,
            (0..data.rows()).collect(),
            cfg.init_alpha,
            rng.split(0),
        );
        shard.set_score_mode(cfg.scoring);
        SerialGibbs {
            data,
            model,
            alpha: cfg.init_alpha,
            cfg,
            shard,
            beta_updater: BetaUpdater::new(cfg.beta_grid),
            timer: PhaseTimer::new(),
            sweeps_done: 0,
            measured_time_s: 0.0,
            beta_scratch: Vec::new(),
        }
    }

    /// Initialize with every datum in a single cluster (worst-case start,
    /// used in convergence tests). As in [`Self::init_from_prior`], the
    /// shard's private kernel stream is split off the caller's RNG.
    pub fn init_single_cluster(
        data: impl Into<DataRef<'a>>,
        cfg: SerialConfig,
        rng: &mut Pcg64,
    ) -> Self {
        let data = data.into();
        let mut model = cfg
            .model
            .build(data, cfg.init_beta)
            .unwrap_or_else(|e| panic!("SerialGibbs: {e}"));
        model.build_lut(data.rows() + 1);
        let mut shard = Shard::init_single_cluster(
            data,
            (0..data.rows()).collect(),
            cfg.init_alpha,
            rng.split(0),
        );
        shard.set_score_mode(cfg.scoring);
        SerialGibbs {
            data,
            model,
            alpha: cfg.init_alpha,
            cfg,
            shard,
            beta_updater: BetaUpdater::new(cfg.beta_grid),
            timer: PhaseTimer::new(),
            sweeps_done: 0,
            measured_time_s: 0.0,
            beta_scratch: Vec::new(),
        }
    }

    /// One full kernel sweep over all data (+ hyper updates per config).
    /// The kernel consumes the shard's private stream; `rng` drives the
    /// centralized α/β updates (mirroring the coordinator's reduce).
    pub fn sweep(&mut self, rng: &mut Pcg64) {
        self.shard.set_theta(self.alpha);
        let t0 = std::time::Instant::now();
        self.cfg.kernel.kernel().sweep(&mut self.shard, self.data, &self.model);
        let dt = t0.elapsed();
        self.timer.add("sweep", dt);
        self.measured_time_s += dt.as_secs_f64();
        if self.cfg.update_alpha {
            self.update_alpha(rng);
        }
        if self.cfg.update_beta {
            self.update_beta(rng);
        }
        self.sweeps_done += 1;
    }

    /// Eq. 6 slice update for α.
    pub fn update_alpha(&mut self, rng: &mut Pcg64) {
        let j = self.num_clusters() as u64;
        self.alpha = sample_alpha(
            rng,
            self.alpha,
            self.data.rows() as u64,
            j,
            &self.cfg.alpha_prior,
        );
    }

    /// Griddy-Gibbs update of every β_d from cluster sufficient stats.
    /// Score caches are only invalidated when some β_d actually moved.
    /// Runs on persistent scratch — no per-sweep hyper-vector clone.
    /// Beta–Bernoulli-specific: a no-op under the other likelihoods
    /// (their hyperparameters are fixed at construction).
    pub fn update_beta(&mut self, rng: &mut Pcg64) {
        if !matches!(self.model, Model::Bernoulli(_)) {
            return;
        }
        let mut stats: Vec<(u64, u32)> = Vec::new();
        self.beta_scratch.clear();
        self.beta_scratch.extend_from_slice(&self.model.as_bernoulli().beta);
        for d in 0..self.model.as_bernoulli().d {
            stats.clear();
            self.shard.collect_dim_stats(d, &mut stats);
            self.beta_scratch[d] = self.beta_updater.sample(rng, &stats);
        }
        let n_max = self.data.rows() + 1;
        if self.model.as_bernoulli_mut().update_betas(&self.beta_scratch, n_max) {
            self.shard.invalidate_caches();
        }
    }

    /// Snapshot the serial chain's latent state as a single-shard
    /// `CCCKPT3` [`Checkpoint`] — the same versioned, checksummed format
    /// (and reader/writer) the coordinator uses, with `μ = [1]`,
    /// `MuMode::Uniform`, and the configured kernel as the one shard's
    /// kernel tag.
    pub fn to_checkpoint(&self) -> Checkpoint {
        Checkpoint {
            alpha: self.alpha,
            model_tag: self.cfg.model.tag(),
            hyper: self.model.hyper_vec(),
            rounds: self.sweeps_done,
            modeled_time_s: self.measured_time_s, // serial: modeled ≡ measured
            measured_time_s: self.measured_time_s,
            mu_mode: MuMode::Uniform,
            mu: vec![1.0],
            kernels: vec![self.cfg.kernel],
            shards: vec![(
                self.shard.rows().iter().map(|&r| r as u64).collect(),
                self.shard.assignments_local().to_vec(),
            )],
        }
    }

    /// Persist the latent state to `path` (`CCCKPT3`).
    pub fn save_checkpoint(&self, path: &Path) -> std::io::Result<()> {
        self.to_checkpoint().save(path)
    }

    /// Rebuild a serial chain from a single-shard checkpoint against the
    /// SAME dataset: sufficient statistics are recomputed from the saved
    /// assignments and integrity-checked before the chain may continue.
    /// The kernel tag AND the model tag must match the config, and the
    /// checkpoint must own every data row — a mismatch is an error,
    /// never a silent reconfiguration. As with the coordinator, the RNG
    /// stream is split fresh from `rng` (the stream position itself is
    /// not serialized).
    pub fn resume(
        data: impl Into<DataRef<'a>>,
        cfg: SerialConfig,
        ckpt: &Checkpoint,
        rng: &mut Pcg64,
    ) -> Result<SerialGibbs<'a>, String> {
        let data = data.into();
        if ckpt.shards.len() != 1 {
            return Err(format!(
                "serial resume needs a 1-shard checkpoint, got {} shards",
                ckpt.shards.len()
            ));
        }
        if ckpt.model_tag != cfg.model.tag() {
            return Err(format!(
                "checkpoint model tag {} does not match configured model {:?} (tag {})",
                ckpt.model_tag,
                cfg.model.name(),
                cfg.model.tag()
            ));
        }
        if ckpt.kernels != [cfg.kernel] {
            return Err(format!(
                "checkpoint kernel {:?} does not match configured {:?}",
                ckpt.kernels, cfg.kernel
            ));
        }
        let (rows, assign) = &ckpt.shards[0];
        if rows.len() != data.rows() {
            return Err(format!(
                "checkpoint owns {} rows, data has {}",
                rows.len(),
                data.rows()
            ));
        }
        let rows: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
        let mut shard = Shard::from_parts(data, rows, assign.clone(), rng.split(0))?;
        shard.check_invariants(data)?;
        shard.set_score_mode(cfg.scoring);
        shard.set_theta(ckpt.alpha);
        let mut model = cfg.model.build(data, cfg.init_beta)?;
        // restore the sampled hypers (Bernoulli β; fixed-hyper models
        // validate bit-equality) — build_lut runs inside, handling the
        // asymmetric-β case itself (clears the LUT)
        model.restore_hyper(&ckpt.hyper, data.rows() + 1)?;
        Ok(SerialGibbs {
            data,
            model,
            alpha: ckpt.alpha,
            cfg,
            shard,
            beta_updater: BetaUpdater::new(cfg.beta_grid),
            timer: PhaseTimer::new(),
            sweeps_done: ckpt.rounds,
            measured_time_s: ckpt.measured_time_s,
            beta_scratch: Vec::new(),
        })
    }

    /// Forward of [`Shard::set_eager_repack`] for the serial chain's one
    /// shard (bench/reference use; see the packed-table refresh policy
    /// docs there).
    pub fn set_eager_repack(&mut self, eager: bool) {
        self.shard.set_eager_repack(eager);
    }

    /// Number of live clusters.
    pub fn num_clusters(&self) -> usize {
        self.shard.num_clusters()
    }

    /// Cluster-slot assignment per datum (aligned with data row order).
    pub fn assignments(&self) -> &[u32] {
        self.shard.assignments_local()
    }

    /// Current concentration α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Active clusters (slot, stats).
    pub fn active_clusters(&self) -> impl Iterator<Item = (usize, &crate::model::ClusterStats)> {
        self.shard.active_clusters()
    }

    /// Export every live cluster's predictive table as an immutable
    /// [`TableSet`] (slot order) — the serial-chain twin of
    /// [`crate::coordinator::Coordinator::export_table_set`], for
    /// sweep-boundary snapshot publication. Consumes no RNG and
    /// changes no chain state.
    pub fn export_table_set(&mut self) -> TableSet {
        let mut b = TableSetBuilder::new(self.model.table_rows());
        self.shard.export_table_columns(&self.model, &mut b);
        b.finish()
    }

    /// Test-set predictive log-likelihood per datum:
    /// `log Σ_j (n_j/(N+α)) p(x|j) + (α/(N+α)) p(x|∅)` — the paper's
    /// convergence metric (Figs. 5–9).
    pub fn predictive_loglik<'b>(&mut self, test: impl Into<DataRef<'b>>) -> f64 {
        let test = test.into();
        let n_total = self.data.rows() as f64 + self.alpha;
        let mut acc = 0.0;
        let mut terms: Vec<f64> = Vec::new();
        for r in 0..test.rows() {
            terms.clear();
            self.shard
                .score_against_all(&self.model, test, r, n_total, &mut terms);
            terms.push((self.alpha / n_total).ln() + self.model.log_pred_empty(test, r));
            acc += logsumexp(&terms);
        }
        acc / test.rows() as f64
    }

    /// Joint log probability `log p(z | α) + Σ_j log m(x_j-cluster)` —
    /// the CRP EPPF times collapsed marginals. Used by the exhaustive
    /// posterior-enumeration tests.
    pub fn joint_log_prob(&self) -> f64 {
        let n = self.data.rows() as f64;
        let j = self.num_clusters() as f64;
        let mut lp = lgamma(self.alpha) - lgamma(self.alpha + n) + j * self.alpha.ln();
        for c in self.shard.clusters() {
            lp += lgamma(c.n() as f64); // Γ(n_j) = (n_j−1)!
            lp += c.log_marginal(&self.model);
        }
        lp
    }

    /// Internal consistency check: every cluster's stats equal the sum of
    /// its members' bits, all counts match. Test/debug aid.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.shard.num_rows() != self.data.rows() {
            return Err("serial shard must own every data row".into());
        }
        self.shard.check_invariants(self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn small_dataset(seed: u64) -> crate::data::Dataset {
        SyntheticConfig {
            n: 300,
            d: 24,
            clusters: 3,
            beta: 0.05,
            seed,
        }
        .generate()
    }

    #[test]
    fn invariants_hold_across_sweeps() {
        let ds = small_dataset(1);
        let mut rng = Pcg64::seed_from(1);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        g.check_invariants().unwrap();
        for _ in 0..5 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        assert!(g.num_clusters() >= 1);
    }

    #[test]
    fn recovers_roughly_true_cluster_count() {
        let ds = small_dataset(2);
        let mut rng = Pcg64::seed_from(7);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        for _ in 0..30 {
            g.sweep(&mut rng);
        }
        let j = g.num_clusters();
        // 3 well-separated true clusters: expect within an order of magnitude
        assert!((2..=12).contains(&j), "found {j} clusters, expected ~3");
    }

    #[test]
    fn walker_kernel_runs_in_the_serial_chain() {
        let ds = small_dataset(2);
        let mut rng = Pcg64::seed_from(17);
        let cfg = SerialConfig {
            kernel: KernelKind::WalkerSlice,
            ..Default::default()
        };
        let mut g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        for _ in 0..20 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        let j = g.num_clusters();
        assert!((2..=16).contains(&j), "Walker-serial found {j} clusters");
    }

    #[test]
    fn split_merge_composite_runs_in_the_serial_chain() {
        let ds = small_dataset(2);
        let mut rng = Pcg64::seed_from(23);
        let cfg = SerialConfig {
            kernel: KernelKind::SplitMergeGibbs,
            ..Default::default()
        };
        let mut g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        for _ in 0..20 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        let j = g.num_clusters();
        assert!((2..=16).contains(&j), "split-merge serial found {j} clusters");
    }

    #[test]
    fn serial_resume_rejects_split_merge_kernel_mismatch() {
        // a checkpoint written under the split–merge composite must not
        // resume under the plain base kernel (and vice versa) — the v2
        // kernel tag round-trips and is validated
        let ds = small_dataset(13);
        let mut rng = Pcg64::seed_from(29);
        let cfg_sm = SerialConfig {
            kernel: KernelKind::SplitMergeWalker,
            ..Default::default()
        };
        let g = SerialGibbs::init_from_prior(&ds.train, cfg_sm, &mut rng);
        let ckpt = g.to_checkpoint();
        assert_eq!(ckpt.kernels, vec![KernelKind::SplitMergeWalker]);
        let cfg_walker = SerialConfig {
            kernel: KernelKind::WalkerSlice,
            ..cfg_sm
        };
        let e = SerialGibbs::resume(&ds.train, cfg_walker, &ckpt, &mut rng).unwrap_err();
        assert!(e.contains("kernel"), "{e}");
        // the matching composite config resumes fine
        let ok = SerialGibbs::resume(&ds.train, cfg_sm, &ckpt, &mut rng).unwrap();
        ok.check_invariants().unwrap();
    }

    #[test]
    fn predictive_loglik_converges_to_true_entropy() {
        // prior init (the paper's §5 choice — single-site Gibbs nucleates
        // new clusters too slowly from a fully-merged start)
        let ds = small_dataset(3);
        let mut rng = Pcg64::seed_from(3);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let before = g.predictive_loglik(&ds.test);
        for _ in 0..30 {
            g.sweep(&mut rng);
        }
        let after = g.predictive_loglik(&ds.test);
        assert!(
            after >= before - 0.05,
            "predictive should not degrade: {before} -> {after}"
        );
        // and approach the generator's entropy rate (Fig. 5's criterion)
        let h = ds.true_entropy_estimate();
        assert!(
            (after + h).abs() < 0.15 * h.abs().max(1.0),
            "pred {after} vs -H {}",
            -h
        );
    }

    #[test]
    fn single_cluster_init_stays_valid_under_sweeps() {
        // from the fully-merged start the chain must remain a valid DPM
        // sampler even if mixing is slow (documents the known failure
        // mode that motivates prior initialization)
        let ds = small_dataset(3);
        let mut rng = Pcg64::seed_from(4);
        let mut g = SerialGibbs::init_single_cluster(&ds.train, SerialConfig::default(), &mut rng);
        for _ in 0..5 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        assert!(g.num_clusters() >= 1);
    }

    #[test]
    fn single_cluster_init_counts() {
        let ds = small_dataset(4);
        let mut rng = Pcg64::seed_from(9);
        let g = SerialGibbs::init_single_cluster(&ds.train, SerialConfig::default(), &mut rng);
        assert_eq!(g.num_clusters(), 1);
        g.check_invariants().unwrap();
        let (_, c) = g.active_clusters().next().unwrap();
        assert_eq!(c.n() as usize, ds.train.rows());
    }

    #[test]
    fn alpha_moves_when_updated() {
        let ds = small_dataset(5);
        let mut rng = Pcg64::seed_from(5);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let a0 = g.alpha();
        let mut moved = false;
        for _ in 0..5 {
            g.sweep(&mut rng);
            if (g.alpha() - a0).abs() > 1e-9 {
                moved = true;
            }
        }
        assert!(moved, "α never moved under slice sampling");
    }

    #[test]
    fn beta_update_keeps_chain_valid() {
        let ds = small_dataset(6);
        let mut rng = Pcg64::seed_from(6);
        let cfg = SerialConfig {
            update_beta: true,
            ..Default::default()
        };
        let mut g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        for _ in 0..3 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        // β moved off its init and stays on the grid
        assert!(g.model.as_bernoulli().beta.iter().all(|&b| b >= 1e-2 && b <= 10.0));
    }

    #[test]
    fn checkpoint_roundtrip_resumes_serial_chain() {
        let ds = small_dataset(11);
        let mut rng = Pcg64::seed_from(11);
        let cfg = SerialConfig::default();
        let mut g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        for _ in 0..5 {
            g.sweep(&mut rng);
        }
        assert_eq!(g.sweeps_done, 5);
        let dir = std::env::temp_dir().join("cc_serial_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serial.ccckpt");
        g.save_checkpoint(&path).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, g.to_checkpoint());
        assert_eq!(ckpt.rounds, 5);
        assert_eq!(ckpt.mu, vec![1.0]);

        let mut rng2 = Pcg64::seed_from(99);
        let mut r = SerialGibbs::resume(&ds.train, cfg, &ckpt, &mut rng2).unwrap();
        assert_eq!(r.sweeps_done, 5);
        assert_eq!(
            r.measured_time_s.to_bits(),
            g.measured_time_s.to_bits(),
            "cumulative sweep time must resume (monotone trace time axis)"
        );
        assert!(r.measured_time_s > 0.0);
        assert_eq!(r.alpha().to_bits(), g.alpha().to_bits());
        assert_eq!(r.assignments(), g.assignments());
        assert_eq!(r.num_clusters(), g.num_clusters());
        for (a, b) in r.model.as_bernoulli().beta.iter().zip(&g.model.as_bernoulli().beta) {
            assert_eq!(a.to_bits(), b.to_bits(), "β must resume bit-exactly");
        }
        r.check_invariants().unwrap();
        // and the resumed chain keeps running
        r.sweep(&mut rng2);
        r.check_invariants().unwrap();
        assert_eq!(r.sweeps_done, 6);
        assert!(r.predictive_loglik(&ds.test).is_finite());
    }

    #[test]
    fn serial_resume_rejects_mismatches() {
        let ds = small_dataset(12);
        let mut rng = Pcg64::seed_from(13);
        let cfg = SerialConfig::default();
        let g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        let ckpt = g.to_checkpoint();
        // kernel mismatch
        let cfg_w = SerialConfig {
            kernel: crate::sampler::KernelKind::WalkerSlice,
            ..cfg
        };
        let e = SerialGibbs::resume(&ds.train, cfg_w, &ckpt, &mut rng).unwrap_err();
        assert!(e.contains("kernel"), "{e}");
        // multi-shard (coordinator) checkpoints are not serial-resumable
        let mut multi = ckpt.clone();
        multi.shards.push((Vec::new(), Vec::new()));
        let e = SerialGibbs::resume(&ds.train, cfg, &multi, &mut rng).unwrap_err();
        assert!(e.contains("1-shard"), "{e}");
        // partial row ownership is rejected
        let mut partial = ckpt.clone();
        partial.shards[0].0.pop();
        partial.shards[0].1.pop();
        let e = SerialGibbs::resume(&ds.train, cfg, &partial, &mut rng).unwrap_err();
        assert!(e.contains("rows"), "{e}");
    }

    #[test]
    fn joint_log_prob_is_finite_and_tracks_fit() {
        let ds = small_dataset(7);
        let mut rng = Pcg64::seed_from(8);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let lp0 = g.joint_log_prob();
        assert!(lp0.is_finite());
        for _ in 0..15 {
            g.sweep(&mut rng);
        }
        let lp1 = g.joint_log_prob();
        assert!(lp1 > lp0, "joint should improve from prior init: {lp0} -> {lp1}");
    }
}
