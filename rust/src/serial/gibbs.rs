//! Neal (2000) Algorithm 3: collapsed Gibbs for the DPM.
//!
//! Per datum: remove from its cluster, score against every extant cluster
//! (`n_j · p(x|stats_j)` in log space) and a fresh cluster (`α · p(x|∅)`),
//! sample, reinsert. Hyperparameters (α via Eq. 6 slice sampling, β_d via
//! griddy Gibbs) are updated once per sweep — the same operators the
//! parallel coordinator runs in its reduce step, which is what makes the
//! K=1 equivalence test meaningful.

use crate::data::BinMat;
use crate::model::alpha::{sample_alpha, GammaPrior};
use crate::model::hyper::{BetaGridConfig, BetaUpdater};
use crate::model::{BetaBernoulli, ClusterStats};
use crate::rng::{categorical_log, categorical_log_inplace, Pcg64};
use crate::special::{lgamma, logsumexp};
use crate::util::timer::PhaseTimer;

/// Configuration for the serial sampler.
#[derive(Debug, Clone, Copy)]
pub struct SerialConfig {
    pub init_alpha: f64,
    pub alpha_prior: GammaPrior,
    pub beta_grid: BetaGridConfig,
    /// initial symmetric β for all dims
    pub init_beta: f64,
    /// update α each sweep
    pub update_alpha: bool,
    /// update β_d each sweep
    pub update_beta: bool,
}

impl Default for SerialConfig {
    fn default() -> Self {
        SerialConfig {
            init_alpha: 1.0,
            alpha_prior: GammaPrior::default(),
            beta_grid: BetaGridConfig::default(),
            init_beta: 0.5,
            update_alpha: true,
            update_beta: false, // β updates are O(D·grid·J) — opt in
        }
    }
}

/// The paper's §5 initialization: "we perform a small calibration run
/// (on 1-10% of the data) using a serial implementation of MCMC
/// inference, and use this to choose the initial concentration
/// parameter α." Runs a short serial chain on a random subsample
/// (started from a generous α so cluster nucleation is not the
/// bottleneck) and returns the adapted concentration — "sufficient to
/// roughly estimate (within an order of magnitude) the correct number
/// of clusters".
pub fn calibrate_alpha(
    data: &BinMat,
    fraction: f64,
    sweeps: usize,
    rng: &mut Pcg64,
) -> f64 {
    let n = data.rows();
    let n_sub = ((n as f64 * fraction) as usize).clamp(50.min(n), n);
    let mut rows: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut rows);
    rows.truncate(n_sub);
    let sub = data.select_rows(&rows);
    let cfg = SerialConfig {
        // generous starting concentration: ~sqrt(n) initial clusters,
        // merged down by the Gibbs sweeps
        init_alpha: (n_sub as f64).sqrt(),
        update_alpha: true,
        update_beta: false,
        ..Default::default()
    };
    let mut g = SerialGibbs::init_from_prior(&sub, cfg, rng);
    for _ in 0..sweeps {
        g.sweep(rng);
    }
    g.alpha()
}

/// The collapsed Gibbs sampler state.
pub struct SerialGibbs<'a> {
    data: &'a BinMat,
    pub model: BetaBernoulli,
    pub alpha: f64,
    cfg: SerialConfig,
    /// cluster assignment per datum (slot index into `clusters`)
    z: Vec<u32>,
    /// slotted cluster storage; `None` = free slot
    clusters: Vec<Option<ClusterStats>>,
    free_slots: Vec<usize>,
    /// scratch: active slot ids and log-weights (reused across data)
    scratch_ids: Vec<u32>,
    scratch_logw: Vec<f64>,
    beta_updater: BetaUpdater,
    pub timer: PhaseTimer,
}

impl<'a> SerialGibbs<'a> {
    /// Initialize by a sequential draw from the CRP prior (the paper's
    /// initialization: "initialize the clustering via a draw from the
    /// prior using the local Chinese restaurant process").
    pub fn init_from_prior(data: &'a BinMat, cfg: SerialConfig, rng: &mut Pcg64) -> Self {
        let mut model = BetaBernoulli::symmetric(data.dims(), cfg.init_beta);
        model.build_lut(data.rows() + 1); // symmetric-beta fast rebuilds
        let mut s = SerialGibbs {
            data,
            model,
            alpha: cfg.init_alpha,
            cfg,
            z: vec![0; data.rows()],
            clusters: Vec::new(),
            free_slots: Vec::new(),
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            beta_updater: BetaUpdater::new(cfg.beta_grid),
            timer: PhaseTimer::new(),
        };
        // sequential CRP: P(new) ∝ α, P(j) ∝ n_j (prior draw — the data
        // likelihood enters only through subsequent Gibbs sweeps)
        for r in 0..data.rows() {
            s.scratch_ids.clear();
            s.scratch_logw.clear();
            for (slot, c) in s.clusters.iter().enumerate() {
                if let Some(c) = c {
                    s.scratch_ids.push(slot as u32);
                    s.scratch_logw.push((c.n() as f64).ln());
                }
            }
            s.scratch_ids.push(u32::MAX);
            s.scratch_logw.push(s.alpha.ln());
            let pick = categorical_log(rng, &s.scratch_logw);
            let slot = s.assign_pick(pick, r);
            s.z[r] = slot;
        }
        s
    }

    /// Initialize with every datum in a single cluster (worst-case start,
    /// used in convergence tests).
    pub fn init_single_cluster(data: &'a BinMat, cfg: SerialConfig) -> Self {
        let mut model = BetaBernoulli::symmetric(data.dims(), cfg.init_beta);
        model.build_lut(data.rows() + 1);
        let mut c = ClusterStats::empty(data.dims());
        for r in 0..data.rows() {
            c.add(data, r);
        }
        SerialGibbs {
            data,
            model,
            alpha: cfg.init_alpha,
            cfg,
            z: vec![0; data.rows()],
            clusters: vec![Some(c)],
            free_slots: Vec::new(),
            scratch_ids: Vec::new(),
            scratch_logw: Vec::new(),
            beta_updater: BetaUpdater::new(cfg.beta_grid),
            timer: PhaseTimer::new(),
        }
    }

    /// Resolve a categorical pick into a cluster slot, creating a new
    /// cluster if the "new table" option (sentinel) was chosen, and add
    /// datum `r` to it. Returns the slot.
    fn assign_pick(&mut self, pick: usize, r: usize) -> u32 {
        let slot = if self.scratch_ids[pick] == u32::MAX {
            match self.free_slots.pop() {
                Some(s) => {
                    self.clusters[s] = Some(ClusterStats::empty(self.data.dims()));
                    s
                }
                None => {
                    self.clusters.push(Some(ClusterStats::empty(self.data.dims())));
                    self.clusters.len() - 1
                }
            }
        } else {
            self.scratch_ids[pick] as usize
        };
        self.clusters[slot].as_mut().unwrap().add(self.data, r);
        slot as u32
    }

    /// One full Gibbs sweep over all data (+ hyper updates per config).
    pub fn sweep(&mut self, rng: &mut Pcg64) {
        for r in 0..self.data.rows() {
            self.resample_datum(r, rng);
        }
        if self.cfg.update_alpha {
            self.update_alpha(rng);
        }
        if self.cfg.update_beta {
            self.update_beta(rng);
        }
    }

    /// Gibbs update of one datum's assignment (Neal Alg. 3 step).
    pub fn resample_datum(&mut self, r: usize, rng: &mut Pcg64) {
        let old = self.z[r] as usize;
        {
            let c = self.clusters[old].as_mut().unwrap();
            c.remove(self.data, r);
            if c.is_empty() {
                self.clusters[old] = None;
                self.free_slots.push(old);
            }
        }
        self.scratch_ids.clear();
        self.scratch_logw.clear();
        for (slot, c) in self.clusters.iter_mut().enumerate() {
            if let Some(c) = c {
                self.scratch_ids.push(slot as u32);
                self.scratch_logw
                    .push(c.log_n() + c.score(&self.model, self.data, r));
            }
        }
        self.scratch_ids.push(u32::MAX);
        self.scratch_logw
            .push(self.alpha.ln() + self.model.empty_cluster_loglik());
        let pick = categorical_log_inplace(rng, &mut self.scratch_logw);
        self.z[r] = self.assign_pick(pick, r);
    }

    /// Eq. 6 slice update for α.
    pub fn update_alpha(&mut self, rng: &mut Pcg64) {
        let j = self.num_clusters() as u64;
        self.alpha = sample_alpha(
            rng,
            self.alpha,
            self.data.rows() as u64,
            j,
            &self.cfg.alpha_prior,
        );
    }

    /// Griddy-Gibbs update of every β_d from cluster sufficient stats.
    pub fn update_beta(&mut self, rng: &mut Pcg64) {
        let mut stats: Vec<(u64, u32)> = Vec::new();
        for d in 0..self.model.d {
            stats.clear();
            for c in self.clusters.iter().flatten() {
                stats.push((c.n(), c.ones()[d]));
            }
            self.model.beta[d] = self.beta_updater.sample(rng, &stats);
        }
        self.model.drop_lut(); // beta is per-dimension now
        for c in self.clusters.iter_mut().flatten() {
            c.invalidate_cache();
        }
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.iter().filter(|c| c.is_some()).count()
    }

    pub fn assignments(&self) -> &[u32] {
        &self.z
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Active clusters (slot, stats).
    pub fn active_clusters(&self) -> impl Iterator<Item = (usize, &ClusterStats)> {
        self.clusters
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.as_ref().map(|c| (i, c)))
    }

    /// Test-set predictive log-likelihood per datum:
    /// `log Σ_j (n_j/(N+α)) p(x|j) + (α/(N+α)) p(x|∅)` — the paper's
    /// convergence metric (Figs. 5–9).
    pub fn predictive_loglik(&mut self, test: &BinMat) -> f64 {
        let n_total = self.data.rows() as f64 + self.alpha;
        let mut acc = 0.0;
        let mut terms: Vec<f64> = Vec::new();
        // borrow clusters mutably one at a time for cached scoring
        for r in 0..test.rows() {
            terms.clear();
            for c in self.clusters.iter_mut().flatten() {
                terms.push((c.n() as f64 / n_total).ln() + c.score(&self.model, test, r));
            }
            terms.push((self.alpha / n_total).ln() + self.model.empty_cluster_loglik());
            acc += logsumexp(&terms);
        }
        acc / test.rows() as f64
    }

    /// Joint log probability `log p(z | α) + Σ_j log m(x_j-cluster)` —
    /// the CRP EPPF times collapsed marginals. Used by the exhaustive
    /// posterior-enumeration tests.
    pub fn joint_log_prob(&self) -> f64 {
        let n = self.data.rows() as f64;
        let j = self.num_clusters() as f64;
        let mut lp = lgamma(self.alpha) - lgamma(self.alpha + n) + j * self.alpha.ln();
        for c in self.clusters.iter().flatten() {
            lp += lgamma(c.n() as f64); // Γ(n_j) = (n_j−1)!
            lp += c.log_marginal(&self.model);
        }
        lp
    }

    /// Internal consistency check: every cluster's stats equal the sum of
    /// its members' bits, all counts match. Test/debug aid.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut rebuilt: Vec<ClusterStats> = self
            .clusters
            .iter()
            .map(|_| ClusterStats::empty(self.data.dims()))
            .collect();
        for (r, &zr) in self.z.iter().enumerate() {
            let slot = zr as usize;
            if slot >= self.clusters.len() || self.clusters[slot].is_none() {
                return Err(format!("datum {r} assigned to dead slot {slot}"));
            }
            rebuilt[slot].add(self.data, r);
        }
        for (slot, c) in self.clusters.iter().enumerate() {
            if let Some(c) = c {
                if c.n() != rebuilt[slot].n() {
                    return Err(format!(
                        "slot {slot}: n {} != rebuilt {}",
                        c.n(),
                        rebuilt[slot].n()
                    ));
                }
                if c.ones() != rebuilt[slot].ones() {
                    return Err(format!("slot {slot}: ones mismatch"));
                }
                if c.is_empty() {
                    return Err(format!("slot {slot}: empty but not freed"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn small_dataset(seed: u64) -> crate::data::Dataset {
        SyntheticConfig {
            n: 300,
            d: 24,
            clusters: 3,
            beta: 0.05,
            seed,
        }
        .generate()
    }

    #[test]
    fn invariants_hold_across_sweeps() {
        let ds = small_dataset(1);
        let mut rng = Pcg64::seed_from(1);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        g.check_invariants().unwrap();
        for _ in 0..5 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        assert!(g.num_clusters() >= 1);
    }

    #[test]
    fn recovers_roughly_true_cluster_count() {
        let ds = small_dataset(2);
        let mut rng = Pcg64::seed_from(7);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        for _ in 0..30 {
            g.sweep(&mut rng);
        }
        let j = g.num_clusters();
        // 3 well-separated true clusters: expect within an order of magnitude
        assert!((2..=12).contains(&j), "found {j} clusters, expected ~3");
    }

    #[test]
    fn predictive_loglik_converges_to_true_entropy() {
        // prior init (the paper's §5 choice — single-site Gibbs nucleates
        // new clusters too slowly from a fully-merged start)
        let ds = small_dataset(3);
        let mut rng = Pcg64::seed_from(3);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let before = g.predictive_loglik(&ds.test);
        for _ in 0..30 {
            g.sweep(&mut rng);
        }
        let after = g.predictive_loglik(&ds.test);
        assert!(
            after >= before - 0.05,
            "predictive should not degrade: {before} -> {after}"
        );
        // and approach the generator's entropy rate (Fig. 5's criterion)
        let h = ds.true_entropy_estimate();
        assert!(
            (after + h).abs() < 0.15 * h.abs().max(1.0),
            "pred {after} vs -H {}",
            -h
        );
    }

    #[test]
    fn single_cluster_init_stays_valid_under_sweeps() {
        // from the fully-merged start the chain must remain a valid DPM
        // sampler even if mixing is slow (documents the known failure
        // mode that motivates prior initialization)
        let ds = small_dataset(3);
        let mut rng = Pcg64::seed_from(4);
        let mut g = SerialGibbs::init_single_cluster(&ds.train, SerialConfig::default());
        for _ in 0..5 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        assert!(g.num_clusters() >= 1);
    }

    #[test]
    fn single_cluster_init_counts() {
        let ds = small_dataset(4);
        let g = SerialGibbs::init_single_cluster(&ds.train, SerialConfig::default());
        assert_eq!(g.num_clusters(), 1);
        g.check_invariants().unwrap();
        let (_, c) = g.active_clusters().next().unwrap();
        assert_eq!(c.n() as usize, ds.train.rows());
    }

    #[test]
    fn alpha_moves_when_updated() {
        let ds = small_dataset(5);
        let mut rng = Pcg64::seed_from(5);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let a0 = g.alpha();
        let mut moved = false;
        for _ in 0..5 {
            g.sweep(&mut rng);
            if (g.alpha() - a0).abs() > 1e-9 {
                moved = true;
            }
        }
        assert!(moved, "α never moved under slice sampling");
    }

    #[test]
    fn beta_update_keeps_chain_valid() {
        let ds = small_dataset(6);
        let mut rng = Pcg64::seed_from(6);
        let cfg = SerialConfig {
            update_beta: true,
            ..Default::default()
        };
        let mut g = SerialGibbs::init_from_prior(&ds.train, cfg, &mut rng);
        for _ in 0..3 {
            g.sweep(&mut rng);
            g.check_invariants().unwrap();
        }
        // β moved off its init and stays on the grid
        assert!(g.model.beta.iter().all(|&b| b >= 1e-2 && b <= 10.0));
    }

    #[test]
    fn joint_log_prob_is_finite_and_tracks_fit() {
        let ds = small_dataset(7);
        let mut rng = Pcg64::seed_from(8);
        let mut g = SerialGibbs::init_from_prior(&ds.train, SerialConfig::default(), &mut rng);
        let lp0 = g.joint_log_prob();
        assert!(lp0.is_finite());
        for _ in 0..15 {
            g.sweep(&mut rng);
        }
        let lp1 = g.joint_log_prob();
        assert!(lp1 > lp0, "joint should improve from prior init: {lp0} -> {lp1}");
    }
}
