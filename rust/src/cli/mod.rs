//! Minimal CLI argument parser (clap is not in the offline crate
//! universe): `repro <command> [--key value | --flag]...` with typed
//! accessors and helpful errors.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub struct Args {
    /// the subcommand (first positional token; "help" when absent)
    pub command: String,
    flags: BTreeMap<String, String>,
    presence: Vec<String>,
}

impl Args {
    /// Parse from raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut presence = Vec::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {tok:?}"))?
                .to_string();
            if key.is_empty() {
                return Err("empty flag name".into());
            }
            // --key=value or --key value or bare --flag
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                // guarded by the peek above, but stay panic-free even if
                // the iterator misbehaves between peek and next
                let v = it
                    .next()
                    .ok_or_else(|| format!("--{key} expects a value"))?;
                flags.insert(key, v);
            } else {
                presence.push(key);
            }
        }
        Ok(Args {
            command,
            flags,
            presence,
        })
    }

    /// Parse from the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `flag` was passed (bare or with a value).
    pub fn has(&self, flag: &str) -> bool {
        self.presence.iter().any(|f| f == flag) || self.flags.contains_key(flag)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of `--key` for the typed accessors, distinguishing the
    /// three shapes a flag can take on the command line: given with a
    /// value (`Ok(Some(v))`), absent (`Ok(None)`), or given **bare**
    /// (`Err`). The last case is the historical silent-miss bug: a
    /// trailing `repro run --out` used to park `out` in the presence
    /// list, and `get_str("out", default)` then quietly fell back to
    /// the default instead of erroring.
    fn value_of(&self, key: &str) -> Result<Option<&str>, String> {
        if let Some(v) = self.flags.get(key) {
            return Ok(Some(v.as_str()));
        }
        if self.presence.iter().any(|f| f == key) {
            return Err(format!("--{key} expects a value"));
        }
        Ok(None)
    }

    /// `--key` as usize, or `default` when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `--key` as u64, or `default` when absent.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `--key` as f64, or `default` when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }

    /// `--key` as owned string, or `default` when absent. A bare
    /// `--key` (no value) is an error, never a silent default.
    pub fn get_str(&self, key: &str, default: &str) -> Result<String, String> {
        Ok(self
            .value_of(key)?
            .map(|v| v.to_string())
            .unwrap_or_else(|| default.to_string()))
    }

    /// `--key` as owned string with no default — for path-valued flags
    /// like `--checkpoint-dir` whose absence disables the feature.
    /// Absent ⇒ `Ok(None)`; bare ⇒ `Err`.
    pub fn get_opt_str(&self, key: &str) -> Result<Option<String>, String> {
        Ok(self.value_of(key)?.map(|v| v.to_string()))
    }

    /// `--key on|off` as bool, or `default` when absent — the shape of
    /// mode toggles like `--overlap on` whose off state must stay
    /// spellable explicitly (a bare presence flag can't be turned back
    /// off in a wrapper script).
    pub fn get_on_off(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "on" => Ok(true),
                "off" => Ok(false),
                _ => Err(format!("--{key} expects \"on\" or \"off\", got {v:?}")),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_flags_and_presence() {
        let a = parse(&["run", "--workers", "8", "--rounds=50", "--no-shuffle"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.get_usize("workers", 1).unwrap(), 8);
        assert_eq!(a.get_u64("rounds", 0).unwrap(), 50);
        assert!(a.has("no-shuffle"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["serial"]);
        assert_eq!(a.get_f64("alpha", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_str("out", "trace.csv").unwrap(), "trace.csv");
        assert_eq!(a.get_opt_str("checkpoint-dir").unwrap(), None);
    }

    #[test]
    fn bare_value_flags_error_instead_of_silently_defaulting() {
        // a trailing `--out` (user forgot the value) must NOT quietly
        // fall back to the default
        let a = parse(&["run", "--workers", "4", "--out"]);
        assert_eq!(a.get_str("out", "trace.csv"), Err("--out expects a value".into()));
        assert_eq!(a.get_opt_str("out"), Err("--out expects a value".into()));
        // same for every typed accessor
        let b = parse(&["run", "--rounds"]);
        assert!(b.get_u64("rounds", 1).unwrap_err().contains("--rounds expects a value"));
        assert!(b.get_usize("rounds", 1).unwrap_err().contains("expects a value"));
        let c = parse(&["run", "--alpha"]);
        assert!(c.get_f64("alpha", 1.0).unwrap_err().contains("--alpha expects a value"));
        let d = parse(&["run", "--overlap"]);
        assert!(d.get_on_off("overlap", false).unwrap_err().contains("expects a value"));
        // genuine presence flags are unaffected
        assert!(parse(&["run", "--no-shuffle"]).has("no-shuffle"));
    }

    #[test]
    fn bad_tokens_are_rejected() {
        assert!(Args::parse(vec!["run".into(), "workers".into()]).is_err());
        let a = parse(&["run", "--workers", "eight"]);
        assert!(a.get_usize("workers", 1).is_err());
    }

    #[test]
    fn on_off_toggles_parse_strictly() {
        let a = parse(&["run", "--overlap", "on"]);
        assert!(a.get_on_off("overlap", false).unwrap());
        let b = parse(&["run", "--overlap=off"]);
        assert!(!b.get_on_off("overlap", true).unwrap());
        let c = parse(&["run"]);
        assert!(!c.get_on_off("overlap", false).unwrap());
        let d = parse(&["run", "--overlap", "maybe"]);
        assert!(d.get_on_off("overlap", false).is_err());
    }

    #[test]
    fn empty_args_default_to_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
