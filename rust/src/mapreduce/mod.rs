//! In-process map-reduce runtime — the substitute for the paper's Hadoop
//! deployment (§5, Fig. 3/4). Mappers run on a **persistent worker
//! pool** (threads are spawned once at construction and reused across
//! rounds, so a 1000-round chain pays thread startup once, not 1000
//! times); per-task compute time is measured individually so the
//! **modeled wall-clock** (what a K-machine cluster would see:
//! `max_k(map_k) + reduce + comm`) is well-defined even on a single-core
//! container. The communication cost model is parameterized on per-round
//! latency (Hadoop job overhead) and bandwidth, and drives the Fig. 8
//! saturation behaviour.
//!
//! Two round schedules are modeled (DESIGN.md § Barrier-free rounds):
//! the **bulk-synchronous** schedule serializes map → reduce → comm, and
//! the **overlapped** schedule hides the previous round's shuffle
//! transfer and global updates behind the current map, so the modeled
//! wall is `latency + stats_upload + max(map_crit, carry_prev)` instead
//! of the sum. Completion delivery is a channel, not a barrier: the
//! caller drains completions as tasks finish ([`MapReduce::map_collect`]
//! and, with in-flight reaction + follow-up resubmission,
//! [`MapReduce::map_streaming`]), which is what lets a coordinator stage
//! shuffle state and grant bonus sweeps for fast shards while slow ones
//! are still sweeping. A [`DelayHook`] can inject deterministic per-task
//! start delays so tests can force any completion-order interleaving.

use std::any::Any;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Test/diagnostics hook: given a task index, return an artificial delay
/// the pool sleeps **before** starting that task's compute (excluded
/// from the task's measured duration). This makes completion order a
/// deterministic function of the hook, which is how the concurrency
/// test layer exercises every interleaving; a panicking hook doubles as
/// an injected shard failure.
pub type DelayHook = Arc<dyn Fn(usize) -> Duration + Send + Sync>;

/// Communication/overhead model for one map-reduce round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// fixed per-round overhead (job scheduling, barrier, shuffle start).
    /// The paper's Hadoop-era overhead is seconds; default reflects a
    /// modest cluster (tunable from every bench/CLI).
    pub round_latency_s: f64,
    /// per-worker connection setup cost
    pub per_worker_latency_s: f64,
    /// bytes/second for state transfer (both directions pooled)
    pub bandwidth_bytes_per_s: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel {
            round_latency_s: 2.0,           // Hadoop job launch overhead
            per_worker_latency_s: 0.05,     // per-mapper startup
            bandwidth_bytes_per_s: 100e6,   // ~1 Gb/s effective
        }
    }
}

impl CommModel {
    /// No communication cost at all (pure algorithmic comparisons).
    pub fn free() -> Self {
        CommModel {
            round_latency_s: 0.0,
            per_worker_latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
        }
    }

    /// Modeled communication time for a round with `workers` mappers
    /// moving `bytes` of state.
    pub fn round_time(&self, workers: usize, bytes: u64) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Modeled wall-clock of one **overlapped** round. Only the small
    /// reduced-statistics upload (`stats_bytes`: J_k counts, pooled dim
    /// stats) sits on the critical path; the bulky shuffle transfer and
    /// the global-update compute of the *previous* round (`carry_s`)
    /// ride behind the current map, so the round pays
    /// `max(map_crit_s, carry_s)` instead of their sum.
    pub fn overlapped_round_time(
        &self,
        workers: usize,
        stats_bytes: u64,
        map_crit_s: f64,
        carry_s: f64,
    ) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + stats_bytes as f64 / self.bandwidth_bytes_per_s
            + map_crit_s.max(carry_s)
    }
}

/// Timing/traffic record of one map-reduce round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// measured compute duration of each map task (base + any follow-up
    /// grants, pooled per task)
    pub map_durations: Vec<Duration>,
    /// measured host-side non-map duration attributed to the round's
    /// reduce/global step. Under the overlapped schedule this is the
    /// staging work absorbed into the map window **plus** the post-window
    /// tail (shuffle decisions + hyper reduce), i.e. everything the bulk
    /// schedule would serialize after the map barrier.
    pub reduce_duration: Duration,
    /// bytes the round moved (stats up + state down)
    pub bytes_transferred: u64,
    /// modeled distributed wall-clock for the round (seconds) under the
    /// schedule the round actually ran: equals [`Self::modeled_bulk_s`]
    /// for bulk-synchronous rounds and [`Self::modeled_overlapped_s`]
    /// for overlapped rounds
    pub modeled_wall_s: f64,
    /// modeled wall under the bulk-synchronous schedule
    /// (`max_k(map_k) + reduce + comm`), always populated so the two
    /// schedules stay comparable round-by-round
    pub modeled_bulk_s: f64,
    /// modeled wall under the overlapped schedule
    /// (`latency + stats_upload + max(map_crit, carry_prev)`); for a
    /// bulk round this is reported equal to the bulk figure (no carry
    /// was tracked, so no overlap is claimed)
    pub modeled_overlapped_s: f64,
    /// actually measured wall-clock on this host (seconds)
    pub measured_wall_s: f64,
    /// measured wall-clock of the round as actually executed on this
    /// host under its own schedule. For an overlapped round this equals
    /// [`Self::measured_wall_s`] (the concurrent pipeline is what ran);
    /// for a bulk round it is also the measured wall (no concurrency was
    /// attempted, none is claimed).
    pub measured_overlapped_s: f64,
    /// measured wall-clock this host *would* have paid had it serialized
    /// the same round bulk-style: the map window plus every piece of
    /// host work the concurrent schedule hid inside it (per-completion
    /// staging) or ran after it (shuffle + reduce tail). The ratio
    /// `measured_serialized_s / measured_overlapped_s` is the **real**
    /// (not modeled) host overlap speedup. For a bulk round both
    /// measured columns equal [`Self::measured_wall_s`].
    pub measured_serialized_s: f64,
}

impl RoundStats {
    /// max_k map time — the parallel critical path.
    pub fn map_critical_path(&self) -> Duration {
        self.map_durations.iter().copied().max().unwrap_or_default()
    }

    /// Σ_k map time — what a serial execution would pay.
    pub fn map_total(&self) -> Duration {
        self.map_durations.iter().sum()
    }
}

/// A type-erased unit of work shipped to the pool. Jobs are *logically*
/// non-`'static` (they borrow the caller's stack); [`MapReduce::map`]
/// guarantees completion before returning, which is what makes the
/// lifetime erasure sound — see the safety comment there.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// The persistent worker threads. Shared one `Receiver` behind a mutex
/// (the lock is held while idle-waiting in `recv`, which serializes job
/// *pickup*, not execution — pickup is nanoseconds against millisecond
/// sweep tasks). Dropping the pool closes the channel and joins.
struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(threads: usize) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: pool dropped
                    }
                })
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
        }
    }

    fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool sender alive until drop")
            .send(job)
            .expect("worker pool alive");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel so workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One completion event delivered to the [`MapReduce::map_streaming`]
/// reaction callback, on the **caller** thread, as tasks (and follow-up
/// grants) finish.
pub struct StreamEvent<'a, R> {
    /// 0-based completion order of this event among all reacted events
    pub rank: usize,
    /// input index of the task that finished
    pub index: usize,
    /// how many follow-up grants this task has already completed
    /// (0 = this is the base task's completion)
    pub followups_done: usize,
    /// measured compute duration of just this unit of work (base task or
    /// single follow-up; injected delays excluded)
    pub duration: Duration,
    /// the task's current result; mutable so the reaction can stage
    /// state out of it before deciding whether to grant a follow-up
    pub result: &'a mut R,
}

/// The map-reduce executor. `parallelism` caps the number of worker
/// threads (tasks beyond it queue, exactly like mappers on a small
/// cluster). Workers are spawned once here and reused by every
/// subsequent [`Self::map`] round.
pub struct MapReduce {
    parallelism: usize,
    pool: Option<WorkerPool>,
    delay: Option<DelayHook>,
}

impl std::fmt::Debug for MapReduce {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapReduce")
            .field("parallelism", &self.parallelism)
            .field("pooled", &self.pool.is_some())
            .field("delayed", &self.delay.is_some())
            .finish()
    }
}

impl MapReduce {
    /// Executor with `parallelism` persistent worker threads (≥ 1).
    pub fn new(parallelism: usize) -> Self {
        assert!(parallelism >= 1);
        // parallelism == 1 runs inline on the caller thread: no pool,
        // no thread overhead, cleanest per-task timing on one core
        let pool = (parallelism > 1).then(|| WorkerPool::new(parallelism));
        MapReduce {
            parallelism,
            pool,
            delay: None,
        }
    }

    /// Use all available cores.
    pub fn host_parallel() -> Self {
        let p = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        MapReduce::new(p)
    }

    /// The configured worker-thread cap.
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Install (or clear) a [`DelayHook`]. Applied to **base** tasks
    /// only, before their compute starts, on whichever thread runs the
    /// task; the sleep is excluded from measured durations. Tests use
    /// this to pin completion order deterministically and to inject
    /// mid-map failures (a panicking hook behaves like a crashed shard).
    pub fn set_delay_hook(&mut self, hook: Option<DelayHook>) {
        self.delay = hook;
    }

    /// Run `f` over `tasks`, returning results (input order) and each
    /// task's measured compute duration (queue wait excluded). Tasks are
    /// distributed over the persistent pool; with `parallelism == 1`
    /// (or a single task) execution is in-place.
    pub fn map<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        self.map_collect(tasks, f, |_, _| {})
    }

    /// Like [`Self::map`], but the caller observes completions as they
    /// happen: `on_done(rank, index)` runs on the **caller** thread when
    /// the `rank`-th task to finish (0-based completion order) turns out
    /// to be input `index`. Results are still returned in **input
    /// order**: every completion message carries its task index, so
    /// out-of-order execution cannot scramble the output vector or the
    /// per-task duration vector.
    ///
    /// If a task panics, the first payload is re-raised on the caller
    /// thread — but only after all completions (success or panic) have
    /// been drained, so a panicking task can never wedge the pool or
    /// leave a borrow live. `on_done` is not invoked for the panicking
    /// task(s).
    pub fn map_collect<T, R, F, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        mut on_done: C,
    ) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        C: FnMut(usize, usize),
    {
        self.map_streaming(
            tasks,
            f,
            |_, r| r,
            |ev| {
                on_done(ev.rank, ev.index);
                false
            },
        )
    }

    /// The full streaming surface the barrier-free coordinator builds
    /// on. Each task `i` runs `f(i, task)` on the pool; when a unit of
    /// work completes, `react` is invoked on the **caller** thread with
    /// a [`StreamEvent`] holding mutable access to the task's current
    /// result — the reaction can stage state out of it (e.g. drain
    /// clusters for the shuffle) and then decide: return `true` to
    /// resubmit the task through `follow(i, result)` as a fresh pool job
    /// (a mid-round bonus-sweep grant), or `false` to retire it. Follow-
    /// up completions re-enter `react` with `followups_done`
    /// incremented, so a task can be granted repeatedly.
    ///
    /// Returned durations pool each task's base + follow-up compute.
    /// Results come back in input order regardless of completion order.
    ///
    /// Panic semantics match [`Self::map_collect`]: the first payload is
    /// re-raised on the caller thread only after every outstanding unit
    /// (base or follow-up) has been drained; once a panic is seen,
    /// `react` is not invoked again (so no further grants are issued)
    /// and the remaining completions are simply accounted for. An
    /// installed [`DelayHook`] delays base tasks only.
    pub fn map_streaming<T, R, F, G, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        follow: G,
        mut react: C,
    ) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        G: Fn(usize, R) -> R + Sync,
        C: FnMut(StreamEvent<'_, R>) -> bool,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => {
                // inline: completion order == input order, reactions and
                // follow-ups interleave synchronously on this thread
                let mut out = Vec::with_capacity(n);
                let mut durs = Vec::with_capacity(n);
                let mut rank = 0usize;
                for (i, t) in tasks.into_iter().enumerate() {
                    if let Some(hook) = &self.delay {
                        std::thread::sleep(hook(i));
                    }
                    let t0 = Instant::now();
                    let mut r = f(i, t);
                    let mut unit = t0.elapsed();
                    let mut total = unit;
                    let mut followups_done = 0usize;
                    loop {
                        let resubmit = react(StreamEvent {
                            rank,
                            index: i,
                            followups_done,
                            duration: unit,
                            result: &mut r,
                        });
                        rank += 1;
                        if !resubmit {
                            break;
                        }
                        let t1 = Instant::now();
                        r = follow(i, r);
                        unit = t1.elapsed();
                        total += unit;
                        followups_done += 1;
                    }
                    out.push(r);
                    durs.push(total);
                }
                return (out, durs);
            }
        };

        // Hand each task to the pool as a type-erased job. The jobs
        // borrow this stack frame (`inputs`, `f`, `follow`, the delay
        // hook), so their lifetime is transmuted up to 'static.
        //
        // SAFETY: every borrow the jobs capture outlives the jobs
        // themselves because this function blocks on the completion
        // drain below until ALL outstanding units (base jobs plus every
        // follow-up this loop itself submitted) have sent their message
        // (panicking jobs are caught and still send one), and the pool
        // can only execute a job once. The `outstanding` counter is
        // incremented before each follow-up submission on this thread,
        // so the drain condition accounts for every job that can ever
        // exist. Nothing below the drain loop can observe a live job.
        // There is deliberately NO public handle type that would let a
        // caller forget a pending job — the drain is unconditional.
        let inputs: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        // (index, followups_done, result-or-panic) per completed unit
        let (done_tx, done_rx) =
            channel::<(usize, usize, Result<(R, Duration), Box<dyn Any + Send>>)>();
        // `Sender<Job>` is not Sync, so jobs must not capture `&self`;
        // borrow just the hook (an Option<&Arc<..>> is Send + Sync)
        let delay = self.delay.as_ref();
        for i in 0..n {
            let inputs = &inputs;
            let f = &f;
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if let Some(hook) = delay {
                        std::thread::sleep(hook(i));
                    }
                    let t = inputs[i].lock().unwrap().take().expect("task taken once");
                    let t0 = Instant::now();
                    let r = f(i, t);
                    (r, t0.elapsed())
                }));
                // only fails if the receiver is gone, which the
                // unconditional drain below rules out
                let _ = done_tx.send((i, 0, ran));
            });
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            pool.submit(job);
        }
        // keep `done_tx` alive: follow-up jobs clone their sender from
        // the drain loop below, and dropping the original only after the
        // drain keeps the channel trivially open throughout
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut totals: Vec<Duration> = vec![Duration::ZERO; n];
        let mut outstanding = n;
        let mut rank = 0usize;
        let mut panic_payload: Option<Box<dyn Any + Send>> = None;
        while outstanding > 0 {
            let (i, followups_done, ran) =
                done_rx.recv().expect("every job sends a completion");
            outstanding -= 1;
            match ran {
                Ok((mut r, d)) => {
                    totals[i] += d;
                    let mut resubmit = false;
                    if panic_payload.is_none() {
                        resubmit = react(StreamEvent {
                            rank,
                            index: i,
                            followups_done,
                            duration: d,
                            result: &mut r,
                        });
                        rank += 1;
                    }
                    if resubmit {
                        let follow = &follow;
                        let done_tx = done_tx.clone();
                        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                            let ran =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    let t0 = Instant::now();
                                    let r = follow(i, r);
                                    (r, t0.elapsed())
                                }));
                            let _ = done_tx.send((i, followups_done + 1, ran));
                        });
                        let job: Job = unsafe {
                            std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                        };
                        outstanding += 1;
                        pool.submit(job);
                    } else {
                        slots[i] = Some(r);
                    }
                }
                Err(p) => {
                    if panic_payload.is_none() {
                        panic_payload = Some(p);
                    }
                }
            }
        }
        drop(done_tx);
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }

        let mut out = Vec::with_capacity(n);
        for s in slots {
            out.push(s.expect("task not executed"));
        }
        (out, totals)
    }
}

/// Real host timings of one overlapped round, fed to
/// [`finish_round_overlapped`] alongside the modeled inputs.
#[derive(Debug, Clone, Copy)]
pub struct OverlappedTiming {
    /// measured wall-clock of the whole round as executed (the
    /// concurrent host pipeline)
    pub wall: Duration,
    /// measured wall-clock of the map window alone: base-task submission
    /// through the last completion drained, staging included (it ran
    /// inside the window, on the coordinator thread, between drains)
    pub window: Duration,
}

/// Assemble a [`RoundStats`] from measured pieces + the comm model,
/// under the **bulk-synchronous** schedule (`max_k(map_k) + reduce +
/// comm`). Both modeled fields are set to the bulk figure, and both
/// measured schedule columns to the measured wall: a bulk round tracked
/// no carry and ran no concurrency, so no overlap is claimed for it.
pub fn finish_round(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    measured_wall: Duration,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    let wall = measured_wall.as_secs_f64();
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: bulk,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: bulk,
        measured_wall_s: wall,
        measured_overlapped_s: wall,
        measured_serialized_s: wall,
    }
}

/// Assemble a [`RoundStats`] for an **overlapped** round. `stats_bytes`
/// is the small reduced-statistics upload that stays on the critical
/// path; `carry_s` is the previous round's hidden tail (its shuffle
/// transfer time plus its global-update compute), which this round pays
/// only to the extent it exceeds the map critical path. The bulk figure
/// is computed from the same measurements so `--overlap on` runs can
/// report both schedules side by side. `timing` carries the real host
/// timings: `measured_overlapped_s` is the round's true wall, and
/// `measured_serialized_s` reconstructs what serializing the same work
/// bulk-style would have cost (map window + reduce tail).
pub fn finish_round_overlapped(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    stats_bytes: u64,
    carry_s: f64,
    timing: OverlappedTiming,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    let overlapped = comm.overlapped_round_time(workers, stats_bytes, crit, carry_s);
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: overlapped,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: overlapped,
        measured_wall_s: timing.wall.as_secs_f64(),
        measured_overlapped_s: timing.wall.as_secs_f64(),
        measured_serialized_s: (timing.window + reduce_duration).as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_results() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..37).collect();
        let (out, durs) = mr.map(tasks, |_, x| x * x);
        assert_eq!(out, (0..37).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(durs.len(), 37);
    }

    #[test]
    fn serial_and_parallel_agree() {
        let tasks: Vec<u64> = (0..16).collect();
        let f = |_: usize, x: u64| {
            // tiny busy-work so durations are nonzero
            let mut acc = x;
            for i in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let (a, _) = MapReduce::new(1).map(tasks.clone(), f);
        let (b, _) = MapReduce::new(3).map(tasks, f);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_is_reused_across_rounds() {
        // many rounds through ONE executor: results stay correct and no
        // per-round spawn is needed (the pool threads persist)
        let mr = MapReduce::new(3);
        for round in 0..50u64 {
            let tasks: Vec<u64> = (0..7).collect();
            let (out, durs) = mr.map(tasks, |_, x| x + round);
            assert_eq!(out, (0..7).map(|x| x + round).collect::<Vec<_>>());
            assert_eq!(durs.len(), 7);
        }
    }

    #[test]
    fn borrowed_state_is_visible_to_tasks() {
        // tasks may capture caller-stack borrows (the coordinator hands
        // shards &data and &model this way)
        let shared: Vec<u64> = (0..100).collect();
        let mr = MapReduce::new(2);
        let tasks: Vec<usize> = (0..10).collect();
        let (out, _) = mr.map(tasks, |_, i| shared[i * 10]);
        assert_eq!(out, (0..10).map(|i| (i as u64) * 10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_with_payload() {
        // the original panic message must survive the pool boundary
        let mr = MapReduce::new(2);
        let tasks: Vec<u64> = (0..4).collect();
        let _ = mr.map(tasks, |_, x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn empty_task_list() {
        let mr = MapReduce::new(2);
        let (out, durs) = mr.map(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty() && durs.is_empty());
    }

    #[test]
    fn comm_model_costs_scale() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        let t = c.round_time(10, 5000);
        assert!((t - (1.0 + 1.0 + 5.0)).abs() < 1e-12);
        assert_eq!(CommModel::free().round_time(128, u64::MAX), 0.0);
    }

    #[test]
    fn round_stats_critical_path() {
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            0,
            Duration::from_millis(40),
        );
        assert_eq!(rs.map_critical_path(), Duration::from_millis(20));
        assert_eq!(rs.map_total(), Duration::from_millis(35));
        assert!((rs.modeled_wall_s - 0.022).abs() < 1e-9);
        // a bulk round claims no overlap: both schedule fields pin to
        // the serialized figure, and both measured columns to the wall
        assert_eq!(rs.modeled_bulk_s, rs.modeled_wall_s);
        assert_eq!(rs.modeled_overlapped_s, rs.modeled_wall_s);
        assert_eq!(rs.measured_overlapped_s, rs.measured_wall_s);
        assert_eq!(rs.measured_serialized_s, rs.measured_wall_s);
    }

    #[test]
    fn overlapped_round_time_takes_max_of_map_and_carry() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        // fixed part: 1.0 + 2*0.1 + 500/1000 = 1.7
        let slow_map = c.overlapped_round_time(2, 500, 5.0, 3.0);
        assert!((slow_map - (1.7 + 5.0)).abs() < 1e-12);
        let slow_carry = c.overlapped_round_time(2, 500, 2.0, 3.0);
        assert!((slow_carry - (1.7 + 3.0)).abs() < 1e-12);
        // no carry, free comm: overlapped == pure map critical path
        assert_eq!(CommModel::free().overlapped_round_time(8, 1 << 20, 0.25, 0.0), 0.25);
    }

    #[test]
    fn finish_round_overlapped_pins_both_schedule_formulas() {
        // the Fig. 8 contract: the SAME measurements yield the
        // serialized figure (map crit 20ms + reduce 2ms = 22ms) AND the
        // overlapped figure (max(map crit 20ms, carry 50ms) = 50ms)
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round_overlapped(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            4096,
            64,
            0.050,
            OverlappedTiming {
                wall: Duration::from_millis(40),
                window: Duration::from_millis(25),
            },
        );
        assert!((rs.modeled_bulk_s - 0.022).abs() < 1e-9);
        assert!((rs.modeled_overlapped_s - 0.050).abs() < 1e-9);
        assert_eq!(rs.modeled_wall_s, rs.modeled_overlapped_s);
        // measured columns: overlapped == real wall; serialized
        // reconstructs window + reduce tail (25ms + 2ms)
        assert!((rs.measured_overlapped_s - 0.040).abs() < 1e-9);
        assert_eq!(rs.measured_overlapped_s, rs.measured_wall_s);
        assert!((rs.measured_serialized_s - 0.027).abs() < 1e-9);
        // with the carry hidden under the map, the overlapped schedule
        // must beat bulk whenever carry < map_crit + reduce + comm
        let rs2 = finish_round_overlapped(
            &CommModel::free(),
            vec![Duration::from_millis(20)],
            Duration::from_millis(2),
            4096,
            64,
            0.010,
            OverlappedTiming {
                wall: Duration::from_millis(40),
                window: Duration::from_millis(25),
            },
        );
        assert!(rs2.modeled_overlapped_s < rs2.modeled_bulk_s);
    }

    #[test]
    fn map_collect_reports_each_completion_once_in_rank_order() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..24).collect();
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let (out, durs) = mr.map_collect(tasks, |_, x| x * 3, |rank, idx| seen.push((rank, idx)));
        // results in input order regardless of completion order
        assert_eq!(out, (0..24).map(|x| x * 3).collect::<Vec<_>>());
        assert_eq!(durs.len(), 24);
        // ranks arrive 0..n in order; indices are a permutation of 0..n
        assert_eq!(
            seen.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
            (0..24).collect::<Vec<_>>()
        );
        let mut idxs: Vec<usize> = seen.iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn map_streaming_accumulates_followups() {
        // every task is granted exactly two follow-ups; the result and
        // the pooled duration must account for base + both grants, on
        // both the inline and the pooled path
        for parallelism in [1usize, 4] {
            let mr = MapReduce::new(parallelism);
            let tasks: Vec<u64> = (0..12).collect();
            let mut events = 0usize;
            let (out, durs) = mr.map_streaming(
                tasks,
                |_, x| x * 10,
                |_, r| r + 1,
                |ev| {
                    events += 1;
                    ev.followups_done < 2
                },
            );
            assert_eq!(out, (0..12).map(|x| x * 10 + 2).collect::<Vec<_>>());
            assert_eq!(durs.len(), 12);
            // 12 base + 24 follow-up completions, each reacted once
            assert_eq!(events, 36);
        }
    }

    #[test]
    fn map_streaming_event_fields_are_consistent() {
        let mr = MapReduce::new(3);
        let tasks: Vec<u64> = (0..9).collect();
        let mut seen: Vec<(usize, usize, usize)> = Vec::new();
        let (_, _) = mr.map_streaming(
            tasks,
            |i, x| x + i as u64,
            |_, r| r,
            |ev| {
                seen.push((ev.rank, ev.index, ev.followups_done));
                ev.followups_done == 0 && ev.index % 3 == 0
            },
        );
        // ranks are a strict 0..len sequence
        assert_eq!(
            seen.iter().map(|&(r, _, _)| r).collect::<Vec<_>>(),
            (0..seen.len()).collect::<Vec<_>>()
        );
        // indexes 0,3,6 got exactly one follow-up event each
        for i in [0usize, 3, 6] {
            assert_eq!(
                seen.iter().filter(|&&(_, x, fu)| x == i && fu == 1).count(),
                1
            );
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn delay_hook_pins_completion_order() {
        // with 4 workers and a long injected delay on task 0, every
        // other base task must complete (and react) before task 0 does —
        // the determinism lever the interleaving harness relies on
        let mut mr = MapReduce::new(4);
        mr.set_delay_hook(Some(Arc::new(|i| {
            Duration::from_millis(if i == 0 { 120 } else { 0 })
        })));
        let tasks: Vec<u64> = (0..4).collect();
        let mut order: Vec<usize> = Vec::new();
        let (out, _) = mr.map_streaming(
            tasks,
            |_, x| x,
            |_, r| r,
            |ev| {
                order.push(ev.index);
                false
            },
        );
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(order.len(), 4);
        assert_eq!(*order.last().unwrap(), 0, "delayed task finishes last");
    }

    #[test]
    #[should_panic(expected = "streaming boom")]
    fn map_streaming_panic_drains_then_propagates() {
        let mr = MapReduce::new(3);
        let tasks: Vec<u64> = (0..6).collect();
        let _ = mr.map_streaming(
            tasks,
            |_, x| {
                if x == 4 {
                    panic!("streaming boom");
                }
                x
            },
            |_, r| r,
            // grant one follow-up to everything that completes before
            // the panic lands; the drain must still terminate
            |ev| ev.followups_done == 0,
        );
    }

    #[test]
    fn more_workers_raise_comm_but_cut_critical_path() {
        // the Fig. 8 mechanism in miniature: total work W split over K
        // workers has modeled time W/K + comm(K); check the tradeoff turns
        let comm = CommModel {
            round_latency_s: 0.5,
            per_worker_latency_s: 0.2,
            bandwidth_bytes_per_s: f64::INFINITY,
        };
        let total_work = 10.0;
        let modeled = |k: usize| total_work / k as f64 + comm.round_time(k, 0);
        assert!(modeled(4) < modeled(1));
        assert!(modeled(64) > modeled(8), "saturation must kick in");
    }
}
