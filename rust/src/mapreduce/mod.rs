//! In-process map-reduce runtime — the substitute for the paper's Hadoop
//! deployment (§5, Fig. 3/4). Mappers run on worker threads; per-task
//! compute time is measured individually so the **modeled wall-clock**
//! (what a K-machine cluster would see: `max_k(map_k) + reduce + comm`)
//! is well-defined even on a single-core container. The communication
//! cost model is parameterized on per-round latency (Hadoop job overhead)
//! and bandwidth, and drives the Fig. 8 saturation behaviour.

use std::time::{Duration, Instant};

/// Communication/overhead model for one map-reduce round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// fixed per-round overhead (job scheduling, barrier, shuffle start).
    /// The paper's Hadoop-era overhead is seconds; default reflects a
    /// modest cluster (tunable from every bench/CLI).
    pub round_latency_s: f64,
    /// per-worker connection setup cost
    pub per_worker_latency_s: f64,
    /// bytes/second for state transfer (both directions pooled)
    pub bandwidth_bytes_per_s: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel {
            round_latency_s: 2.0,           // Hadoop job launch overhead
            per_worker_latency_s: 0.05,     // per-mapper startup
            bandwidth_bytes_per_s: 100e6,   // ~1 Gb/s effective
        }
    }
}

impl CommModel {
    /// No communication cost at all (pure algorithmic comparisons).
    pub fn free() -> Self {
        CommModel {
            round_latency_s: 0.0,
            per_worker_latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
        }
    }

    /// Modeled communication time for a round with `workers` mappers
    /// moving `bytes` of state.
    pub fn round_time(&self, workers: usize, bytes: u64) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + bytes as f64 / self.bandwidth_bytes_per_s
    }
}

/// Timing/traffic record of one map-reduce round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// measured compute duration of each map task
    pub map_durations: Vec<Duration>,
    /// measured reduce-step duration
    pub reduce_duration: Duration,
    /// bytes the round moved (stats up + state down)
    pub bytes_transferred: u64,
    /// modeled distributed wall-clock for the round (seconds)
    pub modeled_wall_s: f64,
    /// actually measured wall-clock on this host (seconds)
    pub measured_wall_s: f64,
}

impl RoundStats {
    /// max_k map time — the parallel critical path.
    pub fn map_critical_path(&self) -> Duration {
        self.map_durations.iter().copied().max().unwrap_or_default()
    }

    /// Σ_k map time — what a serial execution would pay.
    pub fn map_total(&self) -> Duration {
        self.map_durations.iter().sum()
    }
}

/// The map-reduce executor. `parallelism` caps the number of OS threads
/// (tasks beyond it queue, exactly like mappers on a small cluster).
#[derive(Debug, Clone)]
pub struct MapReduce {
    pub parallelism: usize,
}

impl MapReduce {
    pub fn new(parallelism: usize) -> Self {
        assert!(parallelism >= 1);
        MapReduce { parallelism }
    }

    /// Use all available cores.
    pub fn host_parallel() -> Self {
        let p = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        MapReduce { parallelism: p }
    }

    /// Run `f` over `tasks`, returning results (input order) and each
    /// task's measured compute duration. Tasks are distributed over at
    /// most `parallelism` threads; with `parallelism == 1` execution is
    /// in-place (no thread overhead, cleanest per-task timing on a
    /// single-core host).
    pub fn map<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        if self.parallelism == 1 || n == 1 {
            let mut out = Vec::with_capacity(n);
            let mut durs = Vec::with_capacity(n);
            for (i, t) in tasks.into_iter().enumerate() {
                let t0 = Instant::now();
                out.push(f(i, t));
                durs.push(t0.elapsed());
            }
            return (out, durs);
        }

        // work-stealing by atomic counter; results stream back over a
        // channel tagged with their task index
        let next = std::sync::atomic::AtomicUsize::new(0);
        let inputs: Vec<std::sync::Mutex<Option<T>>> = tasks
            .into_iter()
            .map(|t| std::sync::Mutex::new(Some(t)))
            .collect();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, R, Duration)>();

        std::thread::scope(|scope| {
            for _ in 0..self.parallelism.min(n) {
                let tx = tx.clone();
                let next = &next;
                let inputs = &inputs;
                let f = &f;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let t = inputs[i].lock().unwrap().take().unwrap();
                    let t0 = Instant::now();
                    let r = f(i, t);
                    tx.send((i, r, t0.elapsed())).expect("collector alive");
                });
            }
        });
        drop(tx);

        let mut slots: Vec<Option<(R, Duration)>> = (0..n).map(|_| None).collect();
        for (i, r, d) in rx {
            slots[i] = Some((r, d));
        }
        let mut out = Vec::with_capacity(n);
        let mut durs = Vec::with_capacity(n);
        for s in slots {
            let (r, d) = s.expect("task not executed");
            out.push(r);
            durs.push(d);
        }
        (out, durs)
    }
}

/// Assemble a [`RoundStats`] from measured pieces + the comm model.
pub fn finish_round(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    measured_wall: Duration,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let modeled = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: modeled,
        measured_wall_s: measured_wall.as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_results() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..37).collect();
        let (out, durs) = mr.map(tasks, |_, x| x * x);
        assert_eq!(out, (0..37).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(durs.len(), 37);
    }

    #[test]
    fn serial_and_parallel_agree() {
        let tasks: Vec<u64> = (0..16).collect();
        let f = |_: usize, x: u64| {
            // tiny busy-work so durations are nonzero
            let mut acc = x;
            for i in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let (a, _) = MapReduce::new(1).map(tasks.clone(), f);
        let (b, _) = MapReduce::new(3).map(tasks, f);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_task_list() {
        let mr = MapReduce::new(2);
        let (out, durs) = mr.map(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty() && durs.is_empty());
    }

    #[test]
    fn comm_model_costs_scale() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        let t = c.round_time(10, 5000);
        assert!((t - (1.0 + 1.0 + 5.0)).abs() < 1e-12);
        assert_eq!(CommModel::free().round_time(128, u64::MAX), 0.0);
    }

    #[test]
    fn round_stats_critical_path() {
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            0,
            Duration::from_millis(40),
        );
        assert_eq!(rs.map_critical_path(), Duration::from_millis(20));
        assert_eq!(rs.map_total(), Duration::from_millis(35));
        assert!((rs.modeled_wall_s - 0.022).abs() < 1e-9);
    }

    #[test]
    fn more_workers_raise_comm_but_cut_critical_path() {
        // the Fig. 8 mechanism in miniature: total work W split over K
        // workers has modeled time W/K + comm(K); check the tradeoff turns
        let comm = CommModel {
            round_latency_s: 0.5,
            per_worker_latency_s: 0.2,
            bandwidth_bytes_per_s: f64::INFINITY,
        };
        let total_work = 10.0;
        let modeled = |k: usize| total_work / k as f64 + comm.round_time(k, 0);
        assert!(modeled(4) < modeled(1));
        assert!(modeled(64) > modeled(8), "saturation must kick in");
    }
}
