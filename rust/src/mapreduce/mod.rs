//! In-process map-reduce runtime — the substitute for the paper's Hadoop
//! deployment (§5, Fig. 3/4). Mappers run on a **persistent worker
//! pool** (threads are spawned once at construction and reused across
//! rounds, so a 1000-round chain pays thread startup once, not 1000
//! times); per-task compute time is measured individually so the
//! **modeled wall-clock** (what a K-machine cluster would see:
//! `max_k(map_k) + reduce + comm`) is well-defined even on a single-core
//! container. The communication cost model is parameterized on per-round
//! latency (Hadoop job overhead) and bandwidth, and drives the Fig. 8
//! saturation behaviour.
//!
//! Two round schedules are modeled (DESIGN.md § Barrier-free rounds):
//! the **bulk-synchronous** schedule serializes map → reduce → comm, and
//! the **overlapped** schedule hides the previous round's shuffle
//! transfer and global updates behind the current map, so the modeled
//! wall is `latency + stats_upload + max(map_crit, carry_prev)` instead
//! of the sum. Completion delivery is a channel, not a barrier: the
//! caller drains completions as tasks finish ([`MapReduce::map_collect`]),
//! which is what lets a coordinator react to fast shards while slow ones
//! are still sweeping.

use std::any::Any;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Communication/overhead model for one map-reduce round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// fixed per-round overhead (job scheduling, barrier, shuffle start).
    /// The paper's Hadoop-era overhead is seconds; default reflects a
    /// modest cluster (tunable from every bench/CLI).
    pub round_latency_s: f64,
    /// per-worker connection setup cost
    pub per_worker_latency_s: f64,
    /// bytes/second for state transfer (both directions pooled)
    pub bandwidth_bytes_per_s: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel {
            round_latency_s: 2.0,           // Hadoop job launch overhead
            per_worker_latency_s: 0.05,     // per-mapper startup
            bandwidth_bytes_per_s: 100e6,   // ~1 Gb/s effective
        }
    }
}

impl CommModel {
    /// No communication cost at all (pure algorithmic comparisons).
    pub fn free() -> Self {
        CommModel {
            round_latency_s: 0.0,
            per_worker_latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
        }
    }

    /// Modeled communication time for a round with `workers` mappers
    /// moving `bytes` of state.
    pub fn round_time(&self, workers: usize, bytes: u64) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Modeled wall-clock of one **overlapped** round. Only the small
    /// reduced-statistics upload (`stats_bytes`: J_k counts, pooled dim
    /// stats) sits on the critical path; the bulky shuffle transfer and
    /// the global-update compute of the *previous* round (`carry_s`)
    /// ride behind the current map, so the round pays
    /// `max(map_crit_s, carry_s)` instead of their sum.
    pub fn overlapped_round_time(
        &self,
        workers: usize,
        stats_bytes: u64,
        map_crit_s: f64,
        carry_s: f64,
    ) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + stats_bytes as f64 / self.bandwidth_bytes_per_s
            + map_crit_s.max(carry_s)
    }
}

/// Timing/traffic record of one map-reduce round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// measured compute duration of each map task
    pub map_durations: Vec<Duration>,
    /// measured reduce-step duration
    pub reduce_duration: Duration,
    /// bytes the round moved (stats up + state down)
    pub bytes_transferred: u64,
    /// modeled distributed wall-clock for the round (seconds) under the
    /// schedule the round actually ran: equals [`Self::modeled_bulk_s`]
    /// for bulk-synchronous rounds and [`Self::modeled_overlapped_s`]
    /// for overlapped rounds
    pub modeled_wall_s: f64,
    /// modeled wall under the bulk-synchronous schedule
    /// (`max_k(map_k) + reduce + comm`), always populated so the two
    /// schedules stay comparable round-by-round
    pub modeled_bulk_s: f64,
    /// modeled wall under the overlapped schedule
    /// (`latency + stats_upload + max(map_crit, carry_prev)`); for a
    /// bulk round this is reported equal to the bulk figure (no carry
    /// was tracked, so no overlap is claimed)
    pub modeled_overlapped_s: f64,
    /// actually measured wall-clock on this host (seconds)
    pub measured_wall_s: f64,
}

impl RoundStats {
    /// max_k map time — the parallel critical path.
    pub fn map_critical_path(&self) -> Duration {
        self.map_durations.iter().copied().max().unwrap_or_default()
    }

    /// Σ_k map time — what a serial execution would pay.
    pub fn map_total(&self) -> Duration {
        self.map_durations.iter().sum()
    }
}

/// A type-erased unit of work shipped to the pool. Jobs are *logically*
/// non-`'static` (they borrow the caller's stack); [`MapReduce::map`]
/// guarantees completion before returning, which is what makes the
/// lifetime erasure sound — see the safety comment there.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// The persistent worker threads. Shared one `Receiver` behind a mutex
/// (the lock is held while idle-waiting in `recv`, which serializes job
/// *pickup*, not execution — pickup is nanoseconds against millisecond
/// sweep tasks). Dropping the pool closes the channel and joins.
struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(threads: usize) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: pool dropped
                    }
                })
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
        }
    }

    fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool sender alive until drop")
            .send(job)
            .expect("worker pool alive");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel so workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The map-reduce executor. `parallelism` caps the number of worker
/// threads (tasks beyond it queue, exactly like mappers on a small
/// cluster). Workers are spawned once here and reused by every
/// subsequent [`Self::map`] round.
pub struct MapReduce {
    parallelism: usize,
    pool: Option<WorkerPool>,
}

impl std::fmt::Debug for MapReduce {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapReduce")
            .field("parallelism", &self.parallelism)
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl MapReduce {
    /// Executor with `parallelism` persistent worker threads (≥ 1).
    pub fn new(parallelism: usize) -> Self {
        assert!(parallelism >= 1);
        // parallelism == 1 runs inline on the caller thread: no pool,
        // no thread overhead, cleanest per-task timing on one core
        let pool = (parallelism > 1).then(|| WorkerPool::new(parallelism));
        MapReduce { parallelism, pool }
    }

    /// Use all available cores.
    pub fn host_parallel() -> Self {
        let p = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        MapReduce::new(p)
    }

    /// The configured worker-thread cap.
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Run `f` over `tasks`, returning results (input order) and each
    /// task's measured compute duration (queue wait excluded). Tasks are
    /// distributed over the persistent pool; with `parallelism == 1`
    /// (or a single task) execution is in-place.
    pub fn map<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        self.map_collect(tasks, f, |_, _| {})
    }

    /// Like [`Self::map`], but the caller observes completions as they
    /// happen: `on_done(rank, index)` runs on the **caller** thread when
    /// the `rank`-th task to finish (0-based completion order) turns out
    /// to be input `index`. This is the submit/poll surface the
    /// barrier-free coordinator builds on — instead of blocking on a
    /// latch, the caller drains a completion channel and can react to
    /// fast shards while slow ones are still sweeping. Results are still
    /// returned in **input order**: every completion message carries its
    /// task index, so out-of-order execution cannot scramble the output
    /// vector or the per-task duration vector.
    ///
    /// If a task panics, the first payload is re-raised on the caller
    /// thread — but only after all `n` completions (success or panic)
    /// have been drained, so a panicking task can never wedge the pool
    /// or leave a borrow live. `on_done` is not invoked for the
    /// panicking task(s).
    pub fn map_collect<T, R, F, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        mut on_done: C,
    ) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        C: FnMut(usize, usize),
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => {
                let mut out = Vec::with_capacity(n);
                let mut durs = Vec::with_capacity(n);
                for (i, t) in tasks.into_iter().enumerate() {
                    let t0 = Instant::now();
                    out.push(f(i, t));
                    durs.push(t0.elapsed());
                    on_done(i, i);
                }
                return (out, durs);
            }
        };

        // Hand each task to the pool as a type-erased job. The jobs
        // borrow this stack frame (`inputs`, `f`), so their lifetime is
        // transmuted up to 'static.
        //
        // SAFETY: every borrow the jobs capture outlives the jobs
        // themselves because this function blocks on the completion
        // drain below until ALL n jobs have sent their message
        // (panicking jobs are caught and still send one), and the pool
        // can only execute a job once. Nothing below the drain loop can
        // observe a live job. There is deliberately NO public handle
        // type that would let a caller forget a pending job — the drain
        // is unconditional.
        let inputs: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let (done_tx, done_rx) =
            channel::<(usize, Result<(R, Duration), Box<dyn Any + Send>>)>();
        for i in 0..n {
            let inputs = &inputs;
            let f = &f;
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let t = inputs[i].lock().unwrap().take().expect("task taken once");
                    let t0 = Instant::now();
                    let r = f(i, t);
                    (r, t0.elapsed())
                }));
                // only fails if the receiver is gone, which the
                // unconditional drain below rules out
                let _ = done_tx.send((i, ran));
            });
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            pool.submit(job);
        }
        drop(done_tx);
        // drain exactly n completions — the poll loop. Every job sends
        // one message whether it returned or panicked, so a panicking
        // task cannot deadlock the round; the first payload is re-raised
        // once everything is accounted for (as std::thread::scope would).
        let mut slots: Vec<Option<(R, Duration)>> = (0..n).map(|_| None).collect();
        let mut panic_payload: Option<Box<dyn Any + Send>> = None;
        for rank in 0..n {
            let (i, ran) = done_rx.recv().expect("every job sends a completion");
            match ran {
                Ok(rd) => {
                    slots[i] = Some(rd);
                    on_done(rank, i);
                }
                Err(p) => {
                    if panic_payload.is_none() {
                        panic_payload = Some(p);
                    }
                }
            }
        }
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }

        let mut out = Vec::with_capacity(n);
        let mut durs = Vec::with_capacity(n);
        for s in slots {
            let (r, d) = s.expect("task not executed");
            out.push(r);
            durs.push(d);
        }
        (out, durs)
    }
}

/// Assemble a [`RoundStats`] from measured pieces + the comm model,
/// under the **bulk-synchronous** schedule (`max_k(map_k) + reduce +
/// comm`). Both modeled fields are set to the bulk figure: a bulk round
/// tracked no carry, so no overlap is claimed for it.
pub fn finish_round(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    measured_wall: Duration,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: bulk,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: bulk,
        measured_wall_s: measured_wall.as_secs_f64(),
    }
}

/// Assemble a [`RoundStats`] for an **overlapped** round. `stats_bytes`
/// is the small reduced-statistics upload that stays on the critical
/// path; `carry_s` is the previous round's hidden tail (its shuffle
/// transfer time plus its global-update compute), which this round pays
/// only to the extent it exceeds the map critical path. The bulk figure
/// is computed from the same measurements so `--overlap on` runs can
/// report both schedules side by side.
pub fn finish_round_overlapped(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    stats_bytes: u64,
    carry_s: f64,
    measured_wall: Duration,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    let overlapped = comm.overlapped_round_time(workers, stats_bytes, crit, carry_s);
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: overlapped,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: overlapped,
        measured_wall_s: measured_wall.as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_results() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..37).collect();
        let (out, durs) = mr.map(tasks, |_, x| x * x);
        assert_eq!(out, (0..37).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(durs.len(), 37);
    }

    #[test]
    fn serial_and_parallel_agree() {
        let tasks: Vec<u64> = (0..16).collect();
        let f = |_: usize, x: u64| {
            // tiny busy-work so durations are nonzero
            let mut acc = x;
            for i in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let (a, _) = MapReduce::new(1).map(tasks.clone(), f);
        let (b, _) = MapReduce::new(3).map(tasks, f);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_is_reused_across_rounds() {
        // many rounds through ONE executor: results stay correct and no
        // per-round spawn is needed (the pool threads persist)
        let mr = MapReduce::new(3);
        for round in 0..50u64 {
            let tasks: Vec<u64> = (0..7).collect();
            let (out, durs) = mr.map(tasks, |_, x| x + round);
            assert_eq!(out, (0..7).map(|x| x + round).collect::<Vec<_>>());
            assert_eq!(durs.len(), 7);
        }
    }

    #[test]
    fn borrowed_state_is_visible_to_tasks() {
        // tasks may capture caller-stack borrows (the coordinator hands
        // shards &data and &model this way)
        let shared: Vec<u64> = (0..100).collect();
        let mr = MapReduce::new(2);
        let tasks: Vec<usize> = (0..10).collect();
        let (out, _) = mr.map(tasks, |_, i| shared[i * 10]);
        assert_eq!(out, (0..10).map(|i| (i as u64) * 10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_with_payload() {
        // the original panic message must survive the pool boundary
        let mr = MapReduce::new(2);
        let tasks: Vec<u64> = (0..4).collect();
        let _ = mr.map(tasks, |_, x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn empty_task_list() {
        let mr = MapReduce::new(2);
        let (out, durs) = mr.map(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty() && durs.is_empty());
    }

    #[test]
    fn comm_model_costs_scale() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        let t = c.round_time(10, 5000);
        assert!((t - (1.0 + 1.0 + 5.0)).abs() < 1e-12);
        assert_eq!(CommModel::free().round_time(128, u64::MAX), 0.0);
    }

    #[test]
    fn round_stats_critical_path() {
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            0,
            Duration::from_millis(40),
        );
        assert_eq!(rs.map_critical_path(), Duration::from_millis(20));
        assert_eq!(rs.map_total(), Duration::from_millis(35));
        assert!((rs.modeled_wall_s - 0.022).abs() < 1e-9);
        // a bulk round claims no overlap: both schedule fields pin to
        // the serialized figure
        assert_eq!(rs.modeled_bulk_s, rs.modeled_wall_s);
        assert_eq!(rs.modeled_overlapped_s, rs.modeled_wall_s);
    }

    #[test]
    fn overlapped_round_time_takes_max_of_map_and_carry() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        // fixed part: 1.0 + 2*0.1 + 500/1000 = 1.7
        let slow_map = c.overlapped_round_time(2, 500, 5.0, 3.0);
        assert!((slow_map - (1.7 + 5.0)).abs() < 1e-12);
        let slow_carry = c.overlapped_round_time(2, 500, 2.0, 3.0);
        assert!((slow_carry - (1.7 + 3.0)).abs() < 1e-12);
        // no carry, free comm: overlapped == pure map critical path
        assert_eq!(CommModel::free().overlapped_round_time(8, 1 << 20, 0.25, 0.0), 0.25);
    }

    #[test]
    fn finish_round_overlapped_pins_both_schedule_formulas() {
        // the Fig. 8 contract: the SAME measurements yield the
        // serialized figure (map crit 20ms + reduce 2ms = 22ms) AND the
        // overlapped figure (max(map crit 20ms, carry 50ms) = 50ms)
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round_overlapped(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            4096,
            64,
            0.050,
            Duration::from_millis(40),
        );
        assert!((rs.modeled_bulk_s - 0.022).abs() < 1e-9);
        assert!((rs.modeled_overlapped_s - 0.050).abs() < 1e-9);
        assert_eq!(rs.modeled_wall_s, rs.modeled_overlapped_s);
        // with the carry hidden under the map, the overlapped schedule
        // must beat bulk whenever carry < map_crit + reduce + comm
        let rs2 = finish_round_overlapped(
            &CommModel::free(),
            vec![Duration::from_millis(20)],
            Duration::from_millis(2),
            4096,
            64,
            0.010,
            Duration::from_millis(40),
        );
        assert!(rs2.modeled_overlapped_s < rs2.modeled_bulk_s);
    }

    #[test]
    fn map_collect_reports_each_completion_once_in_rank_order() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..24).collect();
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let (out, durs) = mr.map_collect(tasks, |_, x| x * 3, |rank, idx| seen.push((rank, idx)));
        // results in input order regardless of completion order
        assert_eq!(out, (0..24).map(|x| x * 3).collect::<Vec<_>>());
        assert_eq!(durs.len(), 24);
        // ranks arrive 0..n in order; indices are a permutation of 0..n
        assert_eq!(
            seen.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
            (0..24).collect::<Vec<_>>()
        );
        let mut idxs: Vec<usize> = seen.iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_raise_comm_but_cut_critical_path() {
        // the Fig. 8 mechanism in miniature: total work W split over K
        // workers has modeled time W/K + comm(K); check the tradeoff turns
        let comm = CommModel {
            round_latency_s: 0.5,
            per_worker_latency_s: 0.2,
            bandwidth_bytes_per_s: f64::INFINITY,
        };
        let total_work = 10.0;
        let modeled = |k: usize| total_work / k as f64 + comm.round_time(k, 0);
        assert!(modeled(4) < modeled(1));
        assert!(modeled(64) > modeled(8), "saturation must kick in");
    }
}
